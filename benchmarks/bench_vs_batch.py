"""Paper Fig. 5: D3-GNN vs the batch-recompute baseline (DGL emulation).

Streaming and WCount-style batched variants of both systems, compared on
work (messages recomputed vs incremental RMIs) and wall time. The paper
reports ~76x (streaming) / ~15x (WCount-2000) throughput advantages at
cluster scale; here the hardware-independent ratio is the message count.
"""
from __future__ import annotations

from repro.core import windowing as win

from benchmarks.baseline_batch import BatchRecomputeBaseline
from benchmarks.common import (D_IN, fmt_row, make_case, make_pipeline,
                               run_and_time)


def run(scale: str = "small"):
    n_edges = {"small": 1200, "full": 10000}[scale]
    case = make_case(n_edges=n_edges, n_nodes=300)
    rows = []

    # ---- D3-GNN streaming + windowed
    results = {}
    for name, policy, tick in (
            ("stream", win.WindowConfig(kind=win.STREAMING), 1),
            ("wcount", win.WindowConfig(kind=win.TUMBLING, interval=2), 64)):
        model, params, pipe = make_pipeline(case, n_parts=8, window=policy)
        wall = run_and_time(pipe, case, tick_edges=max(tick, 16))
        results[f"d3gnn_{name}"] = (wall, pipe.metrics.reduce_msgs
                                    + pipe.metrics.broadcast_msgs)

    # ---- batch-recompute baseline (per-edge and WCount-64 batches)
    model, params, _ = make_pipeline(case, n_parts=8)
    for name, bs in (("stream", 8), ("wcount", 64)):
        base = BatchRecomputeBaseline(model=model, params=params,
                                      n_nodes=case.n_nodes, d_in=D_IN)
        base.set_features(case.feats)
        for lo in range(0, len(case.edges), bs):
            base.apply_batch(case.edges[lo: lo + bs])
        results[f"batch_{name}"] = (base.wall_seconds,
                                    base.messages_recomputed)

    for name in ("stream", "wcount"):
        dw, dm = results[f"d3gnn_{name}"]
        bw, bm = results[f"batch_{name}"]
        rows.append(fmt_row(
            f"fig5_vs_batch[{name}]", 1e6 * dw,
            f"d3gnn_msgs={dm};baseline_msgs={bm};"
            f"msg_ratio_x={bm / max(dm, 1):.1f};"
            f"wall_ratio_x={bw / max(dw, 1e-9):.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
