"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract), at
CPU-feasible scale; pass --scale full for the larger configurations.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (bench_comm_volume, bench_explosion, bench_imbalance,
                        bench_latency, bench_runtime, bench_scaling,
                        bench_throughput, bench_training, bench_vs_batch)

ALL = {
    "fig4a_throughput": bench_throughput,
    "fig4b_comm_volume": bench_comm_volume,
    "fig4c_runtime": bench_runtime,
    "fig4d_imbalance": bench_imbalance,
    "fig5_vs_batch": bench_vs_batch,
    "fig5d_training": bench_training,
    "fig6_explosion": bench_explosion,
    "fig7_latency": bench_latency,
    "dist_scaling": bench_scaling,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--scale", default="small", choices=["small", "full"])
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name, mod in ALL.items():
        if args.only and args.only not in name:
            continue
        try:
            for row in mod.run(scale=args.scale):
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
    if failed:
        for name, err in failed:
            print(f"{name},FAILED,{err}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
