"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract), at
CPU-feasible scale; pass --scale full for the larger configurations.

``--json PATH`` additionally writes the rows as structured JSON (the
``derived`` k=v;k=v string parsed into a dict) — CI's bench lane runs
``--profile ci --json BENCH.json`` and uploads the file as the
perf-snapshot artifact, so the bench trajectory is recorded per commit.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from types import SimpleNamespace

from benchmarks import (bench_comm_volume, bench_delivery,
                        bench_delta_gating, bench_explosion,
                        bench_imbalance, bench_latency, bench_recovery,
                        bench_runtime, bench_scaling, bench_serving,
                        bench_throughput, bench_training, bench_vs_batch)

ALL = {
    "fig4a_throughput": bench_throughput,
    "fig4b_comm_volume": bench_comm_volume,
    "fig4c_runtime": bench_runtime,
    "fig4d_imbalance": bench_imbalance,
    "fig5_vs_batch": bench_vs_batch,
    "training": bench_training,
    "fig6_explosion": bench_explosion,
    "fig7_latency": bench_latency,
    "dist_scaling": bench_scaling,
    "delivery_backend": bench_delivery,
    "delta_gating": bench_delta_gating,
    "serving": bench_serving,
    "recovery": bench_recovery,
    # the driver comparison alone (fig4a without the 12-policy sweep) —
    # what the CI perf snapshot tracks
    "driver_comparison": SimpleNamespace(
        run=lambda scale="small": bench_throughput.run_driver_comparison(
            n_edges={"small": 2000, "full": 8000}[scale])),
}

# fixed-seed subsets: every PROFILES benchmark builds its stream from a
# seeded rng, so CI snapshots are comparable across commits
PROFILES = {
    "ci": ["driver_comparison", "dist_scaling", "delivery_backend",
           "serving", "fig4b_comm_volume", "delta_gating", "training",
           "recovery"],
}


def parse_derived(derived: str) -> dict:
    """"k=v;k=v" -> dict, float-casting where possible ("1.40x" -> 1.4)."""
    out = {}
    for item in derived.split(";"):
        if "=" not in item:
            if item:
                out[item] = True
            continue
        k, v = item.split("=", 1)
        try:
            out[k] = float(v[:-1] if v.endswith("x") else v)
        except ValueError:
            out[k] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--profile", default=None, choices=sorted(PROFILES),
                    help="named benchmark subset (overrides --only)")
    ap.add_argument("--scale", default="small", choices=["small", "full"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as structured JSON")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="fail when any shared row regresses vs this "
                         "BENCH.json snapshot: events_per_s drops >20%%, "
                         "serving p99_ms rises >100%%, or wire_mb rises "
                         ">25%% (benchmarks/compare.py GATED_METRICS; a "
                         "missing file skips the gate — the CI download "
                         "is best-effort)")
    args = ap.parse_args()

    if args.profile:
        selected = {n: ALL[n] for n in PROFILES[args.profile]}
    else:
        selected = {n: m for n, m in ALL.items()
                    if not args.only or args.only in n}

    print("name,us_per_call,derived")
    rows, failed = [], []
    for name, mod in selected.items():
        try:
            for row in mod.run(scale=args.scale):
                print(row)
                sys.stdout.flush()
                # names may carry commas ("driver[super_tick,T=16]"); the
                # derived field never does (it is ;-separated) — rsplit
                rname, us, derived = row.rsplit(",", 2)
                rows.append({"name": rname, "us_per_call": float(us),
                             "derived": parse_derived(derived)})
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "profile": args.profile,
                       "scale": args.scale,
                       "benchmarks": sorted(selected),
                       "failed": [n for n, _ in failed],
                       "rows": rows}, f, indent=2)
        print(f"wrote {len(rows)} rows -> {args.json}", file=sys.stderr)
    if failed:
        for name, err in failed:
            print(f"{name},FAILED,{err}")
        raise SystemExit(1)
    if args.compare:
        from benchmarks.compare import compare_to_baseline
        regressions = compare_to_baseline(rows, args.compare)
        if regressions is None:
            print(f"no baseline at {args.compare}; skipping perf compare",
                  file=sys.stderr)
        elif regressions:
            for msg in regressions:
                print(f"REGRESSION: {msg}")
            raise SystemExit(1)
        else:
            print(f"perf compare vs {args.compare}: no events_per_s / "
                  "p99_ms / wire_mb regressions", file=sys.stderr)


if __name__ == "__main__":
    main()
