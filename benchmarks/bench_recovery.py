"""Fail-stop recovery drill (ISSUE 10): time-to-first-answer + degraded p99.

Metric: what serving actually pays for a fail-stop shard loss. The worker
streams the chaos plane's hub-heavy stream on a 4-shard mesh with
consistent-cut checkpoints, then loses 2 shards mid-stream and recovers
live: checkpoint-restore, `D3Pipeline.reshard` onto the survivor mesh,
replay of the chunks since the cut — with the ServeSession in declared
degraded mode the whole time. Queries submitted during the degraded
window measure the p99 a client would see mid-recovery;
`time-to-first-answer` is the wall clock from the moment of failure to
the first post-failure answer landing on the host.

Rows (one per driver):

  recovery[failstop,<driver>,D=4->2]
    us_per_call   = recovery wall time (failure -> stream resumed), in us
    first_answer_ms = failure -> first post-failure answer
    p99_degraded_ms = answer p99 over queries issued while degraded
    dropped / route_dropped = MUST be 0 (the CI validator gates this)
    replayed      = chunks replayed from the last consistent cut

Runs in a subprocess with a forced 4-device CPU backend (the XLA device
count is fixed at backend init), mirroring bench_serving/bench_scaling.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

from benchmarks.common import fmt_row

REPO = Path(__file__).resolve().parents[1]

_WORKER = """
import tempfile
import time
import numpy as np
import jax
from repro.ft.chaos import (ChaosConfig, hub_heavy_stream, _chunks,
                            _feat_rows, build_pipeline, _advance)
from repro.ft.checkpoint import CheckpointManager
from repro.launch.mesh import make_stream_mesh, survivor_mesh
from repro.serve.session import ServeSession

cfg = ChaosConfig(driver={driver!r}, n_events={n_events})
edges, feats, hubs = hub_heavy_stream(cfg)
chunks = _chunks(cfg, edges)
fail_at = min(cfg.fail_at_chunk, len(chunks) - 1)

pipe = build_pipeline(cfg, make_stream_mesh(4))
session = ServeSession(pipe, driver=cfg.driver, max_retries=2)
mgr = CheckpointManager(tempfile.mkdtemp(), keep=3)

recovery_s = first_answer_s = None
degraded_qids = []
replayed = 0
for i, chunk in enumerate(chunks):
    if i == fail_at:
        t_fail = time.perf_counter()
        session.degrade("failstop")
        surv = survivor_mesh(pipe.mesh, cfg.lose_shards, n_data=2)
        restored = mgr.restore_pipeline(pipe)
        pipe.reshard(surv)
        # queries issued while degraded: the p99 a client sees
        degraded_qids = session.submit_embed([int(h) for h in hubs])
        n_before = len(session.answers)
        for j in range(restored, i):
            _advance(session, chunks[j], feats)
            replayed += 1
        t = 0
        while len(session.answers) <= n_before and t < 64:
            _advance(session, np.zeros((0, 2), np.int64), feats)
            t += 1
        first_answer_s = time.perf_counter() - t_fail
        session.restore_normal()
        recovery_s = first_answer_s
    _advance(session, chunk, feats)
    if (i + 1) % cfg.checkpoint_every == 0 and i < fail_at:
        mgr.save_pipeline(i + 1, pipe)
session.flush()

lat = [session.answers[q].latency_s for q in degraded_qids
       if q in session.answers
       and session.answers[q].latency_s is not None]
p99 = float(np.percentile(np.asarray(lat), 99) * 1e3) if lat else float("nan")
st = session.latency_stats()
print(f"RESULT,{{recovery_s:.6f}},{{first_answer_s:.6f}},{{p99:.3f}},"
      f"{{int(pipe.metrics.dropped)}},{{int(pipe.metrics.route_dropped)}},"
      f"{{replayed}},{{st['answered']}}")
"""


def _worker(driver: str, n_events: int, timeout: int = 560):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4 "
                        "--xla_backend_optimization_level=0"}
    r = subprocess.run(
        [sys.executable, "-c",
         _WORKER.format(driver=driver, n_events=n_events)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"recovery worker driver={driver} failed:\n"
                           + r.stderr[-2000:])
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, rec, first, p99, drop, rdrop, rep, ans = line.split(",")
            return {"recovery_s": float(rec), "first_answer_s": float(first),
                    "p99_ms": float(p99), "dropped": int(drop),
                    "route_dropped": int(rdrop), "replayed": int(rep),
                    "answered": int(ans)}
    raise RuntimeError("recovery worker printed no RESULT row")


def run(scale: str = "small"):
    n_events = {"small": 288, "full": 1152}[scale]
    rows = []
    for driver in ("tick", "super"):
        res = _worker(driver, n_events)
        rows.append(fmt_row(
            f"recovery[failstop,{driver},D=4->2]",
            res["recovery_s"] * 1e6,
            f"recovery_s={res['recovery_s']:.3f};"
            f"first_answer_ms={res['first_answer_s'] * 1e3:.1f};"
            f"p99_degraded_ms={res['p99_ms']:.1f};"
            f"dropped={res['dropped']};"
            f"route_dropped={res['route_dropped']};"
            f"replayed={res['replayed']};answered={res['answered']}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
