"""Delta-gated incremental propagation (ISSUE 6 tentpole).

Row family ``delta_gating[eps=<e>]``, one row per gating threshold on the
SAME hub-heavy power-law stream followed by waves of tiny feature
updates (log-uniform delta norms in [1e-6, 1e-3] — the sub-threshold
churn the gate exists for):

  eps=0      — exact mode, the PR 5 baseline (bit-identical program by
               the test_delta_gating golden matrix);
  eps=1e-05  — gates only the tiniest churn (sanity midpoint);
  eps=0.001  — gates most of the update churn (the acceptance point:
               >= 3x update-phase RMI reduction).

Derived fields per row:
  msgs        — total reduce_msgs of the whole run (gated <= exact:
                the CI validator's monotonicity gate);
  upd_msgs    — reduce_msgs of the update phase only (the gated traffic;
                reduction_x is computed on this);
  suppressed  — RMIs the gate withheld (0 at eps=0);
  events_per_s— end-to-end event throughput (gating must not cost time);
  err         — worst-vertex L2 distance of the final sink from the
                static oracle on the final snapshot;
  bound       — the eps-derived Lipschitz chain bound for the 2-layer
                SAGE stack: e1 = ||W1_n||2 eps, bound = ||W2_s||2 e1 +
                ||W2_n||2 (e1 + eps)  (err <= bound is the approximation
                contract; at eps=0 err is plain f32 noise);
  reduction_x — upd_msgs(eps=0) / upd_msgs(eps).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core import windowing as win
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE

from benchmarks.common import D_HID, D_IN, fmt_row

EPS_SWEEP = (0.0, 1e-5, 1e-3)


def _make_stream(rng, n_nodes, n_edges):
    edges = powerlaw_edges(rng, n_nodes, n_edges, 1.1)      # hub-heavy
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(n_nodes)}
    return edges, feats


def _update_waves(rng, feats, n_waves):
    """Waves of per-vertex feature nudges with log-uniform L2 norms in
    [1e-6, 1e-3]: a fixed eps splits the churn into suppressed and
    emitted fractions. Returns (per-wave event lists, final features)."""
    cur = {v: np.asarray(f, np.float32).copy() for v, f in feats.items()}
    waves = []
    for _ in range(n_waves):
        events = []
        for v in sorted(cur):
            d = rng.normal(size=D_IN).astype(np.float32)
            norm = 10.0 ** rng.uniform(-6.0, -3.0)
            d *= norm / max(float(np.linalg.norm(d)), 1e-12)
            cur[v] = cur[v] + d
            events.append((v, cur[v].copy()))
        waves.append(events)
    return waves, cur


def _bound(params, eps: float) -> float:
    s1n = np.linalg.norm(np.asarray(params["l0"]["neigh"]["w"]), 2)
    s2s = np.linalg.norm(np.asarray(params["l1"]["self"]["w"]), 2)
    s2n = np.linalg.norm(np.asarray(params["l1"]["neigh"]["w"]), 2)
    e1 = s1n * eps
    return float(s2s * e1 + s2n * (e1 + eps))


def run(scale: str = "small"):
    n_nodes, n_edges, n_waves = {"small": (200, 1000, 6),
                                 "full": (400, 8000, 12)}[scale]
    rng = np.random.default_rng(0)
    edges, feats = _make_stream(rng, n_nodes, n_edges)
    waves, final_feats = _update_waves(rng, feats, n_waves)
    n_events = len(edges) + n_waves * n_nodes

    model = GraphSAGE((D_IN, D_HID, D_HID))
    params = model.init(jax.random.key(0))
    g, _ = build_snapshot(edges, final_feats, D_IN, n_nodes)
    oracle = np.asarray(oracle_embeddings(model, params, g))

    rows, upd_base = [], None
    for eps in EPS_SWEEP:
        cfg = PipelineConfig(
            n_parts=8, node_cap=max(128, 4 * n_nodes // 8),
            edge_cap=max(256, 4 * n_edges // 8), repl_cap=2 * n_nodes,
            feat_cap=2048, edge_tick_cap=1024, max_nodes=n_nodes,
            window=win.WindowConfig(kind=win.STREAMING), delta_eps=eps)
        pipe = D3Pipeline(model, params, cfg)
        t0 = time.perf_counter()
        pipe.run_stream(edges, feats, tick_edges=64)
        pipe.flush(max_ticks=256)
        build_msgs = pipe.metrics.reduce_msgs
        for events in waves:
            pipe.tick(feats=events)
        pipe.flush(max_ticks=256)
        wall = time.perf_counter() - t0

        m = pipe.metrics
        upd_msgs = m.reduce_msgs - build_msgs
        if upd_base is None:
            upd_base = upd_msgs                     # the eps=0 baseline
        emb = pipe.embeddings()
        err = max(float(np.linalg.norm(emb[v] - oracle[v])) for v in emb)
        rows.append(fmt_row(
            f"delta_gating[eps={eps:g}]", 1e6 * wall / n_events,
            f"msgs={m.reduce_msgs};upd_msgs={upd_msgs};"
            f"suppressed={m.suppressed};"
            f"events_per_s={n_events / wall:.0f};"
            f"err={err:.3e};bound={_bound(params, eps):.3e};"
            f"reduction_x={upd_base / max(upd_msgs, 1):.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
