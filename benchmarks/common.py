"""Shared benchmark scaffolding: synthetic streams, pipeline factory,
timing helpers. Benchmarks mirror the paper's figures at CPU-feasible
scale; the semantics (per-figure metrics) match §6.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE

D_IN = 16
D_HID = 32


@dataclass
class StreamCase:
    edges: np.ndarray
    feats: dict
    n_nodes: int


def make_case(seed=0, n_nodes=400, n_edges=2000, alpha=1.3) -> StreamCase:
    rng = np.random.default_rng(seed)
    edges = powerlaw_edges(rng, n_nodes, n_edges, alpha)
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(n_nodes)}
    return StreamCase(edges=edges, feats=feats, n_nodes=n_nodes)


def make_pipeline(case: StreamCase, n_parts=8, window=None,
                  partitioner="hdrf", base_parallelism=2, explosion=1.0,
                  node_cap=None, edge_cap=None, feat_cap=2048,
                  edge_tick_cap=1024, seed=0, delivery_backend="xla"):
    model = GraphSAGE((D_IN, D_HID, D_HID))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(
        n_parts=n_parts,
        node_cap=node_cap or max(128, 4 * case.n_nodes // n_parts),
        edge_cap=edge_cap or max(256, 4 * len(case.edges) // n_parts),
        repl_cap=max(256, 2 * case.n_nodes),
        feat_cap=feat_cap, edge_tick_cap=edge_tick_cap,
        window=window or win.WindowConfig(kind=win.STREAMING),
        delivery_backend=delivery_backend,
        partitioner=partitioner, base_parallelism=base_parallelism,
        explosion=explosion, max_nodes=case.n_nodes, seed=seed)
    return model, params, D3Pipeline(model, params, cfg)


def run_and_time(pipe, case: StreamCase, tick_edges=128, flush=True):
    t0 = time.perf_counter()
    pipe.run_stream(case.edges, case.feats, tick_edges=tick_edges)
    if flush:
        pipe.flush(max_ticks=512)
    wall = time.perf_counter() - t0
    return wall


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
