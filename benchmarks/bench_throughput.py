"""Paper Fig. 4a: inference throughput scaling — Streaming vs windowed
(Tumbling/Session/Adaptive) across parallelism levels — plus the
super-tick vs per-tick DRIVER comparison (ISSUE 1 tentpole).

Metric: final-layer representations produced per second (the paper's
"rate of producing final layer representations"); for the driver
comparison, stream events ingested per second end-to-end.
"""
from __future__ import annotations

import time

from repro.core import windowing as win

from benchmarks.common import fmt_row, make_case, make_pipeline, run_and_time

POLICIES = {
    "streaming": win.WindowConfig(kind=win.STREAMING),
    "tumbling": win.WindowConfig(kind=win.TUMBLING, interval=4),
    "session": win.WindowConfig(kind=win.SESSION, interval=4),
    "adaptive": win.WindowConfig(kind=win.ADAPTIVE),
}

# fine micro-ticks: the paper's low-latency coalescing regime, where the
# per-tick driver pays its fixed cost (eager topology applies, L jit
# dispatches, stats syncs) every 32 events and the scan amortizes it
SUPER_T = 16
SUPER_TICK_EDGES = 32


def _lean_pipeline(case, window=None):
    return make_pipeline(case, n_parts=8, window=window, node_cap=128,
                         edge_cap=1024, feat_cap=256, edge_tick_cap=128)


def run_driver_comparison(n_edges: int = 4000):
    """events/sec: per-tick reference vs super-tick (T=16) on n_parts=8."""
    case = make_case(n_edges=n_edges)
    warm = case.edges[:640]

    _, _, pipe = _lean_pipeline(case)
    pipe.run_stream(warm, case.feats, tick_edges=SUPER_TICK_EDGES)
    pipe.flush(max_ticks=64)
    _, _, pipe = _lean_pipeline(case)
    t0 = time.perf_counter()
    pipe.run_stream(case.edges, case.feats, tick_edges=SUPER_TICK_EDGES)
    pipe.flush(max_ticks=128)
    per_tick_evs = n_edges / (time.perf_counter() - t0)

    _, _, pipe = _lean_pipeline(case)
    pipe.run_stream_super(warm, case.feats, tick_edges=SUPER_TICK_EDGES,
                          super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=64, T=4)
    _, _, pipe = _lean_pipeline(case)
    t0 = time.perf_counter()
    pipe.run_stream_super(case.edges, case.feats,
                          tick_edges=SUPER_TICK_EDGES, super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=128, T=4)
    super_evs = n_edges / (time.perf_counter() - t0)

    speedup = super_evs / per_tick_evs
    return [
        fmt_row("driver[per_tick]", 1e6 / per_tick_evs,
                f"events_per_s={per_tick_evs:.0f}"),
        fmt_row(f"driver[super_tick,T={SUPER_T}]", 1e6 / super_evs,
                f"events_per_s={super_evs:.0f};speedup={speedup:.2f}x"),
    ]


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges)
    rows = []
    for par in (2, 4, 8):
        for name, policy in POLICIES.items():
            _, _, pipe = make_pipeline(case, n_parts=8, window=policy,
                                       base_parallelism=par)
            wall = run_and_time(pipe, case, tick_edges=128)
            thr = pipe.metrics.emitted_total / wall
            rows.append(fmt_row(
                f"fig4a_throughput[{name},p={par}]",
                1e6 * wall / max(pipe.metrics.emitted_total, 1),
                f"emitted={pipe.metrics.emitted_total};rep_per_s={thr:.0f}"))
    rows.extend(run_driver_comparison())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
