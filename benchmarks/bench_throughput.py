"""Paper Fig. 4a: inference throughput scaling — Streaming vs windowed
(Tumbling/Session/Adaptive) across parallelism levels.

Metric: final-layer representations produced per second (the paper's
"rate of producing final layer representations").
"""
from __future__ import annotations

from repro.core import windowing as win

from benchmarks.common import fmt_row, make_case, make_pipeline, run_and_time

POLICIES = {
    "streaming": win.WindowConfig(kind=win.STREAMING),
    "tumbling": win.WindowConfig(kind=win.TUMBLING, interval=4),
    "session": win.WindowConfig(kind=win.SESSION, interval=4),
    "adaptive": win.WindowConfig(kind=win.ADAPTIVE),
}


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges)
    rows = []
    for par in (2, 4, 8):
        for name, policy in POLICIES.items():
            _, _, pipe = make_pipeline(case, n_parts=8, window=policy,
                                       base_parallelism=par)
            wall = run_and_time(pipe, case, tick_edges=128)
            thr = pipe.metrics.emitted_total / wall
            rows.append(fmt_row(
                f"fig4a_throughput[{name},p={par}]",
                1e6 * wall / max(pipe.metrics.emitted_total, 1),
                f"emitted={pipe.metrics.emitted_total};rep_per_s={thr:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
