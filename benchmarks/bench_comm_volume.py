"""Paper Fig. 4b: communication volume — cross-part message bytes for
streaming vs windowed policies (the paper reports iterative communication
volume of the second GNN layer; we count cross-part RMI + broadcast rows
times row bytes)."""
from __future__ import annotations

from repro.core import windowing as win

from benchmarks.common import D_HID, fmt_row, make_case, make_pipeline, run_and_time

POLICIES = {
    "streaming": win.WindowConfig(kind=win.STREAMING),
    "tumbling": win.WindowConfig(kind=win.TUMBLING, interval=4),
    "session": win.WindowConfig(kind=win.SESSION, interval=4),
    "adaptive": win.WindowConfig(kind=win.ADAPTIVE),
}


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges, alpha=1.1)   # hub-heavy
    rows = []
    base = None
    for name, policy in POLICIES.items():
        _, _, pipe = make_pipeline(case, n_parts=8, window=policy)
        wall = run_and_time(pipe, case, tick_edges=64)
        vol_mb = pipe.metrics.cross_part_msgs * 4 * D_HID / 2**20
        if base is None:
            base = vol_mb
        rows.append(fmt_row(
            f"fig4b_comm_volume[{name}]", 1e6 * wall,
            f"cross_msgs={pipe.metrics.cross_part_msgs};"
            f"mb={vol_mb:.2f};reduction_x={base / max(vol_mb, 1e-9):.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
