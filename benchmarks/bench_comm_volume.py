"""Paper Fig. 4b: communication volume.

Two row families:

  fig4b_comm_volume[<policy>]      — cross-part message ROWS per window
      policy (streaming/tumbling/session/adaptive) on the hub-heavy
      stream, in-process: the paper's iterative-communication-volume
      comparison (windowing coalesces messages).

  fig4b_comm_volume[wire,<mode>]   — MEASURED all_to_all wire bytes of
      the routing plane on a real (forced) 4-device CPU mesh, read from
      the new TickStats/StreamMetrics wire counters (ISSUE 5) instead of
      being inferred from message counts:
        dense  : route_cap=None — worst-case D x C buckets (the
                 pre-ISSUE-5 sizing);
        capped : route_cap = C_rmi // D — traffic-adaptive buckets; same
                 stream, same convergence (golden-equivalent by test),
                 a fraction of the wire. `reduction_x` is the measured
                 dense/capped byte ratio (the acceptance bar is >= 2x),
                 `events_per_s` guards against the capped exchange
                 costing throughput.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

from repro.core import windowing as win

from benchmarks.common import D_HID, fmt_row, make_case, make_pipeline, run_and_time

REPO = Path(__file__).resolve().parents[1]

POLICIES = {
    "streaming": win.WindowConfig(kind=win.STREAMING),
    "tumbling": win.WindowConfig(kind=win.TUMBLING, interval=4),
    "session": win.WindowConfig(kind=win.SESSION, interval=4),
    "adaptive": win.WindowConfig(kind=win.ADAPTIVE),
}

_WIRE_WORKER = """
import time
import numpy as np
import jax
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

D = 4
N_EDGES = {n_edges}
rng = np.random.default_rng(0)
n_nodes = 200
edges = powerlaw_edges(rng, n_nodes, N_EDGES, 1.1)      # hub-heavy
feats = {{v: rng.normal(size=16).astype(np.float32) for v in range(n_nodes)}}

N_PARTS, EDGE_CAP, EDGE_TICK_CAP = 8, 1024, 64
C_RMI = EDGE_TICK_CAP + (N_PARTS // D) * EDGE_CAP       # local RMI lane

def run(route_cap):
    model = GraphSAGE((16, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=N_PARTS, node_cap=256, edge_cap=EDGE_CAP,
                         repl_cap=512, feat_cap=512,
                         edge_tick_cap=EDGE_TICK_CAP, max_nodes=n_nodes,
                         route_cap=route_cap,
                         window=win.WindowConfig(kind=win.STREAMING))
    pipe = D3Pipeline(model, params, cfg, mesh=make_stream_mesh(D))
    t0 = time.perf_counter()
    pipe.run_stream_super(edges, feats, tick_edges=64, super_ticks=8)
    pipe.flush_super(max_ticks=128, T=8)
    wall = time.perf_counter() - t0
    m = pipe.metrics
    print(f"RESULT,{{'dense' if route_cap is None else 'capped'}},"
          f"{{m.wire_bytes}},{{m.wire_rows}},{{m.route_deferred}},"
          f"{{m.route_dropped}},{{N_EDGES / wall:.1f}}")

run(None)
run(C_RMI // D)
"""


def _wire_rows(n_edges: int, timeout: int = 560):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r = subprocess.run(
        [sys.executable, "-c", _WIRE_WORKER.format(n_edges=n_edges)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError("comm-volume wire worker failed:\n"
                           + r.stderr[-2000:])
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, mode, by, rows, defer, drop, evs = line.split(",")
            out[mode] = (int(by), int(rows), int(defer), int(drop),
                         float(evs))
    return out


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges, alpha=1.1)   # hub-heavy
    rows = []
    base = None
    for name, policy in POLICIES.items():
        _, _, pipe = make_pipeline(case, n_parts=8, window=policy)
        wall = run_and_time(pipe, case, tick_edges=64)
        vol_mb = pipe.metrics.cross_part_msgs * 4 * D_HID / 2**20
        if base is None:
            base = vol_mb
        rows.append(fmt_row(
            f"fig4b_comm_volume[{name}]", 1e6 * wall,
            f"cross_msgs={pipe.metrics.cross_part_msgs};"
            f"mb={vol_mb:.2f};reduction_x={base / max(vol_mb, 1e-9):.2f}"))

    # measured wire bytes, dense vs capped, D=4 hub-heavy (subprocess:
    # the host-platform device count is fixed at backend init)
    wire = _wire_rows({"small": 1200, "full": 8000}[scale])
    d_by, d_rows, _, _, d_evs = wire["dense"]
    c_by, c_rows, c_def, c_drop, c_evs = wire["capped"]
    rows.append(fmt_row(
        "fig4b_comm_volume[wire,dense]", 1e6 / max(d_evs, 1e-9),
        f"wire_mb={d_by / 2**20:.2f};wire_rows={d_rows};"
        f"events_per_s={d_evs:.0f}"))
    rows.append(fmt_row(
        "fig4b_comm_volume[wire,capped]", 1e6 / max(c_evs, 1e-9),
        f"wire_mb={c_by / 2**20:.2f};wire_rows={c_rows};"
        f"events_per_s={c_evs:.0f};deferred={c_def};dropped={c_drop};"
        f"reduction_x={d_by / max(c_by, 1):.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
