"""Device-count scaling of the streaming engine: LocalRouter vs MeshRouter.

Metric: stream events ingested per second end-to-end (super-tick driver),
at 1/2/4 devices. Each device count runs in a SUBPROCESS because the XLA
host-platform device count is fixed at backend initialization
(--xla_force_host_platform_device_count must be set before first jax use).

On one CPU the mesh rows measure the routing plane's overhead (all_to_all
+ bucketing vs flat scatter) rather than real speedup — the point of the
row pair is tracking that overhead and exercising the sharded path in the
benchmark harness; on a real multi-chip mesh the same harness reports
scaling.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

from benchmarks.common import fmt_row

REPO = Path(__file__).resolve().parents[1]

_WORKER = """
import time
import numpy as np
import jax
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

D = {n_devices}
N_EDGES = {n_edges}
TICK_EDGES, SUPER_T = 64, 8

rng = np.random.default_rng(0)
n_nodes = 200
edges = powerlaw_edges(rng, n_nodes, N_EDGES, 1.3)
feats = {{v: rng.normal(size=16).astype(np.float32) for v in range(n_nodes)}}

def build(mesh=None, route_cap=None, telemetry=False):
    model = GraphSAGE((16, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=256, edge_cap=2048,
                         repl_cap=512, feat_cap=512, edge_tick_cap=64,
                         max_nodes=n_nodes, route_cap=route_cap,
                         telemetry=telemetry,
                         window=win.WindowConfig(kind=win.STREAMING))
    return D3Pipeline(model, params, cfg, mesh=mesh)

def timed(mesh=None, route_cap=None, telemetry=False):
    pipe = build(mesh, route_cap, telemetry)  # warm-up: compile the scan
    pipe.run_stream_super(edges[:512], feats, tick_edges=TICK_EDGES,
                          super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=64, T=SUPER_T)
    pipe = build(mesh, route_cap, telemetry)
    t0 = time.perf_counter()
    pipe.run_stream_super(edges, feats, tick_edges=TICK_EDGES,
                          super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=128, T=SUPER_T)
    return N_EDGES / (time.perf_counter() - t0)

if D == 1:
    print(f"RESULT,local,{{timed(None):.1f}}")
    # telemetry-plane overhead (ISSUE 9): same stream with the trace
    # recorder + occupancy gauges live — the acceptance gate is <= 5%
    print(f"RESULT,telemetry,{{timed(None, telemetry=True):.1f}}")
print(f"RESULT,mesh,{{timed(make_stream_mesh(D)):.1f}}")
if D == 4:
    # traffic-adaptive exchange: route_cap = C_rmi // D (ISSUE 5) — the
    # dense row above is the baseline it must not regress against
    c_rmi = 64 + (8 // D) * 2048
    print(f"RESULT,capped,{{timed(make_stream_mesh(D), c_rmi // D):.1f}}")
"""


_PIPE_WORKER = """
import os
import time
import numpy as np
import jax
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

D = {n_devices}
STAGE = {stage}
N_EDGES = {n_edges}
TICK_EDGES, SUPER_T = 64, 8

rng = np.random.default_rng(0)
n_nodes = 200
edges = powerlaw_edges(rng, n_nodes, N_EDGES, 1.3)       # hub-heavy
feats = {{v: rng.normal(size=32).astype(np.float32) for v in range(n_nodes)}}

def build():
    # stage-uniform stack (in_dim == out_dim == 32), required by the
    # layer-pipelined engine; the stage=1 baseline uses the SAME model so
    # vs_1d isolates the mesh shape
    model = GraphSAGE((32, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=256, edge_cap=2048,
                         repl_cap=512, feat_cap=512, edge_tick_cap=64,
                         max_nodes=n_nodes, n_stages=STAGE,
                         window=win.WindowConfig(kind=win.STREAMING))
    return D3Pipeline(model, params, cfg,
                      mesh=make_stream_mesh(D, stage=STAGE))

pipe = build()                               # warm-up: compile the scan
pipe.run_stream_super(edges[:512], feats, tick_edges=TICK_EDGES,
                      super_ticks=SUPER_T)
pipe.flush_super(max_ticks=64, T=SUPER_T)
pipe = build()
t0 = time.perf_counter()
pipe.run_stream_super(edges, feats, tick_edges=TICK_EDGES,
                      super_ticks=SUPER_T)
pipe.flush_super(max_ticks=128, T=SUPER_T)
evs = N_EDGES / (time.perf_counter() - t0)
m = pipe.metrics
print(f"RESULT,pipeline,{{evs:.1f}},{{pipe.bubble_fraction():.4f}},"
      f"{{m.dropped + m.route_dropped}},{{os.cpu_count()}}")
"""


def _worker(n_devices: int, n_edges: int, timeout: int = 560):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}"}
    r = subprocess.run(
        [sys.executable, "-c",
         _WORKER.format(n_devices=n_devices, n_edges=n_edges)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"scaling worker D={n_devices} failed:\n"
                           + r.stderr[-2000:])
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, name, evs = line.split(",")
            out[name] = float(evs)
    return out


def _pipe_worker(n_devices: int, stage: int, n_edges: int,
                 timeout: int = 560):
    """Hybrid-pipeline scaling point (ISSUE 7): stage x data grid in a
    forced-device subprocess. Returns events/s, measured bubble fraction,
    dropped events and the host's real core count (the speedup target
    only binds on >= 8 real cores; 1-core CI numbers carry `cores` so
    they are never mistaken for the paper's)."""
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}"}
    r = subprocess.run(
        [sys.executable, "-c",
         _PIPE_WORKER.format(n_devices=n_devices, stage=stage,
                             n_edges=n_edges)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(
            f"pipeline worker D={n_devices},stage={stage} failed:\n"
            + r.stderr[-2000:])
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,pipeline,"):
            _, _, evs, bubble, dropped, cores = line.split(",")
            return {"evs": float(evs), "bubble": float(bubble),
                    "dropped": int(dropped), "cores": int(cores)}
    raise RuntimeError(f"pipeline worker D={n_devices} printed no RESULT")


def run(scale: str = "small"):
    n_edges = {"small": 1200, "full": 8000}[scale]
    rows = []
    base = None
    for d in (1, 2, 4):
        res = _worker(d, n_edges)
        if "local" in res:
            base = res["local"]
            rows.append(fmt_row("scaling[local,D=1]", 1e6 / base,
                                f"events_per_s={base:.0f}"))
        if "telemetry" in res:
            tel = res["telemetry"]
            rows.append(fmt_row(
                "scaling[local,D=1,telemetry]", 1e6 / tel,
                f"events_per_s={tel:.0f};vs_off={tel / base:.3f}x"))
        rel = res["mesh"] / base if base else float("nan")
        rows.append(fmt_row(f"scaling[mesh,D={d}]", 1e6 / res["mesh"],
                            f"events_per_s={res['mesh']:.0f};"
                            f"vs_local={rel:.2f}x"))
        if "capped" in res:
            rows.append(fmt_row(
                f"scaling[mesh,D={d},capped]", 1e6 / res["capped"],
                f"events_per_s={res['capped']:.0f};"
                f"vs_dense={res['capped'] / res['mesh']:.2f}x"))
    # hybrid-parallel pipeline pair (ISSUE 7): the 1-D D=4 baseline
    # re-measured on the stage-uniform model, then the 2x4 grid — vs_1d is
    # the tentpole's headline number on a real multi-core host
    p4 = _pipe_worker(4, 1, n_edges)
    rows.append(fmt_row(
        "scaling[pipeline,data=4]", 1e6 / p4["evs"],
        f"events_per_s={p4['evs']:.0f};dropped={p4['dropped']};"
        f"cores={p4['cores']}"))
    p8 = _pipe_worker(8, 2, n_edges)
    rows.append(fmt_row(
        "scaling[pipeline,stage=2,data=4]", 1e6 / p8["evs"],
        f"events_per_s={p8['evs']:.0f};vs_1d={p8['evs'] / p4['evs']:.2f}x;"
        f"bubble_frac={p8['bubble']:.4f};dropped={p8['dropped']};"
        f"cores={p8['cores']}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
