"""Paper Fig. 4c: running time for a bounded stream (runtime measured to
termination detection), streaming vs windowed, across parallelism."""
from __future__ import annotations

from repro.core import windowing as win

from benchmarks.common import fmt_row, make_case, make_pipeline, run_and_time


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges)
    rows = []
    for name, policy in (("streaming", win.WindowConfig(kind=win.STREAMING)),
                         ("session", win.WindowConfig(kind=win.SESSION,
                                                      interval=4))):
        for par in (2, 4, 8):
            _, _, pipe = make_pipeline(case, n_parts=8, window=policy,
                                       base_parallelism=par)
            wall = run_and_time(pipe, case, tick_edges=128)
            rows.append(fmt_row(f"fig4c_runtime[{name},p={par}]",
                                1e6 * wall,
                                f"ticks={pipe.metrics.ticks};"
                                f"runtime_s={wall:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
