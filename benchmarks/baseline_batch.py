"""Batch-recompute baseline (the paper's DGL emulation, §6).

For every batch of edge updates it (1) walks the L-hop OUT-neighborhood of
the touched vertices to find influenced nodes, (2) pulls each influenced
node's L-hop IN-neighborhood (the local computation graph), (3) recomputes
embeddings on that subgraph from scratch. This is the pull-based
"sampling-process" execution the paper benchmarks DGL with (sampling
fanout = full neighborhood => exact, like D3-GNN).

The interesting output is the WORK metric: messages (gathered edges)
recomputed per update batch — the quantity D3-GNN's incremental
aggregators avoid. Wall time on CPU correlates, but message counts are the
hardware-independent comparison (paper Fig. 5).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.graphs import Graph


@dataclass
class BatchRecomputeBaseline:
    model: object                     # GraphSAGE-compatible stack
    params: object
    n_nodes: int
    d_in: int
    n_layers: int = 2
    # dynamic adjacency (grow-only, matching the streams we benchmark)
    out_adj: list = field(default_factory=list)
    in_adj: list = field(default_factory=list)
    feats: np.ndarray = None
    has_feat: np.ndarray = None
    embeddings: dict = field(default_factory=dict)
    messages_recomputed: int = 0
    wall_seconds: float = 0.0

    def __post_init__(self):
        self.out_adj = [[] for _ in range(self.n_nodes)]
        self.in_adj = [[] for _ in range(self.n_nodes)]
        self.feats = np.zeros((self.n_nodes, self.d_in), np.float32)
        self.has_feat = np.zeros(self.n_nodes, bool)

    def set_features(self, feats: dict):
        for v, x in feats.items():
            self.feats[v] = x
            self.has_feat[v] = True

    def apply_batch(self, edges: np.ndarray):
        """Ingest a batch of edges, then recompute all influenced nodes."""
        t0 = time.perf_counter()
        touched = set()
        for u, v in edges:
            self.out_adj[u].append(v)
            self.in_adj[v].append(u)
            touched.add(int(u))
            touched.add(int(v))
        influenced = self._influenced(touched)
        self._recompute(influenced)
        self.wall_seconds += time.perf_counter() - t0

    def _influenced(self, touched):
        """L-hop out-neighborhood cascade (paper's |I| set)."""
        frontier = set(touched)
        influenced = set(touched)
        for _ in range(self.n_layers - 1):
            nxt = set()
            for u in frontier:
                nxt.update(self.out_adj[u])
            influenced |= nxt
            frontier = nxt
        return influenced

    def _recompute(self, influenced):
        """Pull each influenced node's L-hop in-neighborhood and run the
        static model on the union subgraph (vectorized recompute)."""
        nodes = set(influenced)
        frontier = set(influenced)
        for _ in range(self.n_layers):
            nxt = set()
            for v in frontier:
                nxt.update(self.in_adj[v])
            nodes |= nxt
            frontier = nxt
        nodes = sorted(nodes)
        if not nodes:
            return
        local = {v: i for i, v in enumerate(nodes)}
        senders, receivers = [], []
        for v in nodes:
            for u in self.in_adj[v]:
                if u in local and self.has_feat[u]:
                    senders.append(local[u])
                    receivers.append(local[v])
        E = len(senders)
        self.messages_recomputed += E * self.n_layers
        g = Graph(senders=jnp.asarray(senders or [0], jnp.int32),
                  receivers=jnp.asarray(receivers or [0], jnp.int32),
                  x=jnp.asarray(self.feats[nodes]),
                  edge_mask=jnp.asarray(np.ones(max(E, 1), bool)
                                        if E else np.zeros(1, bool)))
        x = g.x
        for i, layer in enumerate(self.model.layers):
            x = layer(self.params[f"l{i}"], g, x)
        x = np.asarray(x)
        for v in influenced:
            if v in local and self.has_feat[v]:
                self.embeddings[v] = x[local[v]]
