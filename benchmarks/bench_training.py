"""Training-plane benchmark: the concept-drift scenario under three
training postures over the SAME drifting labeled stream (paper §4.3 +
the ISSUE 8 online plane):

  training[inference_only] — stream + flush, no labels: the events/s
      ceiling the training planes are measured against;
  training[online]         — TrainSession over the super-tick driver:
      labels ride the update launches, the windowed fire-masked step
      runs on device, the stream never halts;
  training[halt_flush]     — TrainingCoordinator: the paper's §4.3.1
      halt/flush/train/rebuild cycle between phases.

derived: events_per_s (edge events over TOTAL wall incl. training),
loss_init/loss_final (first vs last fired/epoch loss across the drift
phases), grad_norm, steps, wire_mb (modeled exchange volume).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.optim import sgd

from benchmarks.common import D_IN, D_HID, fmt_row

N_CLS = 5


def _drift_case(scale: str):
    n_nodes = {"small": 300, "full": 800}[scale]
    n_phase = {"small": 600, "full": 4000}[scale]
    phases = {"small": 2, "full": 3}[scale]
    rng = np.random.default_rng(0)
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(n_nodes)}
    w_true = rng.normal(size=(D_IN, N_CLS))
    edges, labels = [], []
    for ph in range(phases):
        edges.append(powerlaw_edges(rng, n_nodes, n_phase))
        drift = rng.normal(size=(D_IN, N_CLS)) * 0.3 * ph
        logits = np.stack([feats[v] for v in range(n_nodes)]) \
            @ (w_true + drift)
        labels.append({v: int(np.argmax(logits[v])) for v in range(n_nodes)})
    return n_nodes, feats, edges, labels


def _build(n_nodes, n_edges, train=None, train_cap=0):
    model = GraphSAGE((D_IN, D_HID, D_HID),
                      n_classes=(N_CLS if train is not None else 0))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(
        n_parts=8, node_cap=max(128, 4 * n_nodes // 8),
        edge_cap=max(256, 4 * n_edges // 8), repl_cap=max(256, 2 * n_nodes),
        feat_cap=2048, edge_tick_cap=1024, max_nodes=n_nodes,
        window=win.WindowConfig(kind=win.STREAMING), train_cap=train_cap)
    return model, params, D3Pipeline(model, params, cfg, train=train)


def _row(name, wall, n_events, loss_init, loss_final, grad_norm, steps,
         wire_mb):
    return fmt_row(
        name, 1e6 * wall,
        f"events_per_s={n_events / wall:.0f};loss_init={loss_init:.4f};"
        f"loss_final={loss_final:.4f};grad_norm={grad_norm:.4f};"
        f"steps={steps};wire_mb={wire_mb:.3f}")


def run(scale: str = "small"):
    n_nodes, feats, edge_phases, label_phases = _drift_case(scale)
    n_events = sum(len(e) for e in edge_phases)
    n_total = sum(len(e) for e in edge_phases)
    rows = []

    # ---- inference-only ceiling (same warm T=8 launch shape as online)
    _, _, pipe = _build(n_nodes, n_total)
    pipe.run_super_tick(T=8)
    t0 = time.perf_counter()
    for edges in edge_phases:
        e_chunks, f_chunks = pipe.chunk_stream(edges, feats, 128)
        for i in range(0, len(e_chunks), 8):
            pipe.run_super_tick(e_chunks[i:i + 8], f_chunks[i:i + 8], T=8)
    pipe.flush_super(max_ticks=512, T=8)
    wall_inf = time.perf_counter() - t0
    rows.append(_row("training[inference_only]", wall_inf, n_events,
                     0.0, 0.0, 0.0, 0, pipe.metrics.wire_bytes / 1e6))

    # ---- online plane: labels ride the stream, no halt
    from repro.serve import TrainSession
    tcfg = TrainConfig(optimizer=sgd(), lr=0.05, batch_threshold=8)
    _, _, pipe = _build(n_nodes, n_total, train=tcfg,
                        train_cap=max(64, n_nodes // 2))
    sess = TrainSession(pipe, driver="super", super_ticks=8)
    # warm the two scan shapes (T=1 probe + T=8 cruise) outside the
    # timed region: empty launches, nothing fires, nothing admits
    pipe.run_super_tick(T=1)
    pipe.run_super_tick(T=8)
    loss_init, t0 = None, time.perf_counter()
    for edges, labels in zip(edge_phases, label_phases):
        e_chunks, f_chunks = pipe.chunk_stream(edges, feats, 128)
        sess.observe_labels(labels)
        if loss_init is None:
            # one-tick launch, then read the first fired loss: the
            # untrained starting point of the trajectory
            sess.advance_super(e_chunks[:1], f_chunks[:1], T=1)
            loss_init = sess.train_stats()["loss"]
            e_chunks, f_chunks = e_chunks[1:], f_chunks[1:]
        # labels ride the update launches; steps fire mid-stream (the
        # moving stream re-dirties the window every tick) — no halt.
        # Fixed T=8 launches (shorter tails padded) keep one compiled
        # program across phases AND the final flush.
        for i in range(0, len(e_chunks), 8):
            sess.advance_super(e_chunks[i:i + 8], f_chunks[i:i + 8], T=8)
    sess.flush()
    wall = time.perf_counter() - t0
    st = sess.train_stats()
    rows.append(_row("training[online]", wall, n_events, loss_init,
                     st["loss"], st["grad_norm"], st["steps"],
                     pipe.metrics.wire_bytes / 1e6))

    # ---- halt-flush coordinator cycle per phase
    model, params, pipe = _build(n_nodes, n_total)
    head_model = GraphSAGE((D_IN, D_HID, D_HID), n_classes=N_CLS)
    head_params = head_model.init(jax.random.key(1))["head"]
    coord = TrainingCoordinator(
        pipe, head_model.head, head_params,
        TrainConfig(optimizer=sgd(), lr=0.05, batch_threshold=4, epochs=3))
    loss_init, loss_final, steps, t0 = None, 0.0, 0, time.perf_counter()
    for edges, labels in zip(edge_phases, label_phases):
        pipe.run_stream(edges, feats, tick_edges=128)
        coord.labels.clear()
        coord.observe_labels(labels)
        res = coord.train()
        if loss_init is None:
            loss_init = res.losses[0]
        loss_final = res.losses[-1]
        steps += len(res.losses)
    wall = time.perf_counter() - t0
    gn = float(np.sqrt(sum(
        float((np.asarray(l, np.float32) ** 2).sum())
        for l in jax.tree.leaves(
            coord._full_batch_grads(*coord._device_labels())[1:]))))
    rows.append(_row("training[halt_flush]", wall, n_events, loss_init,
                     loss_final, gn, steps,
                     pipe.metrics.wire_bytes / 1e6))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
