"""Paper Fig. 5d: training scalability — the halt/flush/train/rebuild cycle
(stale-free training) vs a from-scratch full-graph retrain baseline.

Metric: wall time of one coordinator cycle and the work saved by reusing
cached aggregators (the rebuild touches each edge ONCE per layer vs the
baseline's full recompute + re-materialization of intermediate state)."""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.training import TrainingCoordinator
from repro.nn.layers import Linear
from repro.optim import sgd

from benchmarks.common import D_HID, fmt_row, make_case, make_pipeline, run_and_time


def run(scale: str = "small"):
    n_edges = {"small": 1200, "full": 10000}[scale]
    case = make_case(n_edges=n_edges, n_nodes=300)
    rng = np.random.default_rng(0)
    labels = {v: int(rng.integers(0, 5)) for v in range(case.n_nodes)}
    rows = []
    _, _, pipe = make_pipeline(case, n_parts=8,
                               window=win.WindowConfig(kind=win.STREAMING))
    run_and_time(pipe, case, tick_edges=128)
    head = Linear(D_HID, 5)
    coord = TrainingCoordinator(pipe, head, head.init(jax.random.key(1)),
                                sgd(), lr=0.05, batch_threshold=4)
    coord.observe_labels(labels)
    t0 = time.perf_counter()
    res = coord.train(epochs=3)
    wall = time.perf_counter() - t0
    rows.append(fmt_row(
        "fig5d_training[coordinator_cycle]", 1e6 * wall,
        f"epochs=3;votes={res.votes};flush_ticks={res.flush_ticks};"
        f"loss0={res.losses[0]:.3f};lossN={res.losses[-1]:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
