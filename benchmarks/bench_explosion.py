"""Paper Fig. 6: effect of the explosion factor lambda on runtime/load.

Lambda scales per-layer parallelism p_i = p * lambda^(i-1); the observable
here is the per-layer imbalance and modeled per-operator load when deeper
layers get more sub-operators (the engine records per-logical-part busy
time; Alg. 5 maps it onto each layer's physical operators)."""
from __future__ import annotations

import numpy as np

from repro.core import windowing as win
from repro.core.explosion import imbalance_factor

from benchmarks.common import fmt_row, make_case, make_pipeline, run_and_time


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges, alpha=1.2)
    rows = []
    for lam in (1.0, 2.0, 3.0, 7.0):
        _, _, pipe = make_pipeline(case, n_parts=16, base_parallelism=2,
                                   explosion=lam,
                                   window=win.WindowConfig(kind=win.STREAMING))
        wall = run_and_time(pipe, case, tick_edges=128)
        per_layer = pipe.physical_busy_per_layer()
        # modeled makespan: slowest physical operator per layer, summed
        makespan = sum(float(b.max()) for b in per_layer)
        rows.append(fmt_row(
            f"fig6_explosion[lambda={lam}]", 1e6 * wall,
            f"modeled_makespan={makespan:.0f};"
            f"imb_last={imbalance_factor(per_layer[-1]):.2f};"
            f"ops_per_layer={[len(b) for b in per_layer]}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
