"""Paper Fig. 7: event-to-representation latency under a throttled ingest
rate. Latency of an edge event = ticks between its ingestion and the tick
its influenced final-layer representations were emitted, converted to
seconds via the measured tick duration (the paper throttles to 10k edges/s
and reports mean/max/min/std)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import windowing as win

from benchmarks.common import fmt_row, make_case, make_pipeline

POLICIES = {
    "streaming": win.WindowConfig(kind=win.STREAMING),
    "session": win.WindowConfig(kind=win.SESSION, interval=3),
    "adaptive": win.WindowConfig(kind=win.ADAPTIVE),
}


def run(scale: str = "small"):
    n_edges = {"small": 800, "full": 8000}[scale]
    case = make_case(n_edges=n_edges, n_nodes=200)
    rows = []
    for name, policy in POLICIES.items():
        _, _, pipe = make_pipeline(case, n_parts=8, window=policy)
        tick_edges = 32
        lat_ticks = []
        t0 = time.perf_counter()
        for lo in range(0, len(case.edges), tick_edges):
            chunk = case.edges[lo: lo + tick_edges]
            f_events = [(int(v), case.feats[int(v)])
                        for v in np.unique(chunk)
                        if not pipe.states[0].has_feat.any() or True]
            # features for unseen vertices only (host-side gate)
            f_events = [(v, x) for v, x in f_events
                        if pipe.part.t.master[v] < 0]
            start = pipe.now
            pipe.tick(chunk, f_events)
            # drain until this tick's cascade emits (bounded wait)
            waited = 0
            while int(pipe.metrics.dropped) >= 0 and waited < 16:
                from repro.core.tick import has_work
                if not any(bool(has_work(ls)) for ls in pipe.states):
                    break
                pipe.tick()
                waited += 1
            lat_ticks.append(pipe.now - start)
        wall = time.perf_counter() - t0
        s_per_tick = wall / max(pipe.metrics.ticks, 1)
        lat_s = np.asarray(lat_ticks) * s_per_tick
        rows.append(fmt_row(
            f"fig7_latency[{name}]", 1e6 * float(lat_s.mean()),
            f"mean_ms={1e3 * lat_s.mean():.2f};max_ms={1e3 * lat_s.max():.2f};"
            f"std_ms={1e3 * lat_s.std():.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
