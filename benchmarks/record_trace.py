"""CI telemetry lane (ISSUE 9): record a short hub-heavy trace, fit the
cost model, run the capacity advisor, and REPLAY its recommendation.

Everything runs in one forced-4-device subprocess (the XLA host-platform
device count is fixed at backend init, same pattern as bench_scaling):

  1. stream a hub-heavy power-law graph through the super-tick driver
     with the telemetry plane on and a DENSE exchange (route_cap=None —
     peaks recorded under a capped config reflect that config's deferral
     dynamics, see telemetry/advisor.py), saving TRACE.npz;
  2. fit `telemetry/cost_model.py` on the trace and gate its accuracy:
     predicted per-tick cost within 25% of measured on >= 80% of rows;
  3. run `telemetry/advisor.py` -> RECS.json (caps already validated
     against PipelineConfig.validate() by the advisor itself);
  4. replay the SAME stream under the recommended caps and assert the
     acceptance bar: dropped == 0, route_dropped == 0, wire bytes <=
     the dense config, and a bit-identical sink.

CLI:  PYTHONPATH=src:. python benchmarks/record_trace.py \
          --trace TRACE.npz --recs RECS.json
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_WORKER = """
import json
import numpy as np
import jax
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh
from repro.telemetry import (apply_recommendation, fit_cost_model,
                             load_trace, recommend, replay_ok)

D = {n_devices}
N_EDGES = {n_edges}
TICK_EDGES, SUPER_T = 32, 8
TRACE, RECS = {trace!r}, {recs!r}

rng = np.random.default_rng(0)
n_nodes = 160
edges = powerlaw_edges(rng, n_nodes, N_EDGES, 1.3)       # hub-heavy
feats = {{v: rng.normal(size=16).astype(np.float32)
          for v in range(n_nodes)}}
mesh = make_stream_mesh(D)

def build(cfg=None, telemetry=False):
    model = GraphSAGE((16, 24, 24))
    params = model.init(jax.random.key(0))
    cfg = cfg or PipelineConfig(
        n_parts=8, node_cap=128, edge_cap=1024, repl_cap=512,
        feat_cap=512, edge_tick_cap=TICK_EDGES, max_nodes=n_nodes,
        telemetry=telemetry,
        window=win.WindowConfig(kind=win.STREAMING))
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)

def drive(pipe):
    pipe.run_stream_super(edges, feats, tick_edges=TICK_EDGES,
                          super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=64, T=SUPER_T)

# 1. record the dense observability trace
model, params, dense = build(telemetry=True)
drive(dense)
dense.save_trace(TRACE)
trace = load_trace(TRACE)

# 2. cost model accuracy gate (acceptance: 25% on >= 80% of rows)
cm = fit_cost_model(trace)
rep = cm.report(trace, tol=0.25)
assert rep["n"] > 0, "cost model had no rows to score"
assert rep["hit_frac"] >= 0.8, \
    f"cost model off by >25% on too many rows: {{rep}}"

# 3. advisor (bounds-checked inside recommend())
recs = recommend(trace)
with open(RECS, "w") as f:
    json.dump(recs, f, indent=2)

# 4. replay the recommendation through the real pipeline
cfg2 = apply_recommendation(
    PipelineConfig(n_parts=8, node_cap=128, edge_cap=1024, repl_cap=512,
                   max_nodes=n_nodes), recs)
_, _, pipe2 = build(cfg=cfg2)
drive(pipe2)
out = replay_ok(pipe2)                    # raises on any drop
assert pipe2._wire_bytes_per_tick <= dense._wire_bytes_per_tick, \
    "recommended caps cost MORE wire than dense"
np.testing.assert_array_equal(np.asarray(pipe2.sink),
                              np.asarray(dense.sink))
print("RESULT,record_trace,"
      f"{{len(trace)}},{{rep['hit_frac']:.3f}},{{rep['mae_frac']:.3f}},"
      f"{{recs['caps']['route_cap']}},{{out['wire_bytes']}},"
      f"{{dense.metrics.wire_bytes}}")
"""


def run(trace: str, recs: str, n_devices: int = 4, n_edges: int = 960,
        timeout: int = 560) -> dict:
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}"}
    r = subprocess.run(
        [sys.executable, "-c",
         _WORKER.format(n_devices=n_devices, n_edges=n_edges,
                        trace=str(trace), recs=str(recs))],
        env=env, capture_output=True, text=True, timeout=timeout)
    sys.stderr.write(r.stderr[-2000:])
    if r.returncode != 0:
        raise RuntimeError("record_trace worker failed:\n" + r.stderr[-3000:])
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,record_trace,"):
            (_, _, ticks, hit, mae, route_cap, wire_rec,
             wire_dense) = line.split(",")
            return {"ticks": int(ticks), "hit_frac": float(hit),
                    "mae_frac": float(mae),
                    "route_cap": None if route_cap == "None"
                    else int(route_cap),
                    "wire_bytes_recommended": int(wire_rec),
                    "wire_bytes_dense": int(wire_dense)}
    raise RuntimeError("record_trace worker printed no RESULT:\n"
                       + r.stdout[-2000:])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="TRACE.npz")
    ap.add_argument("--recs", default="RECS.json")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--edges", type=int, default=960)
    args = ap.parse_args()
    out = run(args.trace, args.recs, args.devices, args.edges)
    with open(args.recs) as f:
        recs = json.load(f)
    print(json.dumps({"summary": out, "caps": recs["caps"]}, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
