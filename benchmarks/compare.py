"""Perf-regression gate over BENCH.json snapshots (ISSUE 7).

CI's bench lane best-effort-downloads the previous commit's
``bench-<sha>`` artifact and runs ``run.py --compare BASELINE.json``:
any row present in BOTH snapshots whose measured ``events_per_s`` fell
more than ``REGRESSION_FRAC`` below the baseline fails the lane. Rows
that appear or disappear between commits never fail (benchmarks
evolve), rows without an ``events_per_s`` derived column are ignored
(latency/volume rows have their own validator gates), and a missing
baseline file is a no-op — the first run after this lands, expired
artifacts, or a fork without artifact access must not turn red.
"""
from __future__ import annotations

import json
import os

REGRESSION_FRAC = 0.2


def compare_rows(rows: list, baseline_rows: list,
                 threshold: float = REGRESSION_FRAC) -> list:
    """Regression messages for every row name present in both snapshots
    whose events_per_s dropped by more than `threshold` (fraction)."""
    base = {r["name"]: r.get("derived", {}).get("events_per_s")
            for r in baseline_rows}
    msgs = []
    for r in rows:
        cur = r.get("derived", {}).get("events_per_s")
        ref = base.get(r["name"])
        if not cur or not ref:
            continue
        if cur < ref * (1.0 - threshold):
            msgs.append(
                f"{r['name']}: events_per_s {cur:.0f} is "
                f"{1.0 - cur / ref:.0%} below baseline {ref:.0f} "
                f"(allowed {threshold:.0%})")
    return msgs


def compare_to_baseline(rows: list, baseline_path: str,
                        threshold: float = REGRESSION_FRAC):
    """None if the baseline file is absent (best-effort lane), else the
    list of regression messages (empty = clean)."""
    if not os.path.exists(baseline_path):
        return None
    with open(baseline_path) as f:
        snap = json.load(f)
    return compare_rows(rows, snap.get("rows", []), threshold)
