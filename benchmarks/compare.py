"""Perf-regression gate over BENCH.json snapshots (ISSUE 7, 9).

CI's bench lane best-effort-downloads the previous commit's
``bench-<sha>`` artifact and runs ``run.py --compare BASELINE.json``:
any row present in BOTH snapshots whose gated metrics regressed beyond
their allowed fraction fails the lane. Three derived columns are gated
(ISSUE 9 widened this from events_per_s alone):

  events_per_s : throughput, higher is better   (allowed drop 20%)
  p99_ms       : serving tail latency, lower is better (allowed rise
                 100% — wall-clock tails on shared CI runners are far
                 noisier than throughput means)
  wire_mb      : exact collective bytes, lower is better (allowed rise
                 25% — wire volume is deterministic arithmetic, so any
                 rise is a real config/lane change, but new lanes may
                 legitimately add bytes)

Rows that appear or disappear between commits never fail (benchmarks
evolve), rows missing a gated column are ignored for that column, and
a missing baseline file is a no-op — the first run after this lands,
expired artifacts, or a fork without artifact access must not turn
red.
"""
from __future__ import annotations

import json
import os

REGRESSION_FRAC = 0.2

# column -> (higher_is_better, allowed regression fraction)
GATED_METRICS = {
    "events_per_s": (True, REGRESSION_FRAC),
    "p99_ms": (False, 1.0),
    "wire_mb": (False, 0.25),
}


def compare_rows(rows: list, baseline_rows: list,
                 threshold: float = None) -> list:
    """Regression messages for every row name present in both snapshots
    with a gated metric beyond its allowed fraction. `threshold`
    overrides the events_per_s allowance (the historical single-metric
    knob); the latency/volume allowances are fixed in GATED_METRICS."""
    base = {r["name"]: r.get("derived", {}) for r in baseline_rows}
    msgs = []
    for r in rows:
        ref_row = base.get(r["name"])
        if ref_row is None:
            continue
        for col, (higher, allowed) in GATED_METRICS.items():
            if col == "events_per_s" and threshold is not None:
                allowed = threshold
            cur = r.get("derived", {}).get(col)
            ref = ref_row.get(col)
            if not cur or not ref:
                continue
            if higher and cur < ref * (1.0 - allowed):
                msgs.append(
                    f"{r['name']}: {col} {cur:.0f} is "
                    f"{1.0 - cur / ref:.0%} below baseline {ref:.0f} "
                    f"(allowed {allowed:.0%})")
            elif not higher and cur > ref * (1.0 + allowed):
                msgs.append(
                    f"{r['name']}: {col} {cur:.3f} is "
                    f"{cur / ref - 1.0:.0%} above baseline {ref:.3f} "
                    f"(allowed {allowed:.0%})")
    return msgs


def compare_to_baseline(rows: list, baseline_path: str,
                        threshold: float = None):
    """None if the baseline file is absent (best-effort lane), else the
    list of regression messages (empty = clean)."""
    if not os.path.exists(baseline_path):
        return None
    with open(baseline_path) as f:
        snap = json.load(f)
    return compare_rows(rows, snap.get("rows", []), threshold)
