"""Delivery-backend comparison: `delivery_backend="xla"` scatters vs
`delivery_backend="pallas"` segment-reduce kernels (ISSUE 3 tentpole).

Metric: stream events ingested per second end-to-end (super-tick driver),
plus the tick's message-volume telemetry (broadcast/reduce/cross-part) —
identical across backends by the golden tests, reported here so BENCH.json
carries both speed AND volume numbers.

On non-TPU backends the pallas path runs in interpret mode, so the CPU
row measures interpret overhead, not kernel speedup — the point of the
row pair in CI is (a) trajectory tracking and (b) keeping the pallas path
exercised end-to-end in the bench harness; on a TPU the same harness
reports the real MXU-delivery comparison.
"""
from __future__ import annotations

import time

from benchmarks.common import fmt_row, make_case, make_pipeline

TICK_EDGES, SUPER_T = 64, 8


def _build(case, backend):
    return make_pipeline(case, n_parts=4, node_cap=256, edge_cap=1024,
                         feat_cap=256, edge_tick_cap=64,
                         delivery_backend=backend)[2]


def _timed(case, backend, warm_edges=320):
    pipe = _build(case, backend)                 # warm-up: compile the scan
    pipe.run_stream_super(case.edges[:warm_edges], case.feats,
                          tick_edges=TICK_EDGES, super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=64, T=SUPER_T)
    pipe = _build(case, backend)
    t0 = time.perf_counter()
    pipe.run_stream_super(case.edges, case.feats, tick_edges=TICK_EDGES,
                          super_ticks=SUPER_T)
    pipe.flush_super(max_ticks=128, T=SUPER_T)
    wall = time.perf_counter() - t0
    return len(case.edges) / wall, pipe.metrics


def run(scale: str = "small"):
    n_edges = {"small": 800, "full": 6000}[scale]
    case = make_case(n_nodes=200, n_edges=n_edges)
    rows, base = [], None
    for backend in ("xla", "pallas"):
        evs, m = _timed(case, backend)
        if backend == "xla":
            base = evs
        rel = evs / base if base else float("nan")
        rows.append(fmt_row(
            f"delivery[{backend}]", 1e6 / evs,
            f"events_per_s={evs:.0f};vs_xla={rel:.2f}x;"
            f"broadcast_msgs={m.broadcast_msgs};"
            f"reduce_msgs={m.reduce_msgs};"
            f"cross_part_msgs={m.cross_part_msgs};"
            f"emitted={m.emitted_total}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
