"""Paper Fig. 4d: load-imbalance factor (max busy / mean busy over physical
sub-operators) per partitioner and window policy, on a hub-skewed graph."""
from __future__ import annotations

from repro.core import windowing as win
from repro.core.explosion import imbalance_factor

from benchmarks.common import fmt_row, make_case, make_pipeline, run_and_time


def run(scale: str = "small"):
    n_edges = {"small": 1500, "full": 20000}[scale]
    case = make_case(n_edges=n_edges, alpha=1.05)   # heavy skew
    rows = []
    for partitioner in ("hdrf", "clda", "random"):
        for name, policy in (("streaming",
                              win.WindowConfig(kind=win.STREAMING)),
                             ("session",
                              win.WindowConfig(kind=win.SESSION, interval=4))):
            _, _, pipe = make_pipeline(case, n_parts=8, window=policy,
                                       partitioner=partitioner,
                                       base_parallelism=4)
            wall = run_and_time(pipe, case, tick_edges=64)
            imb = [imbalance_factor(b) for b in pipe.physical_busy_per_layer()]
            rows.append(fmt_row(
                f"fig4d_imbalance[{partitioner},{name}]", 1e6 * wall,
                f"imb_l1={imb[0]:.2f};imb_l2={imb[-1]:.2f};"
                f"repl={pipe.part.replication_factor():.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
