"""Online query plane under concurrent update load (ISSUE 4 tentpole).

Metric: answered queries per second and end-to-end enqueue->answer
latency percentiles (p50/p99) while the same device launches ingest the
edge stream — the paper's online-query setting. Rows cover
{local, mesh} x {stale_ok, consistent}:

  * stale_ok rows measure the serving fast path: answers ride the
    super-tick's single host sync, so p50 tracks the launch cadence;
  * consistent rows measure the freshness tax: answers hold until a
    locally-clean, globally-silent tick, which under a continuous
    STREAMING load means the drain at the end — the p99 gap between the
    row pair IS the consistency/latency tradeoff.

Each device count runs in a SUBPROCESS (the XLA host-platform device
count is fixed at backend initialization), mirroring bench_scaling. On
one CPU the mesh row tracks the routing overhead of the extra query
lane, not real scaling; on a multi-chip mesh the same harness reports
the true serving numbers.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

from benchmarks.common import fmt_row

REPO = Path(__file__).resolve().parents[1]

_WORKER = """
import time
import numpy as np
import jax
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh
from repro.serve.session import ServeSession

D = {n_devices}
N_EDGES = {n_edges}
CONSISTENT = {consistent}
TICK_EDGES, SUPER_T, Q_PER_LAUNCH = 64, 8, 24

rng = np.random.default_rng(0)
n_nodes = 200
edges = powerlaw_edges(rng, n_nodes, N_EDGES, 1.3)
feats = {{v: rng.normal(size=16).astype(np.float32) for v in range(n_nodes)}}


def build(mesh=None):
    model = GraphSAGE((16, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=256, edge_cap=2048,
                         repl_cap=512, feat_cap=512, edge_tick_cap=64,
                         query_cap=32, query_tick_cap=64, max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.STREAMING))
    return D3Pipeline(model, params, cfg, mesh=mesh)


def serve(mesh=None):
    s = ServeSession(build(mesh), driver="super", super_ticks=SUPER_T)
    e_chunks, f_chunks = s.pipe.chunk_stream(edges, feats, TICK_EDGES)
    known = []
    t0 = time.perf_counter()
    for lo in range(0, len(e_chunks), SUPER_T):
        if known:
            vids = rng.choice(known, Q_PER_LAUNCH - 4)
            s.submit_embed(vids, consistent=CONSISTENT)
            pairs = rng.choice(known, (4, 2))
            s.submit_link([(int(a), int(b)) for a, b in pairs],
                          consistent=CONSISTENT)
        s.advance_super(e_chunks[lo: lo + SUPER_T],
                        f_chunks[lo: lo + SUPER_T], T=SUPER_T)
        ingested = np.concatenate(
            [c.reshape(-1) for c in e_chunks[lo: lo + SUPER_T]])
        known = sorted(set(known) | set(int(u) for u in ingested))
    s.flush()
    wall = time.perf_counter() - t0
    lat = np.asarray([a.latency_s for a in s.answers.values()
                      if a.latency_s is not None]) * 1e3
    stale = np.asarray([a.staleness_ticks for a in s.answers.values()])
    assert s.outstanding == 0, "all queries must resolve by the flush"
    print(f"RESULT,{{len(lat)}},{{wall:.4f}},{{np.percentile(lat, 50):.2f}},"
          f"{{np.percentile(lat, 99):.2f}},{{np.percentile(stale, 50):.1f}},"
          f"{{N_EDGES / wall:.1f}}")


serve(make_stream_mesh(D) if D > 1 else None)
"""


def _worker(n_devices: int, n_edges: int, consistent: bool,
            timeout: int = 560):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}"}
    r = subprocess.run(
        [sys.executable, "-c",
         _WORKER.format(n_devices=n_devices, n_edges=n_edges,
                        consistent=consistent)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"serving worker D={n_devices} failed:\n"
                           + r.stderr[-2000:])
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, n, wall, p50, p99, stale50, evs = line.split(",")
            return {"answered": int(n), "wall": float(wall),
                    "p50_ms": float(p50), "p99_ms": float(p99),
                    "staleness_p50": float(stale50),
                    "events_per_s": float(evs)}
    raise RuntimeError("serving worker printed no RESULT row")


def run(scale: str = "small"):
    n_edges = {"small": 800, "full": 4000}[scale]
    rows = []
    for name, d in (("local", 1), ("mesh,D=2", 2)):
        for mode in ("stale_ok", "consistent"):
            res = _worker(d, n_edges, mode == "consistent")
            qps = res["answered"] / res["wall"]
            rows.append(fmt_row(
                f"serving[{name},{mode}]", 1e6 / max(qps, 1e-9),
                f"answered_per_s={qps:.1f};p50_ms={res['p50_ms']:.1f};"
                f"p99_ms={res['p99_ms']:.1f};"
                f"staleness_ticks_p50={res['staleness_p50']:.1f};"
                f"events_per_s={res['events_per_s']:.0f};"
                f"answered={res['answered']}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
