"""Stale-free distributed training: layered backprop == jax.grad, Alg.3
averaging, phased rebuild, coordinator votes."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.graph.sage import GraphSAGE
from repro.nn.layers import Linear
from repro.optim import sgd


def setup(seed=0, n_nodes=50, n_edges=150, d_in=8, n_cls=4):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n_nodes, n_edges),
                      rng.integers(0, n_nodes, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    labels = {v: int(rng.integers(0, n_cls)) for v in range(n_nodes)}
    model = GraphSAGE((d_in, 16, 16))
    params = model.init(jax.random.key(0))
    head = Linear(16, n_cls)
    head_params = head.init(jax.random.key(1))
    cfg = PipelineConfig(n_parts=4, node_cap=64, edge_cap=256, repl_cap=256,
                         feat_cap=512, edge_tick_cap=64, max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.STREAMING))
    pipe = D3Pipeline(model, params, cfg)
    pipe.run_stream(edges, feats, tick_edges=32)
    coord = TrainingCoordinator(pipe, head, head_params,
                                TrainConfig(optimizer=sgd(), lr=0.1,
                                            batch_threshold=2))
    coord.observe_labels(labels)
    return edges, feats, labels, model, params, head, head_params, pipe, coord


def oracle_loss_fn(model, head, g, labels, n_nodes):
    def f(all_params):
        x = g.x
        for i, layer in enumerate(model.layers):
            x = layer(all_params[f"l{i}"], g, x)
        logits = head(all_params["head"], x).astype(jnp.float32)
        y = jnp.asarray([labels[v] for v in range(n_nodes)])
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        return -jnp.mean(gold)

    return f


def test_layered_backprop_matches_jax_grad():
    (edges, feats, labels, model, params, head, head_params, pipe,
     coord) = setup()
    pipe.flush()
    la, lm = coord._device_labels()
    loss, hg, pg = coord._full_batch_grads(la, lm)

    g, _ = build_snapshot(edges, feats, 8, 50)
    f = oracle_loss_fn(model, head, g, labels, 50)
    all_p = {**params, "head": head_params}
    oloss = f(all_p)
    og = jax.grad(f)(all_p)
    assert abs(float(loss) - float(oloss)) < 1e-5
    for name in ("l0", "l1"):
        summed = jax.tree.map(lambda x: jnp.sum(x, 0), pg[name])
        flat_s = jax.tree.leaves(summed)
        flat_o = jax.tree.leaves(og[name])
        for s, o in zip(flat_s, flat_o):
            np.testing.assert_allclose(np.asarray(s), np.asarray(o),
                                       rtol=1e-4, atol=1e-6)
    for k in hg:
        np.testing.assert_allclose(np.asarray(hg[k]),
                                   np.asarray(og["head"][k]),
                                   rtol=1e-4, atol=1e-6)


def test_full_train_cycle_decreases_loss_and_rebuilds():
    (edges, feats, labels, model, params, head, head_params, pipe,
     coord) = setup(seed=1)
    res = coord.train(epochs=3)
    assert res.losses[-1] < res.losses[0]
    # post-rebuild state must equal the static oracle under UPDATED params
    g, _ = build_snapshot(edges, feats, 8, 50)
    ref = np.asarray(oracle_embeddings(model, pipe.params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)
    # streaming continues correctly after training resumes
    rng = np.random.default_rng(5)
    new_edges = np.stack([rng.integers(0, 50, 20),
                          rng.integers(0, 50, 20)], 1)
    new_edges = new_edges[new_edges[:, 0] != new_edges[:, 1]]
    pipe.run_stream(new_edges, feats, tick_edges=10)
    pipe.flush(max_ticks=64)
    all_edges = np.concatenate([edges, new_edges])
    g2, _ = build_snapshot(all_edges, feats, 8, 50)
    ref2 = np.asarray(oracle_embeddings(model, pipe.params, g2))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, ref2[vid], rtol=1e-4, atol=1e-4)


def test_majority_vote():
    *_, coord = setup(seed=2)
    # threshold 2 labels/part over 4 parts with 50 labels -> all vote
    assert coord.votes() >= 3
    assert coord.should_train()
    coord2 = TrainingCoordinator(coord.pipe, coord.head, coord.head_params,
                                 TrainConfig(optimizer=sgd(),
                                             batch_threshold=10_000))
    coord2.observe_labels({0: 1})
    assert not coord2.should_train()
