"""End-to-end exactness of the streaming engine (the paper's core claim):
streaming/windowed incremental aggregators produce the SAME embeddings as a
static model on the final graph snapshot."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE


def make_stream(seed=0, n_nodes=60, n_edges=200, d_in=8):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n_nodes, n_edges),
                      rng.integers(0, n_nodes, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    return edges, feats


def build_pipe(window, n_nodes=60, d_in=8, partitioner="hdrf", seed=0):
    model = GraphSAGE((d_in, 16, 16))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=64, edge_cap=256, repl_cap=256,
                         feat_cap=512, edge_tick_cap=64, max_nodes=n_nodes,
                         window=window, partitioner=partitioner, seed=seed)
    return model, params, D3Pipeline(model, params, cfg)


def test_streaming_matches_static_oracle(streamed_pipeline):
    """STREAMING policy rides the shared session pipeline (conftest)."""
    s = streamed_pipeline
    emb = s.pipe.embeddings()
    assert len(emb) == 60, "every vertex must materialize an embedding"
    g, _ = build_snapshot(s.case.edges, s.case.feats, 8, 60)
    ref = np.asarray(oracle_embeddings(s.model, s.params, g))
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", [win.TUMBLING, win.SESSION, win.ADAPTIVE])
def test_windowed_matches_static_oracle(kind):
    edges, feats = make_stream()
    model, params, pipe = build_pipe(win.WindowConfig(kind=kind, interval=3))
    pipe.run_stream(edges, feats, tick_edges=32)
    pipe.flush(max_ticks=128)
    emb = pipe.embeddings()
    assert len(emb) == 60, "every vertex must materialize an embedding"
    g, _ = build_snapshot(edges, feats, 8, 60)
    ref = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("method", ["hdrf", "clda", "random"])
def test_partitioners_all_exact(method):
    edges, feats = make_stream(seed=3)
    model, params, pipe = build_pipe(win.WindowConfig(kind=win.STREAMING),
                                     partitioner=method)
    pipe.run_stream(edges, feats, tick_edges=64)
    pipe.flush(max_ticks=64)
    emb = pipe.embeddings()
    g, _ = build_snapshot(edges, feats, 8, 60)
    ref = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


def test_windowing_reduces_messages():
    edges, feats = make_stream(seed=1, n_edges=300)
    _, _, p_stream = build_pipe(win.WindowConfig(kind=win.STREAMING))
    p_stream.run_stream(edges, feats, tick_edges=16)
    p_stream.flush(max_ticks=128)
    _, _, p_win = build_pipe(win.WindowConfig(kind=win.SESSION, interval=4))
    p_win.run_stream(edges, feats, tick_edges=16)
    p_win.flush(max_ticks=256)
    assert p_win.metrics.reduce_msgs < p_stream.metrics.reduce_msgs, \
        "windowing must reduce aggregator RMI volume (paper Fig. 4b)"
    assert p_win.metrics.emitted_total < p_stream.metrics.emitted_total, \
        "windowing must coalesce forward emissions"


def test_incremental_updates_on_feature_change():
    """updateElement path: replacing a feature updates downstream exactly."""
    edges, feats = make_stream(seed=2, n_nodes=30, n_edges=80, d_in=4)
    model, params, pipe = build_pipe(
        win.WindowConfig(kind=win.STREAMING), n_nodes=30, d_in=4)
    pipe.run_stream(edges, feats, tick_edges=40)
    pipe.flush(max_ticks=64)
    # mutate a few features (replace semantics) and re-verify
    rng = np.random.default_rng(7)
    for vid in (0, 3, 5):
        feats[vid] = rng.normal(size=4).astype(np.float32)
        pipe.tick(None, [(vid, feats[vid])])
    pipe.flush(max_ticks=64)
    emb = pipe.embeddings()
    g, _ = build_snapshot(edges, feats, 4, 30)
    ref = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


def test_termination_detection_flush():
    edges, feats = make_stream(seed=4)
    _, _, pipe = build_pipe(win.WindowConfig(kind=win.SESSION, interval=5))
    pipe.run_stream(edges, feats, tick_edges=64)
    n = pipe.flush(max_ticks=128)
    assert n >= 2          # needs >= quiet_sweeps empty sweeps
    from repro.core.tick import has_work
    assert not any(bool(has_work(ls)) for ls in pipe.states)
