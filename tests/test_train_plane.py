"""The streaming training plane (ISSUE 8).

Contracts pinned here:

  * config plane fails loud: TrainConfig validates its knobs, the
    pipeline rejects inconsistent (train_cap, TrainConfig) pairs, the
    unified `capacities()` view agrees with the deprecated accessors
    (which warn), and TrainingCoordinator insists on a TrainConfig.

  * a QUIET training plane is invisible: enabling train_cap + a
    TrainConfig whose threshold never fires leaves the stream bit-for-bit
    (`assert_array_equal` embeddings + exact integer metrics) the
    train_cap=0 program, across all four window policies and both
    drivers.

  * quiescent online gradients ARE the halt-flush oracle's: after a
    flush, a single firing label tick latches `last_grad`/`loss` exactly
    equal (single device) to `TrainingCoordinator._full_batch_grads` —
    which test_training_core pins against `jax.grad` on the static
    snapshot, so the online plane is transitively pinned to autodiff.

  * online learning learns: loss decreases over repeated label passes,
    both drivers, optimizer state advances.

  * the training state rides the consistent checkpoint cut: a mid-stream
    snapshot restores optimizer state (adam moments + step count) and the
    restored run's continuation is bit-identical to the uninterrupted
    one.

  * the mesh plane agrees with the local plane: data=4 (1-D) and
    stage=2 (2-D) quiescent gradients match the single-device run to
    1e-5 (cross-device scatter-add order differs; see
    backward_layer_routed). Subprocess smokes force the device counts on
    single-device machines.
"""
from pathlib import Path

import numpy as np
import jax
import pytest

from conftest import needs_devices, run_forced_devices
from repro.core import windowing as win
from repro.core.pipeline import Capacities, D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh
from repro.optim import adam, sgd
from repro.serve import TrainSession

N_NODES, D, N_CLS = 32, 8, 4

needs2 = needs_devices(2)
needs4 = needs_devices(4)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]
STREAMING = win.WindowConfig(kind=win.STREAMING)


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D).astype(np.float32)
             for v in range(N_NODES)}
    labels = {v: (v * 7 + 3) % N_CLS for v in range(N_NODES)}
    return edges, feats, labels


def build_pipe(window, train=None, train_cap=0, mesh=None, n_stages=1,
               d_hid=16, uniform=False):
    # stage-parallel runs need SPMD-uniform dims (in == out)
    dims = (D, D, D) if (n_stages > 1 or uniform) else (D, d_hid, d_hid)
    model = GraphSAGE(dims, n_classes=N_CLS)
    params = model.init(jax.random.key(0))
    if train is None:
        params = {k: v for k, v in params.items() if k != "head"}
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         window=window, n_stages=n_stages,
                         train_cap=train_cap)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh,
                                     train=train)


# ------------------------------------------------------------ config plane

def test_train_config_validation():
    with pytest.raises(ValueError, match="optimizer"):
        TrainConfig(optimizer="sgd")
    with pytest.raises(ValueError, match="batch_threshold"):
        TrainConfig(optimizer=sgd(), batch_threshold=0)
    with pytest.raises(ValueError, match="epochs"):
        TrainConfig(optimizer=sgd(), epochs=0)
    with pytest.raises(ValueError, match="window"):
        TrainConfig(optimizer=sgd(), window=-1)
    with pytest.raises(ValueError, match="lr"):
        TrainConfig(optimizer=sgd(), lr=-0.1)
    with pytest.raises(ValueError, match="topk_frac"):
        TrainConfig(optimizer=sgd(), topk_frac=0.0)
    # frozen + hashable: rides jit boundaries as a static argument
    hash(TrainConfig(optimizer=sgd()))


def test_pipeline_rejects_inconsistent_train_config():
    tcfg = TrainConfig(optimizer=sgd(), batch_threshold=1)
    with pytest.raises(ValueError, match="train_cap"):
        build_pipe(STREAMING, train=tcfg, train_cap=0)
    with pytest.raises(ValueError, match="train_cap"):
        build_pipe(STREAMING, train=None, train_cap=8)
    with pytest.raises(ValueError, match="train_cap"):
        PipelineConfig(train_cap=-1).validate()
    # a training pipeline needs an output operator
    model = GraphSAGE((D, 16, 16))          # n_classes=0: no head
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         train_cap=8)
    with pytest.raises(ValueError, match="head"):
        D3Pipeline(model, params, cfg, train=tcfg)


def test_capacities_view_matches_deprecated_accessors():
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, train_cap=8)
    caps = cfg.capacities()
    assert isinstance(caps, Capacities)
    assert caps.train_cap == 8
    with pytest.deprecated_call():
        assert cfg.outbox() == caps.outbox
    with pytest.deprecated_call():
        assert cfg.query_admissions() == caps.query_admissions
    with pytest.deprecated_call():
        assert cfg.defer_rows(cfg.n_parts * cfg.repl_cap, 1) \
            == caps.bc_defer_rows


def test_train_session_rejects_untrained_pipeline():
    _, _, pipe = build_pipe(STREAMING)
    with pytest.raises(ValueError, match="train_cap"):
        TrainSession(pipe)
    tcfg = TrainConfig(optimizer=sgd(), batch_threshold=1)
    _, _, tp = build_pipe(STREAMING, train=tcfg, train_cap=8)
    with pytest.raises(ValueError, match="driver"):
        TrainSession(tp, driver="warp")


def test_training_coordinator_requires_train_config():
    _, _, pipe = build_pipe(STREAMING)
    with pytest.raises(TypeError, match="TrainConfig"):
        TrainingCoordinator(pipe, None, None, sgd())


# ------------------------------------- quiet plane is bit-invisible

@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_quiet_train_plane_bit_identity(window):
    """train_cap > 0 with a never-firing threshold must leave the stream
    bit-for-bit the train_cap=0 program: the training plane reads the
    tick, it never writes it (and at train_cap=0 it is compiled away
    entirely — that side is the reference here)."""
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=sgd(), lr=0.1, batch_threshold=10_000)
    for driver in ("tick", "super"):
        _, _, ref = build_pipe(window)
        _, _, pipe = build_pipe(window, train=tcfg, train_cap=64)
        if driver == "tick":
            for p, lab in ((ref, None), (pipe, labels)):
                p.run_stream(edges, feats, tick_edges=24)
                p.tick(labels=(list(lab.items()) if lab else None))
                p.flush(max_ticks=128)
        else:
            for p, lab in ((ref, None), (pipe, labels)):
                p.run_stream_super(edges, feats, tick_edges=24,
                                   super_ticks=4)
                p.run_super_tick(
                    T=1, label_chunks=([list(lab.items())] if lab else None))
                p.flush_super(max_ticks=128, T=4)
        e_ref, e_got = ref.embeddings(), pipe.embeddings()
        assert set(e_got) == set(e_ref)
        for vid in e_got:
            np.testing.assert_array_equal(e_got[vid], e_ref[vid])
        m, r = pipe.metrics, ref.metrics
        assert (m.reduce_msgs, m.broadcast_msgs, m.cross_part_msgs,
                m.emitted_total, m.dropped) == \
               (r.reduce_msgs, r.broadcast_msgs, r.cross_part_msgs,
                r.emitted_total, r.dropped)
        st = pipe.train_stats()
        assert st["steps"] == 0 and st["loss"] == 0.0


# ------------------------------- quiescent grads == halt-flush oracle

def test_quiescent_online_grads_match_oracle_exactly():
    """lr=0 so fires never move parameters: after the stream flushes, one
    label tick fires on the quiescent fixed point and its latched
    last_grad/loss must equal the halt-flush coordinator's full-batch
    grads over the same labels to f32 round-off (single device: the
    routed backward takes the oracle's gather path — same math, but the
    two jitted programs fuse/reassociate their reductions differently,
    so agreement is ~1 ulp, not bitwise)."""
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=sgd(), lr=0.0, batch_threshold=1)
    model, params, pipe = build_pipe(STREAMING, train=tcfg, train_cap=64)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=128)
    pipe.tick(labels=list(labels.items()))
    ts = pipe.train_state
    st = pipe.train_stats()
    assert st["steps"] == 1, "the label tick must fire exactly once"

    _, _, ref = build_pipe(STREAMING)
    ref.run_stream(edges, feats, tick_edges=24)
    ref.flush(max_ticks=128)
    coord = TrainingCoordinator(ref, model.head, params["head"],
                                TrainConfig(optimizer=sgd(), lr=0.0,
                                            batch_threshold=1))
    coord.observe_labels(labels)
    la, lm = coord._device_labels()
    loss, hg, pg = coord._full_batch_grads(la, lm)

    np.testing.assert_allclose(np.float32(st["loss"]),
                               np.asarray(loss, np.float32),
                               rtol=1e-6, atol=0)
    for name in ("l0", "l1"):
        want = jax.tree.map(lambda x: np.asarray(x).sum(0), pg[name])
        got = ts.last_grad[name]
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=5e-6, atol=1e-7)
    for w, g in zip(jax.tree.leaves(hg),
                    jax.tree.leaves(ts.last_grad["head"])):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5e-6, atol=1e-7)
    # lr=0 fires must not perturb the live parameters
    for k in ("l0", "l1"):
        for a, b in zip(jax.tree.leaves(ts.params[k]),
                        jax.tree.leaves(params[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------- online learning

@pytest.mark.parametrize("driver", ["tick", "super"])
def test_online_training_decreases_loss(driver):
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=sgd(), lr=0.1, batch_threshold=4)
    _, _, pipe = build_pipe(STREAMING, train=tcfg, train_cap=64)
    sess = TrainSession(pipe, driver=driver, super_ticks=4)
    e_chunks, f_chunks = pipe.chunk_stream(edges, feats, 24)
    sess.observe_labels(labels)
    if driver == "tick":
        for e, f in zip(e_chunks, f_chunks):
            sess.advance(e, f)
    else:
        sess.advance_super(e_chunks, f_chunks)
    sess.flush()
    first = sess.train_stats()
    assert first["steps"] > 0 and first["backlog"] == 0
    for _ in range(5):
        sess.observe_labels(labels)
        sess.flush()
    last = sess.train_stats()
    assert last["steps"] > first["steps"]
    assert last["loss"] < first["loss"]
    assert np.isfinite(last["grad_norm"])


def test_online_compression_path_learns():
    """Error-feedback compressed gradients still learn (residual carried
    in TrainState, int8 round-trip on device)."""
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=sgd(), lr=0.1, batch_threshold=4,
                       compression=True, topk_frac=0.5)
    _, _, pipe = build_pipe(STREAMING, train=tcfg, train_cap=64)
    assert pipe.train_state.residual, "compression must allocate residuals"
    sess = TrainSession(pipe, driver="tick")
    pipe.run_stream(edges, feats, tick_edges=24)
    sess.observe_labels(labels)
    sess.flush()
    first = sess.train_stats()
    for _ in range(5):
        sess.observe_labels(labels)
        sess.flush()
    last = sess.train_stats()
    assert last["steps"] > first["steps"]
    assert last["loss"] < first["loss"]


# ------------------------------------------------- checkpoint cut

def test_optimizer_state_survives_checkpoint(tmp_path):
    """Mid-flight snapshot: adam moments + step count restore bit-equal,
    and the restored run's continuation is bit-identical to the
    uninterrupted one."""
    from repro.ft.checkpoint import CheckpointManager
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=adam(), lr=1e-2, batch_threshold=1)
    half = len(edges) // 2

    def build():
        return build_pipe(STREAMING, train=tcfg, train_cap=64)[2]

    pipe = build()
    pipe.run_stream(edges[:half], feats, tick_edges=24)
    pipe.tick(labels=list(labels.items()))
    assert pipe.train_stats()["steps"] >= 1
    mgr = CheckpointManager(tmp_path)
    mgr.save_pipeline(0, pipe)
    opt_at_save = jax.tree.map(np.asarray, pipe.train_state.opt)
    seen = set(int(v) for v in edges[:half].reshape(-1))

    def finish(p):
        e_chunks, f_chunks = p.chunk_stream(edges[half:], feats, 24,
                                            seen=set(seen))
        for e, f in zip(e_chunks, f_chunks):
            p.tick(e, f)
        p.flush(max_ticks=128)
        p.tick(labels=list(labels.items()))
        return (jax.tree.map(np.asarray, p.train_state.params),
                p.train_stats())

    params_a, stats_a = finish(pipe)

    fresh = build()
    mgr.restore_pipeline(fresh)
    for a, b in zip(jax.tree.leaves(fresh.train_state.opt),
                    jax.tree.leaves(opt_at_save)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    params_b, stats_b = finish(fresh)
    assert stats_a == stats_b
    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------ mesh plane

def _quiescent_grad_run(mesh=None, n_stages=1, uniform=False):
    edges, feats, labels = make_stream()
    tcfg = TrainConfig(optimizer=sgd(), lr=0.0, batch_threshold=1)
    _, _, pipe = build_pipe(STREAMING, train=tcfg, train_cap=64,
                            mesh=mesh, n_stages=n_stages, uniform=uniform)
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=160, T=4)
    pipe.run_super_tick(T=1, label_chunks=[list(labels.items())])
    ts = pipe.train_state
    return pipe.train_stats(), jax.tree.map(np.asarray, ts.last_grad)


def _assert_grads_close(ref, got, rtol=1e-5, atol=1e-6):
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=rtol, atol=atol)


@needs4
def test_train_mesh_data4_matches_local():
    """1-D data=4: per-part gradient hops ride the packed wire; the
    quiescent fired gradients match the single-device run to 1e-5."""
    st_ref, g_ref = _quiescent_grad_run()
    mesh = make_stream_mesh(4)
    st, g = _quiescent_grad_run(mesh=mesh, n_stages=1)
    assert st["steps"] == st_ref["steps"] == 1
    np.testing.assert_allclose(st["loss"], st_ref["loss"],
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g)


@needs2
def test_train_stage2_matches_local():
    """2-D stage=2: the stage-replicated training state (stage-gathered
    caches, every stage runs the full-depth backward) agrees with the
    single-device run to 1e-5."""
    st_ref, g_ref = _quiescent_grad_run(uniform=True)
    mesh = make_stream_mesh(2, stage=2)
    st, g = _quiescent_grad_run(mesh=mesh, n_stages=2)
    assert st["steps"] == st_ref["steps"] == 1
    np.testing.assert_allclose(st["loss"], st_ref["loss"],
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g)


def test_train_mesh_forced4_subprocess():
    r = run_forced_devices(4, Path(__file__),
                           ["-k", "test_train_mesh_data4_matches_local"],
                           timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


def test_train_stage2_forced2_subprocess():
    r = run_forced_devices(2, Path(__file__),
                           ["-k", "test_train_stage2_matches_local"],
                           timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
