"""Golden equivalence of the delivery plane (ISSUE 3 tentpole).

`delivery_backend="pallas"` (sorted segment-reduce kernels, interpret
mode off-TPU) must be indistinguishable from `delivery_backend="xla"`
(the reference scatters): same embeddings, same exact integer TickStats,
same busy vector — across all four window policies, both drivers, and
both routers. The xla pipelines are themselves pinned to the static
oracle by tests/test_mesh_router.py, so pallas ≡ xla ≡ oracle.

Float tolerance note: integer-natured quantities (stats, counts, busy)
are compared EXACTLY; embeddings use the same tight allclose as the
router golden matrix, because duplicate RMI records summed by a one-hot
matmul and by a sequential scatter can differ in f32 summation order.

The whole module carries the `pallas` marker (pyproject registers it) —
CI's pallas-interpret lane selects it with `-m pallas`; the mesh tests
skip below 4 devices and run there under a forced 4-device CPU backend.
"""
import numpy as np
import jax
import pytest

from repro.core import windowing as win
from repro.core.delivery import (BACKENDS, PallasDelivery, XlaDelivery,
                                 make_delivery)
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

pytestmark = pytest.mark.pallas

N_NODES, D_IN = 32, 8

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices (CI pallas lane forces a 4-device backend)")

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window, backend, mesh=None):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         window=window, delivery_backend=backend)
    return D3Pipeline(model, params, cfg, mesh=mesh)


def run_per_tick(pipe, edges, feats):
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=96)
    return pipe


def run_super(pipe, edges, feats):
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=96, T=4)
    return pipe


def assert_golden_equal(ref, other):
    """Exact integer telemetry + tight embedding equivalence."""
    assert other.metrics.reduce_msgs == ref.metrics.reduce_msgs
    assert other.metrics.broadcast_msgs == ref.metrics.broadcast_msgs
    assert other.metrics.cross_part_msgs == ref.metrics.cross_part_msgs
    assert other.metrics.emitted_total == ref.metrics.emitted_total
    assert other.metrics.dropped == ref.metrics.dropped
    np.testing.assert_array_equal(other.metrics.busy_logical,
                                  ref.metrics.busy_logical)
    # aggregator counts are integer-valued floats: exact on both backends
    np.testing.assert_array_equal(np.asarray(other.states[0].agg_cnt),
                                  np.asarray(ref.states[0].agg_cnt))
    a, b = ref.embeddings(), other.embeddings()
    assert set(a) == set(b)
    for vid in a:
        np.testing.assert_allclose(b[vid], a[vid], rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ registry units

def test_registry_and_validation():
    assert set(BACKENDS) == {"xla", "pallas"}
    assert isinstance(make_delivery("xla"), XlaDelivery)
    assert isinstance(make_delivery("pallas"), PallasDelivery)
    with pytest.raises(ValueError, match="unknown delivery_backend"):
        make_delivery("cuda")
    with pytest.raises(ValueError, match="not registered"):
        PipelineConfig(delivery_backend="nope").validate()
    # backends must be hashable static-arg citizens (jit cache keys)
    assert hash(make_delivery("pallas")) == hash(make_delivery("pallas"))


def test_pipeline_resolves_backend():
    pipe = build_pipe(win.WindowConfig(kind=win.STREAMING), "pallas")
    assert isinstance(pipe.delivery, PallasDelivery)
    pipe = build_pipe(win.WindowConfig(kind=win.STREAMING), "xla")
    assert isinstance(pipe.delivery, XlaDelivery)


# ------------------------------------- golden matrix (LocalRouter, 1 device)

@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_pallas_golden_matrix_local(window):
    """pallas ≡ xla for BOTH drivers under the LocalRouter, per policy."""
    edges, feats = make_stream()
    ref = run_per_tick(build_pipe(window, "xla"), edges, feats)
    per = run_per_tick(build_pipe(window, "pallas"), edges, feats)
    assert_golden_equal(ref, per)
    sup = run_super(build_pipe(window, "pallas"), edges, feats)
    assert_golden_equal(ref, sup)


def test_pallas_super_tick_stays_donated():
    """The pallas program must not break the donated-carry contract."""
    edges, feats = make_stream()
    pipe = build_pipe(win.WindowConfig(kind=win.STREAMING), "pallas")
    old_feat = pipe.states[0].feat
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    assert old_feat.is_deleted(), "PipelineCarry must stay donated"


# --------------------------------------- golden matrix (MeshRouter, >=4 dev)

@needs4
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_pallas_golden_matrix_mesh(window):
    """pallas ≡ xla on a real 4-device mesh: the delivery kernels run
    INSIDE the shard_map, after the all_to_all routing round."""
    edges, feats = make_stream()
    mesh = make_stream_mesh(4)
    ref = run_per_tick(build_pipe(window, "xla", mesh=mesh), edges, feats)
    per = run_per_tick(build_pipe(window, "pallas", mesh=mesh), edges, feats)
    assert_golden_equal(ref, per)
    sup = run_super(build_pipe(window, "pallas", mesh=mesh), edges, feats)
    assert_golden_equal(ref, sup)
