"""Traffic-adaptive routing plane (ISSUE 5 tentpole).

Covers, bottom-up:
  * the packed wire format (dist/wire.py): exact pack/unpack round-trips
    for both lane types;
  * kernels/route_pack: the sort-by-destination plan vs the O(N*D)
    one-hot reference, and the xla-vs-pallas placement equivalence;
  * the misrouting regression: a VALID record addressed to an
    out-of-range part must be masked out of the exchange (the old
    `jnp.clip(part // Pl, 0, D-1)` silently shipped it to the last
    device, where it burned bucket capacity before being dropped);
  * the capped golden matrix under SKEWED hub-heavy traffic:
    route_cap in {dense, C//D, tiny} x {per-tick, super-tick} x
    {xla, pallas} on a real 4-device mesh must converge to the
    LocalRouter reference and the static oracle with EXACT integer
    aggregator counts, defer (never drop) overflow, re-emit every
    deferred row, and terminate its flush;
  * capped-wire query plane: link tails carried by wire backpressure
    must all answer eventually (the wire-backlog quiescence vote).

Stats contract at route_cap < C: the emission-side counters
(broadcast/reduce/cross_part) are counted BEFORE the wire, so deferral
never double-counts them — but delivery DELAYS shift which ticks
coalesce a vertex's updates, so their cumulative values may legally
differ from the dense reference under windows. What must match exactly:
final aggregator counts (each edge contributes once), the converged
embeddings (to f32 round-off of the telescoped delta sums), and
`route_dropped == 0` in any correctly-sized config. At the dense
default the existing test_mesh_router golden matrix already pins EXACT
integer stats.

Execution tiers mirror test_mesh_router: units anywhere, @needs4
in-process (CI mesh/pallas lanes), a forced-4 subprocess smoke in the
fast lane and the full matrix in the slow lane.
"""
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import needs_devices, run_forced_devices
from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

N_NODES, D_IN = 32, 8

needs4 = needs_devices(4)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]


def hub_stream(seed=0, n_edges=120):
    """Skewed topology: most edges point AT a handful of hub vertices, so
    RMI traffic converges on the hubs' owner device and overflows small
    per-destination buckets (the route_cap stress shape)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(1, N_NODES, n_edges)
    dst = np.where(rng.random(n_edges) < 0.75,
                   rng.integers(0, 3, n_edges),        # hubs 0..2
                   rng.integers(0, N_NODES, n_edges))
    edges = np.stack([src, dst], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window, mesh=None, route_cap=None, route_defer_cap=None,
               backend="xla", query_cap=0):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         window=window, route_cap=route_cap,
                         route_defer_cap=route_defer_cap,
                         delivery_backend=backend, query_cap=query_cap)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def assert_embeddings_close(a, b, rtol=1e-5, atol=1e-5):
    assert set(a) == set(b)
    for vid in a:
        np.testing.assert_allclose(b[vid], a[vid], rtol=rtol, atol=atol)


# ------------------------------------------------------------- wire format

def _msg_batch(rng, cap=13, d=5):
    from repro.core.events import MsgBatch
    return MsgBatch(
        part=jnp.asarray(rng.integers(0, 7, cap), jnp.int32),
        slot=jnp.asarray(rng.integers(0, 31, cap), jnp.int32),
        vec=jnp.asarray(rng.normal(size=(cap, d)), jnp.float32),
        cnt=jnp.asarray(rng.random(cap), jnp.float32),
        src_part=jnp.asarray(rng.integers(0, 7, cap), jnp.int32),
        valid=jnp.asarray(rng.random(cap) < 0.6))


def test_wire_pack_roundtrip_msg_and_query_batches():
    from repro.dist.wire import field_col, lane_width, pack_lane, unpack_lane
    from repro.serve.query import empty_query_batch
    rng = np.random.default_rng(0)
    msg = _msg_batch(rng)
    buf = pack_lane(msg)
    assert buf.shape == (13, lane_width(msg)) and lane_width(msg) == 5 + 5
    back = unpack_lane(buf, msg)
    for a, b in zip(jax.tree.leaves(msg), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the part column is where the router re-derives destinations from
    np.testing.assert_array_equal(
        np.asarray(buf[:, field_col(msg, "part")], np.int32),
        np.asarray(msg.part))
    qb = empty_query_batch(4, 6)
    assert lane_width(qb) == 6 + 10
    q2 = unpack_lane(pack_lane(qb), qb)
    for a, b in zip(jax.tree.leaves(qb), jax.tree.leaves(q2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------- route_pack

@pytest.mark.parametrize("cap", [1, 3, 64])
def test_route_plan_matches_onehot_reference(cap):
    from repro.kernels.route_pack import route_plan, route_plan_ref
    rng = np.random.default_rng(1)
    n, D = 57, 4
    # out-of-range destinations with ok=True must be excluded by the plan
    # itself (route_plan_ref semantics), not just by the caller's mask
    dst = jnp.asarray(rng.integers(-1, D + 2, n), jnp.int32)
    ok = jnp.asarray(rng.random(n) < 0.7)
    order, ship_s, slot_s, left_s = route_plan(dst, ok, D, cap)
    ship_r, slot_r, left_r = route_plan_ref(dst, ok, D, cap)
    inv = np.asarray(order)
    np.testing.assert_array_equal(np.asarray(ship_s), np.asarray(ship_r)[inv])
    np.testing.assert_array_equal(np.asarray(left_s), np.asarray(left_r)[inv])
    np.testing.assert_array_equal(np.asarray(slot_s), np.asarray(slot_r)[inv])
    # FIFO per destination: earlier records never overflow behind later ones
    for dev in range(D):
        ranks = np.flatnonzero(np.asarray(ship_r)
                               & (np.asarray(dst) == dev))
        lefts = np.flatnonzero(np.asarray(left_r)
                               & (np.asarray(dst) == dev))
        if len(ranks) and len(lefts):
            assert ranks.max() < lefts.min()


@pytest.mark.pallas
def test_route_pack_pallas_matches_xla():
    from repro.kernels.route_pack import route_pack, route_pack_ref, route_plan
    rng = np.random.default_rng(2)
    n, D, cap, W = 70, 4, 8, 9
    rows = jnp.asarray(rng.normal(size=(n, W)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, D, n), jnp.int32)
    ok = jnp.asarray(rng.random(n) < 0.8)
    order, _, slot_s, _ = route_plan(dst, ok, D, cap)
    rows_s = rows[order]
    ref = route_pack_ref(rows_s, slot_s, D * cap)
    for backend in ("xla", "pallas"):
        got = route_pack(rows_s, slot_s, D * cap, backend=backend,
                         interpret=True if backend == "pallas" else None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0, atol=0)


def test_config_rejects_undeferrable_capped_wire():
    """route_defer_cap=0 is allowed for MsgBatch lanes (loud drops), but a
    capped query wire that can drop would strand qids — rejected."""
    cfg = PipelineConfig(n_parts=4, feat_cap=4, route_cap=1,
                         route_defer_cap=0, query_cap=8)
    cfg.validate(n_devices=1)            # no wire capping on one device
    with pytest.raises(ValueError, match="strand its qid"):
        cfg.validate(n_devices=4)
    # deferral available (default ring) -> fine
    PipelineConfig(n_parts=4, feat_cap=4, route_cap=1,
                   query_cap=8).validate(n_devices=4)
    with pytest.raises(ValueError, match="route_cap=0 must be > 0"):
        PipelineConfig(route_cap=0, feat_cap=8).validate()


def test_oversized_qid_host_rejected():
    """qids at or beyond 2**24 would round on the packed f32 wire and
    answer under the WRONG qid — the host must reject them with an
    ok=False answer that still carries the exact qid."""
    from repro.serve.query import KIND_EMBED
    _, _, pipe = build_pipe(win.WindowConfig(kind=win.STREAMING),
                            query_cap=4)
    pipe.tick(queries=[(2 ** 24 + 1, KIND_EMBED, 0, False),
                       (-1, KIND_EMBED, 0, False)])
    ans = pipe.drain_answers()
    assert sorted(ans["qid"].tolist()) == [-1, 2 ** 24 + 1]
    assert not ans["ok"].any()
    assert pipe.metrics.queries_admitted == 0


def test_local_router_route_lanes_identity():
    from repro.dist.router import LocalRouter
    from repro.dist.wire import init_defer
    rng = np.random.default_rng(3)
    msg = _msg_batch(rng)
    lanes, defers, rcpt = LocalRouter(n_parts=4).route_lanes(
        (msg,), (init_defer(0, 10),))
    assert lanes[0] is msg
    assert int(rcpt.rows) == 0
    assert int(rcpt.deferred) == 0 and int(rcpt.dropped) == 0


# ------------------------------------------- misrouting regression (4 dev)

@needs4
def test_invalid_part_masked_out_of_exchange():
    """A VALID record with an out-of-range destination part must vanish
    from the exchange (and not burn a bucket slot). Before ISSUE 5 the
    destination clip shipped it to the LAST device."""
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.events import MsgBatch
    from repro.dist.router import MeshRouter
    from repro.dist.wire import init_defer

    mesh = make_stream_mesh(4)
    router = MeshRouter(n_parts=4, n_devices=4, route_cap=1)

    def prog():
        # every device emits: one rogue record (part=99) FIRST, then one
        # valid record for part 3 — with cap=1 the rogue would eat the
        # bucket slot if it were clip-routed to the last device
        rogue_then_valid = jnp.asarray([99, 3], jnp.int32)
        msg = MsgBatch(part=rogue_then_valid,
                       slot=jnp.zeros(2, jnp.int32),
                       vec=jnp.ones((2, 4), jnp.float32),
                       cnt=jnp.zeros(2, jnp.float32),
                       src_part=jnp.zeros(2, jnp.int32),
                       valid=jnp.ones(2, bool))
        (out,), _, rcpt = router.route_lanes((msg,), (init_defer(0, 6),))
        return (out.part, out.valid, router.psum(rcpt.rows),
                router.psum(rcpt.dropped))

    f = shard_map(prog, mesh=mesh, in_specs=(),
                  out_specs=(P("data"), P("data"), P(), P()),
                  check_rep=False)
    parts, valid, rows, dropped = jax.jit(f)()
    parts, valid = np.asarray(parts), np.asarray(valid)
    # device 3 receives the four valid records; nothing else arrives
    assert valid.sum() == 4
    np.testing.assert_array_equal(parts[valid], [3, 3, 3, 3])
    assert int(rows) == 4
    # rogue rows are masked out, not deferred/dropped (they never existed
    # as far as the wire is concerned — delivery could only drop them)
    assert int(dropped) == 0


# --------------------------------------- capped golden matrix (hub-heavy)

def run_capped(edges, feats, mesh, driver, backend, route_cap,
               route_defer_cap=None, window=None):
    window = window or win.WindowConfig(kind=win.STREAMING)
    model, params, pipe = build_pipe(window, mesh=mesh, route_cap=route_cap,
                                     route_defer_cap=route_defer_cap,
                                     backend=backend)
    if driver == "tick":
        pipe.run_stream(edges, feats, tick_edges=24)
        pipe.flush(max_ticks=256)
    else:
        pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
        pipe.flush_super(max_ticks=256, T=4)
    return model, params, pipe


CAPPED_MATRIX = [
    ("tick", "xla", 40), ("super", "xla", 40),
    ("tick", "xla", 2), ("super", "xla", 2),
    pytest.param("super", "pallas", 2, marks=pytest.mark.pallas),
]


@needs4
@pytest.mark.parametrize("driver,backend,cap", CAPPED_MATRIX)
def test_capped_golden_hub_heavy(driver, backend, cap):
    """route_cap < C on skewed traffic: converged state must match the
    LocalRouter reference and the static oracle; overflow defers (never
    drops) and every deferred row is re-emitted (exact agg counts)."""
    edges, feats = hub_stream()
    _, _, ref = run_capped(edges, feats, None, "tick", "xla", None)
    model, params, pipe = run_capped(edges, feats, make_stream_mesh(4),
                                     driver, backend, cap)
    assert_embeddings_close(ref.embeddings(), pipe.embeddings())
    # exact: every edge's RMI contributes once, deferred or not
    np.testing.assert_array_equal(np.asarray(pipe.states[0].agg_cnt),
                                  np.asarray(ref.states[0].agg_cnt))
    g, _ = build_snapshot(edges, feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)
    assert pipe.metrics.route_dropped == 0, \
        "correctly-sized defer rings must never drop"
    if cap <= 2:
        assert pipe.metrics.route_deferred > 0, \
            "a tiny bucket under hub traffic must exercise the defer path"
    # capped wire must be measurably smaller than the dense wire
    _, _, dense = run_capped(edges, feats, make_stream_mesh(4), driver,
                             backend, None)
    assert pipe.metrics.wire_bytes < dense.metrics.wire_bytes
    assert dense.metrics.route_deferred == 0


@needs4
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_capped_golden_all_policies(window):
    """The C//D cap across all four window policies (super-tick, xla)."""
    edges, feats = hub_stream(seed=5)
    _, _, ref = run_capped(edges, feats, None, "tick", "xla", None,
                           window=window)
    model, params, pipe = run_capped(edges, feats, make_stream_mesh(4),
                                     "super", "xla", 40, window=window)
    assert_embeddings_close(ref.embeddings(), pipe.embeddings())
    np.testing.assert_array_equal(np.asarray(pipe.states[0].agg_cnt),
                                  np.asarray(ref.states[0].agg_cnt))
    assert pipe.metrics.route_dropped == 0


@needs4
def test_starved_defer_ring_drops_loudly():
    """route_defer_cap=0 disables deferral: bucket overflow must surface
    in route_dropped instead of passing silently."""
    edges, feats = hub_stream(seed=7)
    _, _, pipe = build_pipe(win.WindowConfig(kind=win.STREAMING),
                            mesh=make_stream_mesh(4), route_cap=1,
                            route_defer_cap=0)
    pipe.run_stream(edges[:48], feats, tick_edges=24)
    assert pipe.metrics.route_dropped > 0
    assert pipe.metrics.route_deferred == 0


@needs4
def test_capped_wire_lane_answers_all_queries():
    """Link-tail wire records carried by backpressure must all answer
    eventually — the wire-backlog quiescence vote keeps flush() ticking
    until the ring drains."""
    from repro.serve.query import KIND_LINK
    edges, feats = hub_stream(seed=9)
    _, _, pipe = build_pipe(win.WindowConfig(kind=win.STREAMING),
                            mesh=make_stream_mesh(4), route_cap=2,
                            query_cap=8)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=256)
    # a burst of cross-device link queries: heads all fire in one tick,
    # the tail fan-in to the hubs' device exceeds the 2-row bucket
    heads = np.unique(edges[:, 0])[:8]
    qs = [(i, KIND_LINK, int(heads[i]), i % 3, False) for i in range(8)]
    pipe.tick(queries=qs)
    pipe.flush(max_ticks=256)
    ans = pipe.drain_answers()
    assert sorted(ans["qid"].tolist()) == list(range(8))
    assert ans["ok"].all()
    assert pipe.metrics.route_dropped == 0


# ------------------------------------------------- subprocess (forced 4)

def _run_forced4(pytest_args, timeout=540):
    return run_forced_devices(4, Path(__file__), pytest_args, timeout)


def test_capped_golden_forced4_subprocess():
    """Fast-lane smoke on any machine: the tiny-cap overflow-defer
    regression + the misrouting regression on a forced 4-device CPU."""
    r = _run_forced4(["-k", "(test_capped_golden_hub_heavy and tick-xla-2)"
                            " or test_invalid_part_masked_out_of_exchange"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_capped_full_matrix_forced4_subprocess():
    """Slow lane: the whole capped matrix + policies + wire tests under a
    forced 4-device CPU (the CI mesh lane runs them in-process)."""
    r = _run_forced4(["-k", "capped or invalid_part or starved"],
                     timeout=1200)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
