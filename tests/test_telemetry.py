"""Telemetry plane (ISSUE 9): occupancy-exactness on golden streams
(every gauge equals the integer count derivable from the plain
LocalRouter run), trace recorder roundtrip + schema gating, cost-model
coefficient recovery on synthetic traces, advisor recommendations
validated by zero-drop replay, and mesh parity at forced-4 (defer-ring
gauges vs the `defer_occupancy` oracle, telemetry on == off golden).
"""
import json
from pathlib import Path

import numpy as np
import jax
import pytest

from conftest import needs_devices, run_forced_devices
from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.state import defer_occupancy
from repro.graph.sage import GraphSAGE
from repro.telemetry.advisor import (apply_recommendation, recommend,
                                     replay_ok)
from repro.telemetry.cost_model import CostModel, FEATURES, fit_cost_model
from repro.telemetry.trace import (TRACE_DEVICE_COLS, TRACE_HOST_COLS,
                                   Trace, TraceRecorder, load_trace)

N_NODES, D_IN = 32, 8

needs4 = needs_devices(4)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]

FLUSH_TICKS = 8


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window=None, telemetry=False, mesh=None, **cfg_kw):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    kw = dict(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
              feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
              window=window or win.WindowConfig(kind=win.STREAMING),
              telemetry=telemetry)
    kw.update(cfg_kw)
    return model, params, D3Pipeline(model, params, PipelineConfig(**kw),
                                     mesh=mesh)


def drive(pipe, e_chunks, f_chunks, driver):
    """Fixed tick sequence (chunks + FLUSH_TICKS empty ticks) so every
    pipeline in a test sees identical tick boundaries."""
    if driver == "tick":
        for e, f in zip(e_chunks, f_chunks):
            pipe.tick(e, f)
        for _ in range(FLUSH_TICKS):
            pipe.tick()
    else:
        pipe.run_super_tick(e_chunks, f_chunks, T=len(e_chunks))
        pipe.run_super_tick(T=FLUSH_TICKS)
    return pipe


# ------------------------------------- occupancy exactness (golden, local)

@pytest.mark.parametrize("driver", ["tick", "super"])
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_occupancy_exactness_local(window, driver, tmp_path):
    """Every per-plane occupancy column equals the exact integer count
    from the plain (telemetry=False) per-tick LocalRouter run, on both
    drivers, and the traced pipeline's numerics are bit-identical."""
    edges, feats = make_stream()
    _, _, ref = build_pipe(window)
    e_chunks, f_chunks = ref.chunk_stream(edges, feats, 24)
    ref_rows = []
    for e, f in zip(e_chunks, f_chunks):
        ref_rows.append(ref.tick(e, f))
    for _ in range(FLUSH_TICKS):
        ref_rows.append(ref.tick())

    _, _, tel = build_pipe(window, telemetry=True)
    drive(tel, e_chunks, f_chunks, driver)
    cols = tel.trace.columns()
    T = len(ref_rows)
    assert len(tel.trace) == T

    exact = {
        "emitted_final": [int(r[-1].emitted) for r in ref_rows],
        "emitted_sum": [sum(int(s.emitted) for s in r) for r in ref_rows],
        "reduce_msgs": [sum(int(s.reduce_msgs) for s in r)
                        for r in ref_rows],
        "broadcast_msgs": [sum(int(s.broadcast_msgs) for s in r)
                           for r in ref_rows],
        "dropped": [sum(int(s.dropped) for s in r) for r in ref_rows],
        "suppressed": [sum(int(s.n_suppressed) for s in r)
                       for r in ref_rows],
        "outbox_demand": [max(int(s.emitted) + int(s.dropped) for s in r)
                          for r in ref_rows],
    }
    for col, want in exact.items():
        np.testing.assert_array_equal(cols[col], want, err_msg=col)
    # per-part demand peak: not derivable from the psum'd scalars, but
    # tightly bracketed by them — per layer the hottest part carries at
    # least the global demand / n_parts and at most all of it
    demand = np.asarray(exact["outbox_demand"])
    pp = cols["outbox_part_peak"]
    assert (pp >= -(-demand // 4)).all() and (pp <= demand).all()
    # LocalRouter: no wire, no route buckets, no defer rings — exactly 0
    for col in ("wire_rows", "route_deferred", "route_dropped",
                "occ_bc_defer", "occ_rmi_defer", "route_peak"):
        assert cols[col].sum() == 0, col
    # query/training planes compiled away -> their gauges are exactly 0
    for col in ("query_pending", "query_backlog", "train_labeled",
                "train_dirty", "q_admitted"):
        assert cols[col].sum() == 0, col
    # the untraced TickStats gauges are static zeros (compile-away knob)
    assert all(int(s.occ_bc_defer) == 0 and int(s.route_peak) == 0
               and int(s.outbox_part_peak) == 0
               for r in ref_rows for s in r)
    # telemetry on is numerically bit-identical to off
    np.testing.assert_array_equal(np.asarray(tel.sink),
                                  np.asarray(ref.sink))
    assert tel.metrics.emitted_total == ref.metrics.emitted_total
    # host columns: monotone tick clock, ingest counts, wall timings
    np.testing.assert_array_equal(cols["tick"], np.arange(T))
    np.testing.assert_array_equal(
        cols["edges_in"][:len(e_chunks)], [len(e) for e in e_chunks])
    assert (cols["wall_s"] > 0).all()
    assert cols["amortized"].all() if driver == "super" \
        else not cols["amortized"].any()
    # trace survives a disk roundtrip
    tel.save_trace(tmp_path / "t.npz")
    back = load_trace(tmp_path / "t.npz")
    for c in TRACE_DEVICE_COLS:
        np.testing.assert_array_equal(back.col(c), cols[c])


def test_query_plane_occupancy_gauges():
    """query_pending equals the device's held-slot population after each
    tick; q_admitted/q_answered match the flow counters."""
    from repro.serve.query import KIND_EMBED
    edges, feats = make_stream()
    _, _, pipe = build_pipe(telemetry=True, query_cap=8)
    pipe.run_stream(edges[:48], feats, tick_edges=24)
    base = len(pipe.trace)
    u = int(edges[0, 0])
    pipe.tick(edges[48:72], queries=[(1, KIND_EMBED, u, True),
                                     (2, KIND_EMBED, u, False)])
    held = int(np.asarray(jax.device_get(pipe.queries.pending)).sum())
    cols = pipe.trace.columns()
    assert cols["query_pending"][base] == held
    assert cols["q_admitted"][base] == 2
    assert cols["queries_in"][base] == 2
    pipe.flush(max_ticks=64)
    cols = pipe.trace.columns()
    assert cols["q_answered"].sum() == 2
    assert cols["query_pending"][-1] == 0


# --------------------------------------------- trace recorder & loader

def test_trace_roundtrip_schema_and_validation(tmp_path):
    rec = TraceRecorder(meta={"n_parts": 4})
    assert rec.meta["schema"] == 1
    row = np.arange(len(TRACE_DEVICE_COLS))
    rec.append({"tick": 0, "wall_s": 0.25, "edges_in": 7}, row)
    rec.append({"tick": 1, "wall_s": 0.5}, row * 2)
    rec.annotate(serving_p99_ms=3.5)
    with pytest.raises(ValueError, match="columns"):
        rec.append({"tick": 2}, np.zeros(3))
    p = tmp_path / "trace.npz"
    rec.save(p)
    tr = load_trace(p)
    assert len(tr) == 2
    assert tr.meta["n_parts"] == 4 and tr.meta["serving_p99_ms"] == 3.5
    np.testing.assert_array_equal(tr.col("route_peak"),
                                  [row[11], 2 * row[11]])
    np.testing.assert_allclose(tr.col("wall_s"), [0.25, 0.5])
    assert tr.col("edges_in")[0] == 7 and tr.col("edges_in")[1] == 0
    assert set(tr.columns) == set(TRACE_HOST_COLS + TRACE_DEVICE_COLS)
    # wrong schema version is rejected
    rec.meta["schema"] = 99
    rec.save(p)
    with pytest.raises(ValueError, match="schema"):
        load_trace(p)
    # a random npz is not a trace
    np.savez(tmp_path / "junk.npz", a=np.zeros(3))
    with pytest.raises(ValueError, match="meta"):
        load_trace(tmp_path / "junk.npz")


def test_defer_occupancy_oracle_helper():
    from dataclasses import replace as rep
    from repro.core.state import init_layer
    ls = init_layer(4, 8, D_IN, D_IN, bc_defer_rows=6, rmi_defer_rows=4)
    b, r = defer_occupancy(ls)
    assert (int(b), int(r)) == (0, 0)
    import jax.numpy as jnp
    ls = rep(ls, bc_defer_ok=jnp.array([1, 0, 1, 1, 0, 0], bool),
             rmi_defer_ok=jnp.array([0, 1, 0, 0], bool))
    b, r = defer_occupancy(ls)
    assert (int(b), int(r)) == (3, 1)


# ------------------------------------------------------------ cost model

def _synthetic_trace(T=64, seed=0, c0=2e-3, per_row=None):
    rng = np.random.default_rng(seed)
    cols = {c: np.zeros(T, np.int64)
            for c in TRACE_HOST_COLS + TRACE_DEVICE_COLS}
    cols["tick"] = np.arange(T)
    cols["ticks"] = np.ones(T, np.int64)
    cols["amortized"] = np.ones(T, np.int64)
    cols["emitted_sum"] = rng.integers(0, 200, T)
    cols["wire_rows"] = rng.integers(0, 400, T)
    cols["reduce_msgs"] = rng.integers(0, 300, T)
    cols["edges_in"] = rng.integers(0, 64, T)
    per_row = per_row or {"compute_rows": 4e-6, "wire_rows": 1e-6,
                          "deliver_rows": 2e-6, "ingest_rows": 8e-6}
    wall = np.full(T, c0)
    wall += per_row.get("compute_rows", 0) * cols["emitted_sum"]
    wall += per_row.get("wire_rows", 0) * cols["wire_rows"]
    wall += per_row.get("deliver_rows", 0) * cols["reduce_msgs"]
    wall += per_row.get("ingest_rows", 0) * cols["edges_in"]
    cols["wall_s"] = wall
    meta = {"schema": 1, "n_parts": 4, "n_devices": 4, "n_stages": 1,
            "route_cap": None, "wire_lanes": [[100, 13], [160, 13]],
            "a2a_mult": 64, "fixed_wire_bytes": 1000,
            "wire_bytes_per_tick": 1000 + 64 * (100 + 160) * 13}
    cols = {k: np.asarray(v, np.float64 if k in ("wall_s", "host_s")
                          else np.int64) for k, v in cols.items()}
    return Trace(meta, cols)


def test_cost_model_recovers_synthetic_coefficients():
    tr = _synthetic_trace()
    cm = fit_cost_model(tr)
    assert abs(cm.intercept - 2e-3) < 1e-7
    for k, want in (("compute_rows", 4e-6), ("wire_rows", 1e-6),
                    ("deliver_rows", 2e-6), ("ingest_rows", 8e-6)):
        assert abs(cm.coef[k] - want) < 1e-9, k
    assert cm.coef["query_rows"] == 0.0 and cm.coef["train_rows"] == 0.0
    rep = cm.report(tr, tol=0.25)
    assert rep["n"] == len(tr) and rep["hit_frac"] == 1.0
    # serialization roundtrip
    cm2 = CostModel.from_dict(json.loads(json.dumps(cm.to_dict())))
    np.testing.assert_allclose(cm2.predict(tr.columns),
                               cm.predict(tr.columns))
    with pytest.raises(ValueError, match="schema"):
        CostModel.from_dict({"schema": 0, "intercept": 0, "coef": {}})


def test_cost_model_what_if_reprices_wire_exactly():
    tr = _synthetic_trace()
    cm = fit_cost_model(tr)
    # dense (recorded) config reproduces the recorded byte count
    assert cm.wire_bytes_at() == tr.meta["wire_bytes_per_tick"]
    # a capped exchange shrinks every lane to route_cap rows
    assert cm.wire_bytes_at(route_cap=8) == 1000 + 64 * (8 + 8) * 13
    wi = cm.what_if(tr, route_cap=8)
    assert wi["wire_bytes_delta"] == (8 + 8 - 100 - 160) * 13 * 64
    assert wi["wire_delta_s"] < 0 and wi["pred_tick_s"] > 0
    # doubling the data axis rescales the a2a multiplier (4->8: x4)
    assert cm.wire_bytes_at(n_devices=8) == \
        2 * 1000 + 4 * 64 * (100 + 160) * 13


def test_cost_model_masks_compile_spikes():
    tr = _synthetic_trace()
    tr.columns  # no-op sanity
    cols = {k: v.copy() for k, v in tr.columns.items()}
    cols["wall_s"][0] = 50.0          # jit-compile spike
    spiked = Trace(tr.meta, cols)
    cm = fit_cost_model(spiked)
    assert abs(cm.intercept - 2e-3) < 1e-6
    rep = cm.report(spiked, tol=0.25)
    assert rep["n"] == len(spiked) - 1 and rep["hit_frac"] == 1.0


# --------------------------------------------------------------- advisor

def test_advisor_zero_drop_recommendation_replays_clean(tmp_path):
    """The full loop the CI bench lane runs, locally: record -> recommend
    -> validate bounds -> replay through the real pipeline with zero
    drops and identical numerics."""
    edges, feats = make_stream(n_edges=160)
    model, params, pipe = build_pipe(telemetry=True)
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=64, T=4)
    pipe.save_trace(tmp_path / "TRACE.npz")
    trace = load_trace(tmp_path / "TRACE.npz")
    recs = recommend(trace)
    caps = recs["caps"]
    assert caps["outbox_cap"] % 4 == 0
    assert caps["outbox_cap"] >= trace.col("outbox_demand").max()
    assert caps["outbox_cap"] >= 4 * trace.col("outbox_part_peak").max()
    assert caps["edge_tick_cap"] >= trace.col("edges_in").max()
    assert caps["route_cap"] is None          # LocalRouter: no buckets
    assert caps["query_cap"] == 0 and caps["train_cap"] == 0
    assert recs["basis"]["ticks"] == len(trace)

    cfg2 = apply_recommendation(
        PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                       max_nodes=N_NODES), recs)
    cfg2.validate()
    pipe2 = D3Pipeline(model, params, cfg2)
    pipe2.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe2.flush_super(max_ticks=64, T=4)
    out = replay_ok(pipe2)
    assert out["dropped"] == 0 and out["route_dropped"] == 0
    np.testing.assert_array_equal(np.asarray(pipe2.sink),
                                  np.asarray(pipe.sink))


def test_advisor_cli(tmp_path):
    from repro.telemetry.advisor import main
    edges, feats = make_stream(n_edges=80)
    _, _, pipe = build_pipe(telemetry=True)
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.save_trace(tmp_path / "TRACE.npz")
    out = tmp_path / "RECS.json"
    assert main([str(tmp_path / "TRACE.npz"), "--out", str(out),
                 "--slack", "1.5"]) == 0
    recs = json.loads(out.read_text())
    assert recs["schema"] == 1 and recs["slack"] == 1.5
    assert recs["caps"]["outbox_cap"] >= 4


# ----------------------------------------- mesh parity (>= 4 devices)

@needs4
def test_mesh_telemetry_exactness_and_parity(tmp_path):
    """Forced-4 mesh with a capped exchange: the defer-ring gauges equal
    the `defer_occupancy` oracle on the end-of-tick carry, route_peak is
    live, telemetry on == off bit-for-bit, the super-tick driver's
    device rows equal the per-tick driver's, and the advisor's
    recommended caps replay with zero drops and less wire than dense."""
    from repro.launch.mesh import make_stream_mesh
    edges, feats = make_stream(n_edges=140)
    mesh = make_stream_mesh(4)
    capped = dict(route_cap=8, route_defer_cap=64)

    _, _, tel = build_pipe(telemetry=True, mesh=mesh, **capped)
    e_chunks, f_chunks = tel.chunk_stream(edges, feats, 24)
    oracle_bc, oracle_rmi = [], []
    for e, f in zip(e_chunks, f_chunks):
        tel.tick(e, f)
        occ = [defer_occupancy(ls) for ls in tel.states]
        oracle_bc.append(sum(int(b) for b, _ in occ))
        oracle_rmi.append(sum(int(r) for _, r in occ))
    for _ in range(FLUSH_TICKS):
        tel.tick()
        occ = [defer_occupancy(ls) for ls in tel.states]
        oracle_bc.append(sum(int(b) for b, _ in occ))
        oracle_rmi.append(sum(int(r) for _, r in occ))
    cols = tel.trace.columns()
    np.testing.assert_array_equal(cols["occ_bc_defer"], oracle_bc)
    np.testing.assert_array_equal(cols["occ_rmi_defer"], oracle_rmi)
    assert cols["route_peak"].max() > 0
    # every pre-cap demand row ships, defers, or drops in its tick
    assert (cols["route_peak"] <= cols["wire_rows"]
            + cols["route_deferred"] + cols["route_dropped"]).all()
    assert tel.metrics.route_peak == cols["route_peak"].max()
    assert tel.metrics.outbox_peak == cols["outbox_demand"].max()
    assert cols["outbox_part_peak"].max() > 0
    assert tel.metrics.outbox_part_peak == cols["outbox_part_peak"].max()

    # telemetry off: identical numerics (bit-for-bit golden)
    _, _, off = build_pipe(mesh=mesh, **capped)
    for e, f in zip(e_chunks, f_chunks):
        off.tick(e, f)
    for _ in range(FLUSH_TICKS):
        off.tick()
    np.testing.assert_array_equal(np.asarray(tel.sink),
                                  np.asarray(off.sink))
    assert tel.metrics.emitted_total == off.metrics.emitted_total
    assert tel.metrics.wire_rows == off.metrics.wire_rows

    # super-tick driver: same tick boundaries -> identical device rows
    _, _, sup = build_pipe(telemetry=True, mesh=mesh, **capped)
    drive(sup, e_chunks, f_chunks, "super")
    sup_cols = sup.trace.columns()
    for c in TRACE_DEVICE_COLS:
        np.testing.assert_array_equal(sup_cols[c], cols[c], err_msg=c)

    # advisor: record the observability trace DENSE (peaks recorded
    # under a capped config are only valid for that config's deferral
    # dynamics), then the zero-defer sizing route_cap = max route_peak
    # replays bit-identically to dense with strictly less wire
    model, params, dense = build_pipe(telemetry=True, mesh=mesh)
    drive(dense, e_chunks, f_chunks, "super")
    dense.save_trace(tmp_path / "MESH.npz")
    trace = load_trace(tmp_path / "MESH.npz")
    recs = recommend(trace)
    assert recs["caps"]["route_cap"] == \
        int(dense.trace.columns()["route_peak"].max())
    cfg2 = apply_recommendation(
        PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                       max_nodes=N_NODES), recs)
    rep = D3Pipeline(model, params, cfg2, mesh=mesh)
    drive(rep, e_chunks, f_chunks, "super")
    replay_ok(rep)
    assert rep._wire_bytes_per_tick <= dense._wire_bytes_per_tick
    assert rep.metrics.route_deferred == 0   # zero-defer sizing held
    np.testing.assert_array_equal(np.asarray(rep.sink),
                                  np.asarray(dense.sink))


def test_telemetry_forced4_subprocess():
    r = run_forced_devices(4, Path(__file__),
                           ["-k", "mesh_telemetry"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
