"""Per-kernel interpret-mode validation: shape/dtype sweeps against the
pure-jnp oracles (kernels are TPU-targeted; CPU interpret checks the body)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is an optional [test] extra: the property test below is only
# defined when it is importable; the deterministic sweeps always run
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.segment_reduce.ops import gather_segment_sum
from repro.kernels.segment_reduce.ref import gather_segment_sum_ref


# ------------------------------------------------------------ segment_reduce
@pytest.mark.parametrize("N,E,d,be,bv", [
    (100, 400, 16, 128, 64),
    (257, 1000, 32, 128, 64),
    (64, 64, 8, 64, 64),
    (1000, 3000, 64, 256, 128),
])
def test_segment_reduce_shapes(N, E, d, be, bv):
    rng = np.random.default_rng(N + E)
    x = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    s = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    r = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    mask = jnp.asarray(rng.random(E) > 0.3)
    out = gather_segment_sum(x, s, r, N, mask, block_e=be, block_v=bv)
    ref = gather_segment_sum_ref(x, s, r, N, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_segment_reduce_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32)).astype(dtype)
    s = jnp.asarray(rng.integers(0, 64, 200).astype(np.int32))
    r = jnp.asarray(rng.integers(0, 64, 200).astype(np.int32))
    out = gather_segment_sum(x, s, r, 64, None, block_e=64, block_v=64)
    ref = gather_segment_sum_ref(x, s, r, 64, None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


if HAS_HYPOTHESIS:
    @given(st.integers(2, 80), st.integers(1, 300), st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_segment_reduce_property(n, e, dq):
        d = dq * 8
        rng = np.random.default_rng(n * e)
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        s = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        r = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        out = gather_segment_sum(x, s, r, n, None, block_e=64, block_v=32)
        ref = gather_segment_sum_ref(x, s, r, n, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="property tests need the optional [test] extra")
    def test_segment_reduce_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------- flash_attention
@pytest.mark.parametrize("B,S,H,Kh,D,bq,bk", [
    (2, 128, 4, 2, 32, 64, 64),
    (1, 256, 8, 8, 16, 128, 64),
    (2, 64, 4, 1, 64, 64, 64),
    (1, 512, 2, 2, 128, 256, 256),
])
def test_flash_attention_shapes(B, S, H, Kh, D, bq, bk):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = gqa_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = gqa_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = gqa_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("V,d,B,W,mode", [
    (1000, 32, 128, 8, "mean"),
    (500, 64, 64, 4, "sum"),
    (100, 16, 256, 2, "mean"),
    (2048, 128, 64, 16, "sum"),
])
def test_embedding_bag_shapes(V, d, B, W, mode):
    rng = np.random.default_rng(V + B)
    table = jnp.asarray(rng.normal(size=(V, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, V, (B, W)).astype(np.int32))
    out = embedding_bag(table, ids, mode=mode, block_b=32)
    ref = embedding_bag_ref(table, ids, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding_row():
    table = jnp.ones((10, 8), jnp.float32)
    ids = jnp.full((32, 4), -1, jnp.int32)
    out = embedding_bag(table, ids, mode="mean", block_b=32)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
