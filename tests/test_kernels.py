"""Per-kernel interpret-mode validation: shape/dtype sweeps against the
pure-jnp oracles (kernels are TPU-targeted; CPU interpret checks the body)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is an optional [test] extra: the property test below is only
# defined when it is importable; the deterministic sweeps always run
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.segment_reduce.ops import (gather_segment_sum, mean_rows,
                                              rmi_apply_read, segment_deliver,
                                              segment_sum_sorted)
from repro.kernels.segment_reduce.ref import (gather_segment_sum_ref,
                                              rmi_apply_read_ref,
                                              segment_deliver_ref,
                                              segment_sum_sorted_ref)


# ------------------------------------------------------------ segment_reduce
@pytest.mark.parametrize("N,E,d,be,bv", [
    (100, 400, 16, 128, 64),
    (257, 1000, 32, 128, 64),
    (64, 64, 8, 64, 64),
    (1000, 3000, 64, 256, 128),
])
def test_segment_reduce_shapes(N, E, d, be, bv):
    rng = np.random.default_rng(N + E)
    x = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    s = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    r = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    mask = jnp.asarray(rng.random(E) > 0.3)
    out = gather_segment_sum(x, s, r, N, mask, block_e=be, block_v=bv)
    ref = gather_segment_sum_ref(x, s, r, N, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_segment_reduce_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32)).astype(dtype)
    s = jnp.asarray(rng.integers(0, 64, 200).astype(np.int32))
    r = jnp.asarray(rng.integers(0, 64, 200).astype(np.int32))
    out = gather_segment_sum(x, s, r, 64, None, block_e=64, block_v=64)
    ref = gather_segment_sum_ref(x, s, r, 64, None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


if HAS_HYPOTHESIS:
    @given(st.integers(2, 80), st.integers(1, 300), st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_segment_reduce_property(n, e, dq):
        d = dq * 8
        rng = np.random.default_rng(n * e)
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        s = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        r = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        out = gather_segment_sum(x, s, r, n, None, block_e=64, block_v=32)
        ref = gather_segment_sum_ref(x, s, r, n, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="property tests need the optional [test] extra")
    def test_segment_reduce_property():
        pytest.importorskip("hypothesis")


# ------------------------------- delivery variants (ISSUE 3) — `-m pallas`

def _deliver_case(seed, C, R, d):
    rng = np.random.default_rng(seed)
    # ragged random segments, including out-of-range sentinels both sides
    idx = jnp.asarray(rng.integers(-2, R + 4, C).astype(np.int32))
    vec = jnp.asarray(rng.normal(size=(C, d)).astype(np.float32))
    cnt = jnp.asarray(rng.integers(-1, 3, C).astype(np.float32))
    return idx, vec, cnt


@pytest.mark.pallas
@pytest.mark.parametrize("mode", ["add", "set"])
@pytest.mark.parametrize("C,R,d", [(64, 37, 6), (7, 129, 4), (300, 9, 8)])
def test_segment_deliver_matches_ref(mode, C, R, d):
    idx, vec, cnt = _deliver_case(C * R, C, R, d)
    out = segment_deliver(idx, vec, cnt, R, mode=mode,
                          block_e=64, block_v=64)
    ref = segment_deliver_ref(idx, vec, cnt, R, mode=mode)
    for got, want in zip(out, ref):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.pallas
def test_segment_deliver_set_last_writer_wins():
    """Duplicate destinations under mode="set" must resolve to the record
    with the highest position — XLA scatter-set update order."""
    idx = jnp.asarray([3, 5, 3, 3, 5], jnp.int32)
    vec = jnp.arange(10, dtype=jnp.float32).reshape(5, 2)
    cnt = jnp.arange(5, dtype=jnp.float32)
    v, c, t = segment_deliver(idx, vec, cnt, 8, mode="set",
                              block_e=64, block_v=64)
    np.testing.assert_array_equal(np.asarray(v[3]), [6.0, 7.0])   # record 3
    np.testing.assert_array_equal(np.asarray(v[5]), [8.0, 9.0])   # record 4
    assert float(c[3]) == 3.0 and float(c[5]) == 4.0
    np.testing.assert_array_equal(
        np.asarray(t), [False, False, False, True, False, True, False, False])


@pytest.mark.pallas
@pytest.mark.parametrize("mode", ["add", "set"])
def test_segment_deliver_all_padding(mode):
    """Every record invalid: zero payload, nothing touched."""
    idx = jnp.full((32,), 99, jnp.int32)
    v, c, t = segment_deliver(idx, jnp.ones((32, 3)), jnp.ones((32,)), 16,
                              mode=mode, block_e=64, block_v=64)
    assert not bool(t.any())
    np.testing.assert_array_equal(np.asarray(v), 0.0)
    np.testing.assert_array_equal(np.asarray(c), 0.0)


@pytest.mark.pallas
def test_segment_deliver_single_segment():
    """All records land on one row (the worst-case hot destination)."""
    C, R, d = 96, 40, 5
    rng = np.random.default_rng(7)
    vec = jnp.asarray(rng.normal(size=(C, d)).astype(np.float32))
    cnt = jnp.ones((C,), jnp.float32)
    idx = jnp.full((C,), 11, jnp.int32)
    v, c, t = segment_deliver(idx, vec, cnt, R, mode="add",
                              block_e=64, block_v=64)
    np.testing.assert_allclose(np.asarray(v[11]), np.asarray(vec.sum(0)),
                               rtol=1e-5, atol=1e-5)
    assert float(c[11]) == C and bool(t[11]) and int(t.sum()) == 1


@pytest.mark.pallas
def test_rmi_apply_read_fused_matches_ref():
    rng = np.random.default_rng(3)
    R, C, K, d = 70, 50, 12, 6
    agg = jnp.asarray(rng.normal(size=(R, d)).astype(np.float32))
    cnt = jnp.asarray(rng.integers(0, 4, R).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, R + 6, C).astype(np.int32))
    vec = jnp.asarray(rng.normal(size=(C, d)).astype(np.float32))
    dcnt = jnp.asarray(rng.integers(0, 2, C).astype(np.float32))
    ridx = jnp.asarray(rng.integers(0, R, K).astype(np.int32))
    out = rmi_apply_read(agg, cnt, idx, vec, dcnt, ridx,
                         block_e=64, block_v=64, block_r=64)
    ref = rmi_apply_read_ref(agg, cnt, idx, vec, dcnt, ridx)
    for got, want in zip(out, ref):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.pallas
def test_mean_rows_empty_count_reads_zero():
    sums = jnp.asarray([[4.0, 8.0], [0.0, 0.0], [3.0, 3.0]])
    cnts = jnp.asarray([2.0, 0.0, 1.0])
    out = mean_rows(sums, cnts, block_r=64)
    np.testing.assert_allclose(np.asarray(out),
                               [[2.0, 4.0], [0.0, 0.0], [3.0, 3.0]])


@pytest.mark.pallas
def test_mean_rows_stale_residual_on_emptied_neighborhood():
    """Remove-to-empty regression (ISSUE 6): a neighborhood whose count
    was driven to 0 (or negative) by remove/replace RMIs can keep a
    NONZERO f32 residual in sigma — the old clamp-to-1 divide read that
    stale `sigma/1` back. The contract is: cnt <= 0 reads ZEROS, on the
    kernel path, the XLA reader, and the fused-apply oracle alike."""
    from repro.core.aggregators import mean_read
    sums = jnp.asarray([[4.0, 8.0], [2.5, -1.0], [3.0, 3.0], [7.0, 7.0]])
    cnts = jnp.asarray([2.0, 0.0, 1.0, -1.0])     # stale rows 1 and 3
    want = [[2.0, 4.0], [0.0, 0.0], [3.0, 3.0], [0.0, 0.0]]
    np.testing.assert_allclose(
        np.asarray(mean_rows(sums, cnts, block_r=64)), want)
    np.testing.assert_allclose(np.asarray(mean_read(sums, cnts)), want)


@pytest.mark.pallas
def test_rmi_remove_to_empty_reads_zero():
    """End-to-end remove: reduce a message in, remove it back out — the
    fused apply+read must return zeros for the emptied row even though
    f32 cancellation leaves sigma only approximately zero; and a pure
    REMOVE record (negative count) onto an already-empty row must not
    resurrect the subtracted payload as a read value."""
    d = 4
    agg = jnp.zeros((3, d), jnp.float32)
    cnt = jnp.zeros((3,), jnp.float32)
    msg = jnp.asarray([[0.3, -1.7, 2.2, 0.9]], jnp.float32)
    # reduce(msg) then remove(msg) on row 1; plain remove on row 2
    idx = jnp.asarray([1, 1, 2], jnp.int32)
    vec = jnp.concatenate([msg, -msg, -msg])
    dcnt = jnp.asarray([1.0, -1.0, -1.0], jnp.float32)
    ridx = jnp.asarray([0, 1, 2], jnp.int32)
    for impl in (rmi_apply_read,
                 rmi_apply_read_ref):
        agg2, cnt2, _, reads = impl(agg, cnt, idx, vec, dcnt, ridx)
        assert float(cnt2[1]) == 0.0 and float(cnt2[2]) == -1.0
        np.testing.assert_array_equal(np.asarray(reads[1]), np.zeros(d))
        np.testing.assert_array_equal(np.asarray(reads[2]), np.zeros(d))


@pytest.mark.pallas
def test_segment_sum_sorted_trims_off_by_block_tail():
    """Regression: segment_sum_sorted used to return the block-padded
    [n_segments_pad, d] array and rely on every caller to slice."""
    rng = np.random.default_rng(5)
    E, n_seg, d = 150, 100, 4            # 100 is NOT a multiple of block_v
    ids = jnp.sort(jnp.asarray(rng.integers(0, n_seg + 10, E),
                               dtype=jnp.int32))
    msgs = jnp.asarray(rng.normal(size=(E, d)).astype(np.float32))
    out = segment_sum_sorted(msgs, ids, n_seg, block_e=64, block_v=64)
    assert out.shape == (n_seg, d)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(segment_sum_sorted_ref(
                                   msgs, ids, n_seg)),
                               rtol=1e-5, atol=1e-5)
    # the block-aligned opt-out keeps the old padded contract, zero tail
    padded = segment_sum_sorted(msgs, ids, n_seg, block_e=64, block_v=64,
                                trim=False)
    assert padded.shape == (128, d)
    np.testing.assert_allclose(np.asarray(padded[:n_seg]), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(padded[n_seg:]), 0.0)


if HAS_HYPOTHESIS:
    @pytest.mark.pallas
    @given(st.integers(0, 10_000), st.integers(1, 200), st.integers(2, 60),
           st.integers(1, 6), st.sampled_from(["add", "set"]))
    @settings(max_examples=25, deadline=None)
    def test_segment_deliver_property(seed, C, R, dq, mode):
        d = dq * 2
        idx, vec, cnt = _deliver_case(seed, C, R, d)
        out = segment_deliver(idx, vec, cnt, R, mode=mode,
                              block_e=64, block_v=32)
        ref = segment_deliver_ref(idx, vec, cnt, R, mode=mode)
        for got, want in zip(out, ref):
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="property tests need the optional [test] extra")
    def test_segment_deliver_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------- flash_attention
@pytest.mark.parametrize("B,S,H,Kh,D,bq,bk", [
    (2, 128, 4, 2, 32, 64, 64),
    (1, 256, 8, 8, 16, 128, 64),
    (2, 64, 4, 1, 64, 64, 64),
    (1, 512, 2, 2, 128, 256, 256),
])
def test_flash_attention_shapes(B, S, H, Kh, D, bq, bk):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = gqa_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = gqa_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = gqa_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("V,d,B,W,mode", [
    (1000, 32, 128, 8, "mean"),
    (500, 64, 64, 4, "sum"),
    (100, 16, 256, 2, "mean"),
    (2048, 128, 64, 16, "sum"),
])
def test_embedding_bag_shapes(V, d, B, W, mode):
    rng = np.random.default_rng(V + B)
    table = jnp.asarray(rng.normal(size=(V, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, V, (B, W)).astype(np.int32))
    out = embedding_bag(table, ids, mode=mode, block_b=32)
    ref = embedding_bag_ref(table, ids, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding_row():
    table = jnp.ones((10, 8), jnp.float32)
    ids = jnp.full((32, 4), -1, jnp.int32)
    out = embedding_bag(table, ids, mode="mean", block_b=32)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
