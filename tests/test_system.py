"""End-to-end behaviour tests for the paper's system: the full lifecycle
(stream -> window -> train -> resume -> checkpoint -> recover) in one run,
plus the distributed-runtime modules (compression, EP, decode combine) and
the HLO analyzer the roofline rests on."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.ft.checkpoint import CheckpointManager
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.nn.layers import Linear
from repro.optim import adam, sgd


def test_full_lifecycle(tmp_path):
    """The quickstart + serve scenario as one assertive test."""
    rng = np.random.default_rng(0)
    n_nodes, d_in = 100, 8
    edges = powerlaw_edges(rng, n_nodes, 360)
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    labels = {v: int(rng.integers(0, 3)) for v in range(n_nodes)}

    model = GraphSAGE((d_in, 16, 16))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=192, edge_cap=1024,
                         repl_cap=512, feat_cap=1024, edge_tick_cap=128,
                         max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.ADAPTIVE))
    pipe = D3Pipeline(model, params, cfg)

    # phase 1: stream half, train, checkpoint
    half = len(edges) // 2
    pipe.run_stream(edges[:half], feats, tick_edges=64)
    head = Linear(16, 3)
    coord = TrainingCoordinator(
        pipe, head, head.init(jax.random.key(1)),
        TrainConfig(optimizer=sgd(), lr=0.05, batch_threshold=2))
    coord.observe_labels(labels)
    res = coord.train(epochs=2)
    assert res.losses[-1] <= res.losses[0]
    mgr = CheckpointManager(tmp_path)
    mgr.save_pipeline(step=1, pipe=pipe)

    # phase 2: crash, restore, stream the rest, verify vs oracle with the
    # POST-TRAINING parameters
    _, _, pipe2 = (model, params, D3Pipeline(model, params, cfg))
    mgr.restore_pipeline(pipe2)
    pipe2.run_stream(edges[half:], feats, tick_edges=64)
    pipe2.flush(max_ticks=128)
    g, _ = build_snapshot(edges, feats, d_in, n_nodes)
    ref = np.asarray(oracle_embeddings(model, pipe2.params, g))
    emb = pipe2.embeddings()
    touched = set(np.unique(edges).tolist())
    assert len(emb) == len(touched)
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-3, atol=1e-3)


def test_grad_compression_error_feedback():
    from repro.dist.grad_compression import (compress_decompress,
                                             init_error_feedback)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    res = init_error_feedback(g)
    # accumulated compressed steps track the true sum (error feedback):
    # the residual is bounded (~1/frac steps worth), so relative drift
    # decays like O(1/steps)
    total_sent = jnp.zeros((64, 64))
    total_true = jnp.zeros((64, 64))
    rels = []
    for step in range(32):
        sent, res = compress_decompress(g, res, int8=True, topk_frac=0.25)
        total_sent = total_sent + sent["w"]
        total_true = total_true + g["w"]
        rels.append(float(jnp.linalg.norm(total_sent - total_true)
                          / jnp.linalg.norm(total_true)))
    assert rels[-1] < 0.15, f"error feedback drift {rels[-1]}"
    assert rels[-1] < rels[3], "drift must decay with steps"


def test_int8_quant_roundtrip():
    from repro.dist.grad_compression import dequantize_int8, quantize_int8
    x = jnp.asarray(np.random.default_rng(1).normal(size=(128,)) * 5)
    q, s = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, s) - x).max())
    assert err <= float(s) * 0.5 + 1e-6


def test_decode_partial_combine_matches_full():
    """LSE-combined sharded decode == full attention."""
    from repro.nn.attention import (combine_partial_decodes, decode_attend,
                                    decode_attend_partial)
    rng = np.random.default_rng(0)
    B, T, Kh, G, D = 2, 64, 2, 3, 16
    H = Kh * G
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, Kh, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, Kh, D)).astype(np.float32))
    valid = jnp.asarray(rng.random((B, T)) > 0.1)
    full = decode_attend(q, k, v, valid)
    # shard the cache over 4 sequence chunks, combine partials
    outs, ms, ss = [], [], []
    for i in range(4):
        sl = slice(i * T // 4, (i + 1) * T // 4)
        o, m, s = decode_attend_partial(q, k[:, sl], v[:, sl], valid[:, sl])
        outs.append(o)
        ms.append(m)
        ss.append(s)
    comb = combine_partial_decodes(jnp.stack(outs), jnp.stack(ms),
                                   jnp.stack(ss))
    np.testing.assert_allclose(np.asarray(comb), np.asarray(full, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_ref():
    from repro.nn.attention import causal_mask, mha, mha_chunked
    rng = np.random.default_rng(0)
    B, S, H, Kh, D = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Kh, D)).astype(np.float32))
    ref = mha(q, k, v, mask=causal_mask(S, S))
    out = mha_chunked(q, k, v, q_chunk=32, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_hlo_analyzer_scan_flops():
    from repro.roofline.hlo_analyzer import analyze_hlo
    n, K = 64, 5

    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                         jax.ShapeDtypeStruct((K, n, n), jnp.float32)
                         ).compile()
    r = analyze_hlo(c.as_text())
    assert abs(r["flops"] / (K * 2 * n ** 3) - 1.0) < 1e-6


def test_moe_ep_matches_oracle():
    """shard_map EP dispatch == dense oracle at ample capacity."""
    import os
    from jax.sharding import PartitionSpec as P
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >= 2 devices (run in dryrun env)")
    from repro.dist.moe_ep import moe_ep_apply
    from repro.nn.moe import MoEConfig, MoELayer
    lay = MoELayer(32, MoEConfig(num_experts=4, top_k=2, d_ff=16,
                                 capacity_factor=8.0))
    params = lay.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (32, 32))
    mesh = jax.make_mesh((2,), ("m",))
    ep_params = dict(params)
    fn = jax.shard_map(
        lambda p, xx: moe_ep_apply(lay, p, xx, "m"),
        mesh=mesh,
        in_specs=({"router": P(), "wg": P("m"), "wu": P("m"), "wd": P("m")},
                  P()),
        out_specs=P())
    with mesh:
        out = fn(ep_params, x)
    ref, _ = lay.dense_oracle(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
