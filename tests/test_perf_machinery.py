"""Tests for the §Perf machinery: locality plan/step, 8-bit Adam, analyzer
DUS accounting. The multi-device locality equivalence runs in a
subprocess (the main suite pins one CPU device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_locality_plan_invariants():
    from repro.dist.gnn_locality import build_plan
    rng = np.random.default_rng(0)
    senders = rng.integers(0, 64, 300)
    receivers = rng.integers(0, 64, 300)
    plan = build_plan(senders, receivers, 64, 8)
    # every edge lands exactly once, on its receiver's shard
    assert plan.edge_mask.sum() == 300
    n_loc = plan.n_loc
    for s in range(8):
        rs = plan.receivers_local[s][plan.edge_mask[s]]
        assert (rs < n_loc).all()
    # halo indices stay within each shard's owned range
    for p in range(8):
        idx = plan.send_idx[p][plan.send_mask[p]]
        assert (idx < n_loc).all() and (idx >= 0).all()


@pytest.mark.slow
def test_locality_step_equals_global_step_multidevice():
    # 4 shards, not 8: XLA:CPU SPMD compile time grows superlinearly in the
    # forced device count (8-way takes ~8 min, 4-way seconds) while the
    # halo-exchange/psum semantics under test are identical
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.gnn_locality import build_plan, make_locality_train_step
        from repro.graph.graphs import Graph
        from repro.graph.pna import PNA
        from repro.optim import adam, apply_updates, clip_by_global_norm

        rng = np.random.default_rng(0)
        n_nodes, n_edges, d, ncls, S = 64, 300, 8, 4, 4
        senders = rng.integers(0, n_nodes, n_edges)
        receivers = rng.integers(0, n_nodes, n_edges)
        x_glob = rng.normal(size=(n_nodes, d)).astype(np.float32)
        labels = rng.integers(0, ncls, n_nodes).astype(np.int32)
        model = PNA(d, d_hidden=16, n_layers=2, n_classes=ncls, avg_log_deg=1.5)
        params = model.init(jax.random.key(0))

        def ref_loss(p):
            g = Graph(senders=jnp.asarray(senders, jnp.int32),
                      receivers=jnp.asarray(receivers, jnp.int32),
                      x=jnp.asarray(x_glob))
            logits = model(p, g).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, -1)
            gold = jnp.take_along_axis(logp, jnp.asarray(labels)[:, None],
                                       -1)[:, 0]
            return -jnp.mean(gold)
        ref_l, ref_g = jax.value_and_grad(ref_loss)(params)

        plan = build_plan(senders, receivers, n_nodes, S)
        mesh = jax.make_mesh((S,), ("shards",))
        step = make_locality_train_step(model, ncls, "shards", mesh)
        batch = {
            "x": jnp.asarray(x_glob.reshape(S, plan.n_loc, d)),
            "labels": jnp.asarray(labels.reshape(S, plan.n_loc)),
            "label_mask": jnp.ones((S, plan.n_loc), bool),
            "senders": jnp.asarray(plan.senders_local),
            "receivers": jnp.asarray(plan.receivers_local),
            "edge_mask": jnp.asarray(plan.edge_mask),
            "send_idx": jnp.asarray(plan.send_idx),
            "send_mask": jnp.asarray(plan.send_mask),
        }
        opt_state = adam().init(params)
        with mesh:
            new_p, _, loss = step(params, opt_state, batch)
        # relative: the loss is O(100) at init and shard-order fp
        # reassociation moves the last couple of ulps
        assert abs(float(loss) - float(ref_l)) < 1e-5 * max(
            1.0, abs(float(ref_l))), (loss, ref_l)
        rg, _ = clip_by_global_norm(ref_g, 1.0)
        upd, _ = adam().update(adam().init(params), rg, params, 1e-3)
        ref_p = apply_updates(params, upd)
        errs = [float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(new_p), jax.tree.leaves(ref_p))]
        assert max(errs) < 1e-5, max(errs)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            # without this jax probes non-CPU backends and
                            # stalls for minutes before falling back
                            "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=500)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_adam8bit_tracks_adam32():
    from repro.optim import adam, apply_updates
    from repro.optim.quantized import adam8bit
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 16)))
    A = A @ A.T / 16 + jnp.eye(16)
    b = jnp.asarray(rng.normal(size=(16,)))

    def f(x):
        return 0.5 * x["x"] @ A @ x["x"] - b @ x["x"]

    finals = {}
    for opt, name in ((adam(), "a32"), (adam8bit(), "a8")):
        x = {"x": jnp.zeros(16)}
        st = opt.init(x)
        for _ in range(200):
            g = jax.grad(f)(x)
            upd, st = opt.update(st, g, x, 0.05)
            x = apply_updates(x, upd)
        finals[name] = float(f(x))
    assert abs(finals["a8"] - finals["a32"]) < 1e-2 * max(1, abs(finals["a32"]))


def test_quantize_blockwise_roundtrip():
    from repro.optim.quantized import dequantize_blockwise, quantize_blockwise
    for shape in ((1024,), (4, 512), (3, 5, 100)):   # divisible + ragged
        x = jnp.asarray(np.random.default_rng(1).normal(size=shape) * 0.01)
        q, s = quantize_blockwise(x)
        assert q.shape == x.shape and q.dtype == jnp.int8
        xr = dequantize_blockwise(q, s)
        rel = float(jnp.linalg.norm(xr - x) / jnp.linalg.norm(x))
        assert rel < 0.02, (shape, rel)


def test_analyzer_dus_inplace_accounting():
    """A scan that DUS-writes one row per step into a big carry must be
    charged per-slice, not per-buffer."""
    from repro.roofline.hlo_analyzer import analyze_hlo
    N, K, d = 1024, 8, 64

    def f(buf, xs):
        def body(c, i):
            c = jax.lax.dynamic_update_slice(
                c, jnp.ones((1, d), c.dtype), (i, 0))
            return c, None
        out, _ = jax.lax.scan(body, buf, jnp.arange(K))
        return out

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((N, d), jnp.float32),
                         None).compile()
    r = analyze_hlo(c.as_text())
    buf_bytes = N * d * 4
    # per-step traffic must be ~2x a row (512 B), NOT the 256 KB buffer
    assert r["bytes"] < K * buf_bytes / 4, r["bytes"]
