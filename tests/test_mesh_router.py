"""Golden equivalence of the routing plane (ISSUE 2 tentpole).

`MeshRouter` (part axis block-sharded over a ("data",) mesh, fixed-capacity
all_to_all delivery) must be indistinguishable from `LocalRouter` (flat
scatter, one device): same embeddings, same integer TickStats, same busy
vector — in BOTH drivers, across all four window policies, and both must
match the static oracle.

Three execution tiers:
  * in-process on the suite's single CPU device: router/config/termination
    units + a degenerate 1-device mesh (full shard_map machinery, D=1);
  * in-process `@needs4` tests: the full policy matrix — they skip unless
    jax sees >= 4 devices, i.e. they run in the CI mesh lane
    (XLA_FLAGS=--xla_force_host_platform_device_count=4);
  * a subprocess smoke (fast lane, any environment) that forces a 4-device
    CPU backend and checks the streaming golden triplet + backpressure;
    the slow lane re-runs the full @needs4 matrix the same way.
"""
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import needs_devices, run_forced_devices
from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

N_NODES, D_IN = 32, 8

needs4 = needs_devices(4)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window, mesh=None, outbox_cap=None):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, outbox_cap=outbox_cap,
                         edge_tick_cap=32, max_nodes=N_NODES, window=window)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def assert_embeddings_close(a, b, rtol=1e-5, atol=1e-5):
    assert set(a) == set(b)
    for vid in a:
        np.testing.assert_allclose(b[vid], a[vid], rtol=rtol, atol=atol)


# ----------------------------------------------------------- units (1 dev)

def test_local_router_delivery_is_identity():
    from repro.core.events import MsgBatch
    from repro.dist.router import LocalRouter
    msg = MsgBatch(part=jnp.arange(4, dtype=jnp.int32),
                   slot=jnp.zeros(4, jnp.int32),
                   vec=jnp.ones((4, 3)), cnt=jnp.zeros(4),
                   src_part=jnp.zeros(4, jnp.int32),
                   valid=jnp.ones(4, bool))
    r = LocalRouter(n_parts=4)
    assert r.route(msg) is msg
    assert int(r.part0()) == 0
    assert r.psum(5) == 5


def test_config_validation_rejects_indivisible_parts():
    cfg = PipelineConfig(n_parts=6, feat_cap=6)
    cfg.validate()                       # fine on one device
    with pytest.raises(ValueError, match="not divisible by the mesh"):
        cfg.validate(n_devices=4)
    with pytest.raises(ValueError, match="outbox_cap or feat_cap"):
        PipelineConfig(n_parts=8, feat_cap=100).validate()
    with pytest.raises(ValueError, match="must be > 0"):
        PipelineConfig(node_cap=0).validate()


def test_termination_public_quiet_api():
    from repro.core.termination import TerminationCoordinator
    term = TerminationCoordinator(quiet_sweeps=2)
    assert term.quiet == 0 and term.seed_quiet() == 0
    # device-computed counter replaces the host count (observe_flag)
    assert not term.observe_flag(1)
    assert term.quiet == 1 and term.seed_quiet() == 1
    assert term.observe_flag(2)          # reached quiet_sweeps
    term.reset()
    assert term.quiet == 0


def test_mesh_single_device_golden_and_donated():
    """The full shard_map/MeshRouter machinery on a degenerate 1-device
    mesh must match the LocalRouter reference, keep the sharded carry
    donated, and sync once per super-tick."""
    edges, feats = make_stream()
    _, _, ref = build_pipe(win.WindowConfig(kind=win.STREAMING))
    ref.run_stream(edges, feats, tick_edges=24)
    ref.flush(max_ticks=64)

    mesh = make_stream_mesh(1)
    _, _, sup = build_pipe(win.WindowConfig(kind=win.STREAMING), mesh=mesh)
    old_feat = sup.states[0].feat
    sup.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    assert old_feat.is_deleted(), "sharded PipelineCarry must stay donated"
    sup.flush_super(max_ticks=64, T=4)
    assert_embeddings_close(ref.embeddings(), sup.embeddings())
    assert sup.metrics.reduce_msgs == ref.metrics.reduce_msgs
    assert sup.metrics.broadcast_msgs == ref.metrics.broadcast_msgs
    np.testing.assert_array_equal(sup.metrics.busy_logical,
                                  ref.metrics.busy_logical)


def test_stream_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="only"):
        make_stream_mesh(len(jax.devices()) + 1)


# ------------------------------------------- full matrix (>= 4 devices)

@needs4
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_mesh_golden_matrix_multidevice(window):
    """LocalRouter vs MeshRouter vs static oracle, per-tick AND super-tick
    drivers, on a real 4-device ("data",) mesh."""
    edges, feats = make_stream()
    model, params, ref = build_pipe(window)
    ref.run_stream(edges, feats, tick_edges=24)
    ref.flush(max_ticks=96)
    e_ref = ref.embeddings()

    mesh = make_stream_mesh(4)
    _, _, per = build_pipe(window, mesh=mesh)
    per.run_stream(edges, feats, tick_edges=24)
    per.flush(max_ticks=96)
    assert_embeddings_close(e_ref, per.embeddings())
    # identical tick boundaries -> identical integer counters
    assert per.metrics.reduce_msgs == ref.metrics.reduce_msgs
    assert per.metrics.broadcast_msgs == ref.metrics.broadcast_msgs
    assert per.metrics.cross_part_msgs == ref.metrics.cross_part_msgs
    assert per.metrics.emitted_total == ref.metrics.emitted_total
    np.testing.assert_array_equal(per.metrics.busy_logical,
                                  ref.metrics.busy_logical)
    # agg counts converge to the oracle's in-degrees on every shard layout
    np.testing.assert_allclose(np.asarray(per.states[0].agg_cnt),
                               np.asarray(ref.states[0].agg_cnt))

    _, _, sup = build_pipe(window, mesh=mesh)
    old_feat = sup.states[0].feat
    sup.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    assert old_feat.is_deleted(), "sharded PipelineCarry must stay donated"
    sup.flush_super(max_ticks=96, T=4)
    assert_embeddings_close(e_ref, sup.embeddings())

    g, _ = build_snapshot(edges, feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in sup.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


@needs4
def test_mesh_outbox_backpressure_dropped():
    """Regression: a starved outbox (one emission slot per part per tick)
    must defer — not lose — emissions under the sharded path."""
    edges, feats = make_stream(seed=3, n_edges=80)
    mesh = make_stream_mesh(4)
    model, params, pipe = build_pipe(win.WindowConfig(kind=win.STREAMING),
                                     mesh=mesh, outbox_cap=4)  # 1 slot/part
    pipe.run_stream_super(edges, feats, tick_edges=32, super_ticks=3)
    assert pipe.metrics.dropped > 0, "starved outbox must report deferrals"
    pipe.flush_super(max_ticks=256, T=8)
    g, _ = build_snapshot(edges, feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    emb = pipe.embeddings()
    assert len(emb) == N_NODES
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


def test_last_slot_emission_not_lost_by_topk_padding():
    """Regression: when a part's ONLY evicted vertex sits in its last
    node_cap slot and the per-part quota has spare entries, the top_k
    padding used to clamp onto the same slot and the duplicate-index
    scatter-set could erase the emission — fwd_pending then never cleared
    and flush() span to max_ticks."""
    from repro.core.events import (edge_batch_from_numpy, empty_feat_batch,
                                   feat_batch_from_numpy, repl_batch_from_numpy)
    from repro.core.state import apply_edge_batch, apply_repl_batch, init_topo
    from repro.core.tick import layer_tick_body
    from repro.core import state as st_mod
    import jax.numpy as jnp

    N = 4                                    # tiny per-part slot space
    model = GraphSAGE((D_IN, 8))
    params = model.init(jax.random.key(0))
    layer = model.layers[0]
    topo = init_topo(1, 8, 8, N)
    # one master vertex in slot N-1 of part 0, no edges
    from repro.core.events import VertexBatch
    vb = VertexBatch(part=jnp.zeros(1, jnp.int32),
                     slot=jnp.full(1, N - 1, jnp.int32),
                     is_master=jnp.ones(1, bool), valid=jnp.ones(1, bool))
    topo = st_mod.apply_vertex_batch(topo, vb)
    ls = st_mod.init_layer(1, N, D_IN, D_IN)
    fb = feat_batch_from_numpy(np.zeros(1), np.full(1, N - 1),
                               np.ones((1, D_IN), np.float32), 4, D_IN)
    eb = edge_batch_from_numpy({k: np.zeros(0, np.int64) for k in
                                ("part", "edge_slot", "src_slot", "dst_slot",
                                 "dst_master_part", "dst_master_slot")}, 4)
    rb = repl_batch_from_numpy({k: np.zeros(0, np.int64) for k in
                                ("part", "repl_slot", "master_slot",
                                 "rep_part", "rep_slot")}, 4)
    new_ls, outbox, stats, _ = layer_tick_body(
        layer, params["l0"], topo, ls, fb, eb, rb,
        jnp.int32(0), win.WindowConfig(kind=win.STREAMING), outbox_cap=2)
    assert int(stats.emitted) == 1
    assert int(outbox.valid.sum()) == 1
    assert not bool(new_ls.fwd_pending.any()), \
        "emitted vertex must leave the pending set"


# ------------------------------------------------- subprocess (forced 4)

def _run_forced4(pytest_args, timeout=540):
    return run_forced_devices(4, Path(__file__), pytest_args, timeout)


def test_mesh_golden_streaming_forced4_subprocess():
    """Fast-lane smoke on any machine: force a 4-device CPU backend in a
    subprocess and run the STREAMING golden + backpressure tests there."""
    r = _run_forced4(["-k", "test_mesh_golden_matrix_multidevice and "
                            "streaming or backpressure"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_mesh_golden_full_matrix_forced4_subprocess():
    """Slow lane: the complete 4-policy x 2-driver matrix under forced
    4-device CPU (the CI mesh lane runs the same tests in-process)."""
    r = _run_forced4(["-k", "test_mesh_golden_matrix_multidevice or "
                            "backpressure"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
