"""Golden serving matrix for the query plane (ISSUE 4 tentpole).

The query plane must be indistinguishable across every execution
configuration: {LocalRouter, MeshRouter} x {per-tick, super-tick} x
{xla, pallas delivery} — same answered qids, EXACT integer answer
ticks/ok flags, embeddings to f32 round-off. Within one configuration:

  * stale_ok answers BIT-match the `read_nodes` host oracle of the same
    tick (they read the same sink buffer);
  * consistent answers issued before a drain flush match the STATIC
    oracle (they hold until a locally-clean, globally-silent tick);
  * `embeddings()` is a thin wrapper over `read_nodes` (same dict);
  * the donated-carry and one-host-sync-per-super-tick contracts hold
    with queries aboard, and query_cap=0 compiles the plane away.

The in-process mesh tests use the degenerate 1-device mesh (full
shard_map/MeshRouter machinery); the @needs4 variant re-runs the matrix
on a real 4-device backend (CI mesh lane).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh
from repro.serve.query import (KIND_EMBED, KIND_LINK, admit,
                               init_query_state, query_batch_from_numpy)
from repro.serve.session import ServeSession

N_NODES, D_IN = 32, 8

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices (CI mesh lane forces a 4-device CPU backend)")


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window=None, mesh=None, backend="xla", query_cap=8,
               query_tick_cap=None):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         query_cap=query_cap, query_tick_cap=query_tick_cap,
                         delivery_backend=backend,
                         window=window or win.WindowConfig(kind=win.STREAMING))
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def chunked(edges, feats, tick_edges=24):
    e_chunks = [edges[lo: lo + tick_edges]
                for lo in range(0, len(edges), tick_edges)]
    seen, f_chunks = set(), []
    for ch in e_chunks:
        fe = []
        for u in ch.reshape(-1):
            u = int(u)
            if u not in seen and u in feats:
                seen.add(u)
                fe.append((u, feats[u]))
        f_chunks.append(fe)
    return e_chunks, f_chunks


def query_mix(edges):
    """Fixed query set: stale_ok + consistent embeds, a consistent link."""
    u, v = int(edges[0, 0]), int(edges[0, 1])
    return [(1, KIND_EMBED, 0, False),          # stale_ok read
            (2, KIND_LINK, u, v, True),          # consistent link score
            (3, KIND_EMBED, 5, True),            # consistent read
            (4, KIND_LINK, u, 5, False)]         # stale_ok link score


def run_config(edges, feats, mesh, driver, backend):
    """Stream 3 update ticks, admit the query mix on tick 4, flush."""
    _, _, pipe = build_pipe(mesh=mesh, backend=backend)
    e_chunks, f_chunks = chunked(edges, feats)
    q = query_mix(edges)
    if driver == "tick":
        for ch, fe in zip(e_chunks[:-1], f_chunks[:-1]):
            pipe.tick(ch, fe)
        pipe.tick(e_chunks[-1], f_chunks[-1], queries=q)
        pipe.flush(max_ticks=96)
    else:
        q_chunks = [None] * (len(e_chunks) - 1) + [q]
        pipe.run_super_tick(e_chunks, f_chunks, T=len(e_chunks),
                            query_chunks=q_chunks)
        pipe.flush_super(max_ticks=96, T=4)
    return pipe, canon(pipe.drain_answers())


def canon(ans):
    order = np.argsort(ans["qid"])
    return {k: v[order] for k, v in ans.items()}


# -------------------------------------------------------------- unit tests

def test_config_validation():
    PipelineConfig(query_cap=0).validate()             # disabled is fine
    with pytest.raises(ValueError, match="must be >= 0"):
        PipelineConfig(query_cap=-1).validate()
    with pytest.raises(ValueError, match="query plane is disabled"):
        PipelineConfig(query_cap=0, query_tick_cap=8).validate()
    cfg = PipelineConfig(query_cap=8)
    assert cfg.query_admissions() == 8 * cfg.n_parts
    assert PipelineConfig(query_cap=8,
                          query_tick_cap=16).query_admissions() == 16


def test_admission_fills_free_slots_and_drops_overflow():
    from repro.dist.router import LocalRouter
    qs = init_query_state(2, 2, 4)                     # 2 parts x 2 slots
    rows = {"qid": np.arange(3), "kind": np.zeros(3),
            "part": np.zeros(3), "slot": np.arange(3),
            "part2": np.zeros(3), "slot2": np.zeros(3),
            "consistent": np.zeros(3, bool), "issue": np.zeros(3)}
    qb = query_batch_from_numpy(rows, 4, 4)
    qs, n_adm, dropped = admit(qs, qb, jnp.int32(0))
    # part 0 has 2 slots; the third record for part 0 must drop — and the
    # drop MASK identifies exactly which record, so it can answer ok=False
    assert int(n_adm) == 2 and int(dropped.sum()) == 1
    assert bool(dropped[2]) and not bool(dropped[0]) and not bool(dropped[1])
    assert qs.pending[0].tolist() == [True, True]
    assert qs.pending[1].tolist() == [False, False]
    assert sorted(np.asarray(qs.qid[0]).tolist()) == [0, 1]


def test_queries_require_enabled_plane():
    _, _, pipe = build_pipe(query_cap=0)
    with pytest.raises(AssertionError, match="query_cap=0"):
        pipe.tick(queries=[(1, KIND_EMBED, 0, False)])
    with pytest.raises(ValueError, match="query_cap > 0"):
        ServeSession(pipe)


def test_read_nodes_partial_gather_and_embeddings_wrapper():
    edges, feats = make_stream()
    _, _, pipe = build_pipe(query_cap=0)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=96)
    full = pipe.embeddings()
    some = pipe.read_nodes([0, 1, 5, 99999])           # unknown vid ignored
    assert set(some) <= set(full)
    for v in some:
        np.testing.assert_array_equal(some[v], full[v])
    assert pipe.read_nodes([]) == {}


def test_pending_table_overflow_answers_ok_false():
    """Device-side admission overflow must NOT silently lose queries:
    the dropped qids come back as ok=False answers in the same tick, so
    the client knows exactly what to re-submit."""
    edges, feats = make_stream()
    # 1 pending slot per part, but room to ADMIT 8 requests per tick
    _, _, pipe = build_pipe(query_cap=1, query_tick_cap=8)
    pipe.run_stream(edges[:48], feats, tick_edges=24)
    vid = int(edges[0, 0])
    # 5 consistent reads of ONE vertex admitted alongside an update chunk:
    # the tick moves messages, so they all want to hold — but the master
    # part has a single slot; 4 must drop and answer ok=False now
    qs = [(i, KIND_EMBED, vid, True) for i in range(5)]
    pipe.tick(edges[48:72], queries=qs)
    ans = canon(pipe.drain_answers())
    assert len(ans["qid"]) == 4 and not ans["ok"].any()
    assert ans["tick"].tolist() == [pipe.now - 1] * 4
    assert pipe.metrics.queries_dropped == 4
    # the surviving query still resolves on flush
    pipe.flush(max_ticks=96)
    survivor = pipe.drain_answers()
    assert len(survivor["qid"]) == 1 and survivor["ok"].all()
    assert set(survivor["qid"]) | set(ans["qid"]) == set(range(5))


def test_session_budgets_submission_bursts():
    """A submission burst larger than one launch's admission budget must
    stay queued (not crash the fixed-capacity staging) and drain over
    subsequent advances."""
    edges, feats = make_stream()
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         query_cap=8, query_tick_cap=4,
                         window=win.WindowConfig(kind=win.STREAMING))
    pipe = D3Pipeline(model, params, cfg)
    s = ServeSession(pipe, driver="super", super_ticks=2)
    e_chunks, f_chunks = chunked(edges, feats)
    s.advance_super(e_chunks, f_chunks)            # ingest everything first
    vids = [int(edges[i % len(edges), 0]) for i in range(13)]
    s.submit_embed(vids)                           # 13 > 4/tick * 2 ticks
    s.advance_super(T=2)
    assert len(s._queue) == 5                      # budget = 8 admitted
    s.advance_super(T=2)
    s.flush()
    assert s.outstanding == 0
    assert len(s.answers) == 13


# ------------------------------------------------- per-config golden checks

def test_stale_ok_bit_matches_read_nodes_same_tick():
    """A stale_ok answer at tick t IS the sink row read_nodes sees after
    tick t — bitwise, not approximately."""
    edges, feats = make_stream()
    _, _, pipe = build_pipe()
    pipe.run_stream(edges[:72], feats, tick_edges=24)
    pipe.tick(edges[72:], queries=[(1, KIND_EMBED, 0, False),
                                   (2, KIND_EMBED, 5, False)])
    oracle = pipe.read_nodes([0, 5])
    ans = canon(pipe.drain_answers())
    assert ans["qid"].tolist() == [1, 2]
    assert ans["tick"].tolist() == [pipe.now - 1] * 2
    for i, vid in enumerate((0, 5)):
        if vid in oracle:
            assert bool(ans["ok"][i])
            np.testing.assert_array_equal(ans["vec"][i], oracle[vid])
        else:
            assert not bool(ans["ok"][i])


def test_consistent_answers_match_static_oracle_after_flush():
    edges, feats = make_stream()
    model, params, pipe = build_pipe()
    pipe, ans = run_config(edges, feats, None, "tick", "xla")
    g, _ = build_snapshot(edges, feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    u, v = int(edges[0, 0]), int(edges[0, 1])
    by = {int(q): i for i, q in enumerate(ans["qid"])}
    assert ans["ok"].all()
    np.testing.assert_allclose(ans["vec"][by[3]], oracle[5],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ans["score"][by[2]],
                               float(oracle[u] @ oracle[v]), rtol=1e-4)


def test_unknown_vertex_host_rejected():
    """Queries naming a vertex the partitioner has never seen (or an id
    outside the configured id space) answer ok=False on the host, without
    burning device pending slots."""
    _, _, pipe = build_pipe()
    pipe.tick(queries=[(7, KIND_EMBED, 0, False),          # unseen vid
                       (8, KIND_LINK, 0, 10 ** 6, False)])  # out of range
    ans = canon(pipe.drain_answers())
    assert ans["qid"].tolist() == [7, 8]
    assert not ans["ok"].any()
    assert pipe.metrics.queries_admitted == 0


def test_super_tick_donation_and_single_sync_with_queries():
    edges, feats = make_stream()
    _, _, pipe = build_pipe()
    e_chunks, f_chunks = chunked(edges, feats)
    old_feat = pipe.states[0].feat
    old_q = pipe.queries.pending
    pipe.run_super_tick(e_chunks, f_chunks, T=len(e_chunks),
                        query_chunks=[query_mix(edges)])
    assert old_feat.is_deleted(), "PipelineCarry must stay donated"
    assert old_q.is_deleted(), "QueryState rides the donated carry"


def test_query_metrics_accumulate():
    edges, feats = make_stream()
    _, _, pipe = build_pipe()
    pipe.run_stream(edges[:48], feats, tick_edges=24)
    # admit together with an update chunk: that tick MOVES messages, so
    # the consistent queries must hold at least one tick
    pipe.tick(edges[48:72], queries=query_mix(edges))
    pipe.flush(max_ticks=96)
    m = pipe.metrics
    assert m.queries_admitted == 4
    assert m.queries_answered == 4
    assert m.queries_dropped == 0
    assert m.query_hold_ticks > 0          # the consistent ones held


# --------------------------------------------------- the full golden matrix

def assert_answers_match(ref, other, name):
    np.testing.assert_array_equal(other["qid"], ref["qid"], err_msg=name)
    np.testing.assert_array_equal(other["tick"], ref["tick"],
                                  err_msg=f"{name}: answer ticks must be "
                                          "EXACTLY equal across configs")
    np.testing.assert_array_equal(other["ok"], ref["ok"], err_msg=name)
    np.testing.assert_array_equal(other["issue"], ref["issue"], err_msg=name)
    np.testing.assert_array_equal(other["kind"], ref["kind"], err_msg=name)
    np.testing.assert_allclose(other["vec"], ref["vec"], rtol=1e-5,
                               atol=1e-5, err_msg=name)
    np.testing.assert_allclose(other["score"], ref["score"], rtol=1e-4,
                               atol=1e-5, err_msg=name)


@pytest.fixture(scope="module")
def golden_ref():
    """The reference config's answers: LocalRouter, per-tick driver, xla.
    Built once; every matrix cell compares against it."""
    edges, feats = make_stream()
    _, ref = run_config(edges, feats, None, "tick", "xla")
    assert len(ref["qid"]) == 4 and ref["ok"].all()
    return edges, feats, ref


MATRIX = [("tick", "xla", "mesh1"), ("super", "xla", "local"),
          ("super", "xla", "mesh1"),
          pytest.param("tick", "pallas", "local", marks=pytest.mark.pallas),
          pytest.param("super", "pallas", "local", marks=pytest.mark.pallas),
          pytest.param("super", "pallas", "mesh1",
                       marks=pytest.mark.pallas)]


@pytest.mark.parametrize("driver,backend,where", MATRIX)
def test_golden_serving_matrix(golden_ref, driver, backend, where):
    """{LocalRouter, MeshRouter} x {per-tick, super-tick} x {xla, pallas}:
    identical answered qids, EXACT answer ticks, equivalent payloads.
    The in-process mesh is the degenerate 1-device one (full shard_map +
    MeshRouter machinery); @needs4 below re-runs on real 4 devices."""
    edges, feats, ref = golden_ref
    mesh = make_stream_mesh(1) if where == "mesh1" else None
    _, got = run_config(edges, feats, mesh, driver, backend)
    assert_answers_match(ref, got, f"{driver}-{backend}-{where}")


@needs4
@pytest.mark.parametrize("driver", ["tick", "super"])
def test_golden_serving_matrix_4dev_mesh(golden_ref, driver):
    """The matrix's mesh column on a real 4-device ("data",) mesh — query
    wire records actually cross devices on the extra all_to_all lane."""
    edges, feats, ref = golden_ref
    _, got = run_config(edges, feats, make_stream_mesh(4), driver, "xla")
    assert_answers_match(ref, got, f"4dev-{driver}")


# ------------------------------------------------------------- ServeSession

def test_serve_session_both_drivers():
    edges, feats = make_stream()
    e_chunks, f_chunks = chunked(edges, feats)
    results = {}
    for driver in ("tick", "super"):
        _, _, pipe = build_pipe()
        s = ServeSession(pipe, driver=driver, super_ticks=4)
        s.submit_embed([0], consistent=False)
        s.submit_embed([5], consistent=True)
        s.submit_link([(int(edges[0, 0]), int(edges[0, 1]))],
                      consistent=True)
        if driver == "tick":
            for ch, fe in zip(e_chunks, f_chunks):
                s.advance(ch, fe)
        else:
            s.advance_super(e_chunks, f_chunks, T=len(e_chunks))
        s.flush()
        assert s.outstanding == 0
        stats = s.latency_stats()
        assert stats["answered"] == 3
        assert stats["p99_ms"] >= stats["p50_ms"] >= 0
        results[driver] = s.answers
    # the two drivers resolve the same queries with the same payloads
    assert set(results["tick"]) == set(results["super"])
    for qid, a in results["tick"].items():
        b = results["super"][qid]
        assert (a.kind, a.ok) == (b.kind, b.ok)
        np.testing.assert_allclose(a.vec, b.vec, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a.score, b.score, rtol=1e-4, atol=1e-5)


def test_serve_session_latency_stats_single_population():
    """latency_stats bugfix (ISSUE 6): staleness percentiles used to run
    over ALL answers while latency percentiles skipped adopted ones
    (latency_s=None) — two silently different populations. Both must
    filter identically, with adopted answers counted separately."""
    from repro.serve.session import Answer
    _, _, pipe = build_pipe()
    s = ServeSession(pipe, driver="tick")
    # two timed answers (staleness 1, 3) + one ADOPTED answer with a huge
    # staleness that must NOT leak into the percentile population
    s.answers[0] = Answer(qid=0, kind=KIND_EMBED, ok=True,
                          vec=np.zeros(12, np.float32), score=0.0,
                          issue_tick=0, answer_tick=1, latency_s=0.010)
    s.answers[1] = Answer(qid=1, kind=KIND_EMBED, ok=True,
                          vec=np.zeros(12, np.float32), score=0.0,
                          issue_tick=0, answer_tick=3, latency_s=0.030)
    s.answers[2] = Answer(qid=2, kind=KIND_EMBED, ok=True,
                          vec=np.zeros(12, np.float32), score=0.0,
                          issue_tick=0, answer_tick=500, latency_s=None)
    stats = s.latency_stats()
    assert stats["answered"] == 3 and stats["adopted"] == 1
    assert stats["staleness_ticks_max"] == 3          # not the adopted 500
    assert stats["p50_ms"] == pytest.approx(20.0)
    # all-adopted sessions report counts only (no percentile keys)
    s2 = ServeSession(pipe, driver="tick")
    s2.answers[9] = Answer(qid=9, kind=KIND_EMBED, ok=True,
                           vec=np.zeros(12, np.float32), score=0.0,
                           issue_tick=0, answer_tick=2, latency_s=None)
    st2 = s2.latency_stats()
    assert st2["answered"] == st2["adopted"] == 1 and "p50_ms" not in st2


def test_serve_session_answer_retention_bound():
    """`answers` is bounded by max_retained: the OLDEST harvested answers
    evict first, and the bound never blocks new answers from landing."""
    edges, feats = make_stream()
    e_chunks, f_chunks = chunked(edges, feats)
    _, _, pipe = build_pipe()
    s = ServeSession(pipe, driver="tick", max_retained=4)
    early = s.submit_embed([0, 1, 2])
    for ch, fe in zip(e_chunks, f_chunks):
        s.advance(ch, fe)
    s.flush()
    assert s.outstanding == 0 and set(s.answers) == set(early)
    late = s.submit_embed([3, 4, 5])
    s.advance()                            # admit the queued wave
    s.flush()
    assert s.outstanding == 0
    # 6 answers harvested, bound 4: the two OLDEST-harvested rows (both
    # from the first wave) evicted; the fresh wave is fully retained
    assert len(s.answers) == 4
    assert set(late) <= set(s.answers)
    assert len(set(early) & set(s.answers)) == 1
    with pytest.raises(ValueError, match="max_retained"):
        ServeSession(pipe, driver="tick", max_retained=0)
