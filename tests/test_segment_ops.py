"""Property tests (hypothesis) for the masked segment reductions — the
message-passing primitive everything sits on."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional [test] extra")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.graph import segment


def _case(draw):
    n_seg = draw(st.integers(1, 16))
    n = draw(st.integers(1, 64))
    data = draw(hnp.arrays(np.float32, (n, 4),
                           elements=st.floats(-100, 100, width=32)))
    ids = draw(hnp.arrays(np.int64, (n,),
                          elements=st.integers(0, n_seg - 1)))
    mask = draw(hnp.arrays(np.bool_, (n,)))
    return n_seg, data, ids, mask


case = st.composite(_case)()


@given(case)
@settings(max_examples=60, deadline=None)
def test_segment_sum_matches_numpy(c):
    n_seg, data, ids, mask = c
    out = np.asarray(segment.segment_sum(jnp.asarray(data), jnp.asarray(ids),
                                         n_seg, jnp.asarray(mask)))
    ref = np.zeros((n_seg, 4), np.float32)
    for i in range(len(ids)):
        if mask[i]:
            ref[ids[i]] += data[i]
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@given(case)
@settings(max_examples=60, deadline=None)
def test_segment_mean_max_min(c):
    n_seg, data, ids, mask = c
    out_mean = np.asarray(segment.segment_mean(
        jnp.asarray(data), jnp.asarray(ids), n_seg, jnp.asarray(mask)))
    out_max = np.asarray(segment.segment_max(
        jnp.asarray(data), jnp.asarray(ids), n_seg, jnp.asarray(mask)))
    out_min = np.asarray(segment.segment_min(
        jnp.asarray(data), jnp.asarray(ids), n_seg, jnp.asarray(mask)))
    for s in range(n_seg):
        rows = data[(ids == s) & mask]
        if len(rows):
            np.testing.assert_allclose(out_mean[s], rows.mean(0), rtol=1e-4,
                                       atol=1e-4)
            np.testing.assert_allclose(out_max[s], rows.max(0), rtol=1e-4,
                                       atol=1e-4)
            np.testing.assert_allclose(out_min[s], rows.min(0), rtol=1e-4,
                                       atol=1e-4)
        else:
            np.testing.assert_array_equal(out_mean[s], 0)
            np.testing.assert_array_equal(out_max[s], 0)
            np.testing.assert_array_equal(out_min[s], 0)


@given(case)
@settings(max_examples=40, deadline=None)
def test_segment_std_synopsis_invariance(c):
    """std must be computable from the invertible synopsis (sum, sumsq, n) —
    identical under any permutation of rows (streaming commutativity)."""
    n_seg, data, ids, mask = c
    perm = np.random.default_rng(0).permutation(len(ids))
    a = np.asarray(segment.segment_std(jnp.asarray(data), jnp.asarray(ids),
                                       n_seg, jnp.asarray(mask)))
    b = np.asarray(segment.segment_std(jnp.asarray(data[perm]),
                                       jnp.asarray(ids[perm]), n_seg,
                                       jnp.asarray(mask[perm])))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


@given(case)
@settings(max_examples=40, deadline=None)
def test_segment_softmax_normalized(c):
    n_seg, data, ids, mask = c
    scores = data[:, 0]
    w = np.asarray(segment.segment_softmax(jnp.asarray(scores),
                                           jnp.asarray(ids), n_seg,
                                           jnp.asarray(mask)))
    sums = np.zeros(n_seg)
    for i in range(len(ids)):
        if mask[i]:
            sums[ids[i]] += w[i]
    for s in range(n_seg):
        if ((ids == s) & mask).any():
            np.testing.assert_allclose(sums[s], 1.0, rtol=1e-3)
