"""Coverage for the data pipeline and the GAT model (paper §3.3 zoo)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.streams import (edge_stream, feature_stream, temporal_stream,
                                token_batches)
from repro.graph.gat import GAT
from repro.graph.graphs import erdos_graph


def test_temporal_stream_shapes():
    st = temporal_stream(seed=0, n_nodes=100, n_edges=500, d_feat=8)
    assert st.edges.shape == (500, 2)
    assert (np.diff(st.timestamps) >= 0).all()
    chunks = list(edge_stream(st, 64))
    assert sum(len(c) for c in chunks) == 500


def test_feature_stream_covers_all_touched_and_lags():
    st = temporal_stream(seed=1, n_nodes=50, n_edges=200, d_feat=4)
    for lag in (0, 2):
        events = list(feature_stream(st, 32, feature_lag=lag))
        vids = {v for tick in events for v, _ in tick}
        assert vids == set(np.unique(st.edges).tolist())
        if lag:
            assert all(not e for e in events[:lag])


def test_token_batches_zipf():
    batches = list(token_batches(0, vocab=1000, batch=4, seq=32, n_batches=3))
    assert len(batches) == 3
    toks, labels = batches[0]
    assert toks.shape == (4, 32)
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])
    # Zipf: low ids must dominate
    all_toks = np.concatenate([t.ravel() for t, _ in batches])
    assert (all_toks < 100).mean() > 0.5


def test_gat_forward_and_grad():
    g = erdos_graph(jax.random.key(0), 64, 256, 16)
    model = GAT((16, 32, 32), n_heads=4, n_classes=5)
    params = model.init(jax.random.key(1))
    out = model(params, g)
    assert out.shape == (64, 5)
    assert bool(jnp.all(jnp.isfinite(out)))

    labels = jax.random.randint(jax.random.key(2), (64,), 0, 5)

    def loss(p):
        logp = jax.nn.log_softmax(model(p, g).astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))

    grads = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(grads))


def test_gat_attention_normalized():
    """Per-destination attention weights sum to 1 over in-edges."""
    from repro.graph import segment
    g = erdos_graph(jax.random.key(3), 32, 128, 8)
    scores = jax.random.normal(jax.random.key(4), (128,))
    w = segment.segment_softmax(scores, g.receivers, 32, None)
    sums = jax.ops.segment_sum(w, g.receivers, 32)
    present = np.asarray(jax.ops.segment_sum(jnp.ones(128), g.receivers, 32)) > 0
    np.testing.assert_allclose(np.asarray(sums)[present], 1.0, rtol=1e-5)
