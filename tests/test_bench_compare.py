"""The bench perf-regression gate (`run.py --compare`, ISSUE 7): pure
logic over BENCH.json row dicts — no jax, no subprocesses."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import (GATED_METRICS, REGRESSION_FRAC,
                                compare_rows, compare_to_baseline)


def row(name, evs=None, **derived):
    if evs is not None:
        derived["events_per_s"] = evs
    return {"name": name, "us_per_call": 1.0, "derived": derived}


def test_clean_when_within_threshold():
    base = [row("scaling[mesh,D=4]", 1000.0)]
    # exactly at the 20% edge is NOT a regression (strict inequality)
    assert compare_rows([row("scaling[mesh,D=4]", 800.0)], base) == []
    assert compare_rows([row("scaling[mesh,D=4]", 999.0)], base) == []
    assert compare_rows([row("scaling[mesh,D=4]", 1500.0)], base) == []


def test_regression_detected_and_described():
    base = [row("scaling[pipeline,stage=2,data=4]", 1000.0)]
    msgs = compare_rows(
        [row("scaling[pipeline,stage=2,data=4]", 700.0)], base)
    assert len(msgs) == 1
    assert "scaling[pipeline,stage=2,data=4]" in msgs[0]
    assert "700" in msgs[0] and "1000" in msgs[0]


def test_unshared_and_metricless_rows_are_ignored():
    base = [row("gone[old]", 500.0),
            row("fig7[latency]", p50_ms=3.0),
            row("shared", 100.0)]
    cur = [row("new[row]", 1.0),              # not in baseline: never fails
           row("fig7[latency]", p50_ms=99.0),  # no events_per_s: ignored
           row("shared", 99.0)]               # within threshold
    assert compare_rows(cur, base) == []


def test_custom_threshold():
    base = [row("r", 100.0)]
    assert compare_rows([row("r", 94.0)], base, threshold=0.05) != []
    assert compare_rows([row("r", 96.0)], base, threshold=0.05) == []
    assert 0.0 < REGRESSION_FRAC < 1.0


def test_p99_latency_gate_lower_is_better():
    """ISSUE 9: serving p99_ms is gated in the opposite direction."""
    base = [row("serving[super,T=8]", p99_ms=10.0)]
    # rises within 100% pass; beyond fail; drops never fail
    assert compare_rows([row("serving[super,T=8]", p99_ms=19.0)], base) == []
    assert compare_rows([row("serving[super,T=8]", p99_ms=2.0)], base) == []
    msgs = compare_rows([row("serving[super,T=8]", p99_ms=25.0)], base)
    assert len(msgs) == 1 and "p99_ms" in msgs[0] and "above" in msgs[0]


def test_wire_mb_gate_lower_is_better():
    base = [row("fig4b[capped]", wire_mb=8.0)]
    assert compare_rows([row("fig4b[capped]", wire_mb=9.9)], base) == []
    msgs = compare_rows([row("fig4b[capped]", wire_mb=10.1)], base)
    assert len(msgs) == 1 and "wire_mb" in msgs[0]


def test_multiple_metrics_gate_independently():
    """One row can regress on several gated columns at once; the
    events_per_s threshold override must not loosen the other gates."""
    base = [row("serving[s]", 1000.0, p99_ms=10.0, wire_mb=4.0)]
    cur = [row("serving[s]", 500.0, p99_ms=30.0, wire_mb=6.0)]
    msgs = compare_rows(cur, base)
    assert len(msgs) == 3
    msgs = compare_rows(cur, base, threshold=0.6)   # evs 500 now allowed
    assert len(msgs) == 2
    assert set(GATED_METRICS) == {"events_per_s", "p99_ms", "wire_mb"}


def test_missing_baseline_is_a_noop(tmp_path):
    assert compare_to_baseline([row("r", 1.0)],
                               str(tmp_path / "absent.json")) is None


def test_baseline_file_roundtrip(tmp_path):
    p = tmp_path / "BASELINE.json"
    p.write_text(json.dumps({"schema": 1, "rows": [row("r", 1000.0)]}))
    assert compare_to_baseline([row("r", 900.0)], str(p)) == []
    bad = compare_to_baseline([row("r", 100.0)], str(p))
    assert bad and "r:" in bad[0]
