"""Per-assigned-architecture smoke tests: reduced config, one forward /
train step on CPU, output shapes + no NaNs (deliverable f).

The model zoo compiles ~4 min of XLA on CPU and exercises nothing of the
streaming engine, so the whole module is `slow` — deselected from the
tier-1 run (`-m "not slow"` in pyproject addopts), executed by the CI
slow lane / `pytest -m slow`."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.configs import ARCH_IDS, get_arch
from repro.configs.gnn_common import GNN_SHAPES
from repro.graph.graphs import batch_molecules, erdos_graph
from repro.graph.triplets import build_triplets
from repro.optim import adam

LM_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "gnn"]


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_train_step(arch):
    spec = get_arch(arch)
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                              model.cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    loss0 = model.loss(params, toks, labels)
    assert jnp.isfinite(loss0)
    grads = jax.grad(model.loss)(params, toks, labels)
    assert _finite(grads)
    # one optimizer step reduces loss on the same batch
    from repro.optim import apply_updates
    opt = adam()
    st = opt.init(params)
    for _ in range(3):
        g = jax.grad(model.loss)(params, toks, labels)
        upd, st = opt.update(st, g, params, 1e-2)
        params = apply_updates(params, upd)
    assert model.loss(params, toks, labels) < loss0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_decode_matches_forward(arch):
    """Greedy decode logits == slice of the full forward logits."""
    spec = get_arch(arch)
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, model.cfg.vocab)
    full = model.logits(params, toks)
    cache = model.init_cache(B, S + 4)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape", ["full_graph_sm", "molecule"])
def test_gnn_reduced_step(arch, shape):
    spec = get_arch(arch)
    model = spec.build_reduced(shape)
    params = model.init(jax.random.key(0))
    dims = GNN_SHAPES[shape].dims
    key = jax.random.key(1)
    if shape == "molecule":
        g = batch_molecules(key, 4, 10, 24, 16)
        n_graphs = 4
    else:
        g = erdos_graph(key, 64, 256, 16, with_pos=True)
        g = g.replace(node_mask=jnp.ones(64, bool),
                      edge_mask=jnp.ones(256, bool))
        n_graphs = 1
    batch = {
        "senders": g.senders, "receivers": g.receivers, "x": g.x,
        "edge_mask": (g.edge_mask if g.edge_mask is not None
                      else jnp.ones(g.n_edges, bool)),
        "node_mask": (g.node_mask if g.node_mask is not None
                      else jnp.ones(g.n_nodes, bool)),
    }
    if spec.name in ("nequip", "dimenet"):
        batch["pos"] = g.pos
    if dims["n_classes"]:
        batch["labels"] = jax.random.randint(jax.random.key(3),
                                             (g.n_nodes,), 0,
                                             dims["n_classes"])
        batch["label_mask"] = jnp.ones(g.n_nodes, bool)
    else:
        batch["targets"] = jax.random.normal(jax.random.key(4), (n_graphs,))
        batch["graph_ids"] = (g.graph_ids if g.graph_ids is not None
                              else jnp.zeros(g.n_nodes, jnp.int32))
    if spec.name == "dimenet":
        tkj, tji, tmask = build_triplets(np.asarray(g.senders),
                                         np.asarray(g.receivers),
                                         g.n_nodes, 4 * g.n_edges)
        batch.update(t_kj=jnp.asarray(tkj), t_ji=jnp.asarray(tji),
                     t_mask=jnp.asarray(tmask))

    # build a reduced-shape step directly with the same machinery
    from repro.configs.base import ShapeSpec
    from repro.configs.gnn_common import make_gnn_train_step
    from repro.graph.graphs import Graph
    from repro.optim import apply_updates, clip_by_global_norm
    sh = ShapeSpec(shape, "train", {**dims, "n_graphs": n_graphs})
    if spec.name in ("pna", "gatedgcn") and not dims["n_classes"]:
        # molecule shape for [N,1]-logit models: per-graph energy MSE
        opt = adam()

        def loss_fn(params, batch):
            gg = Graph(senders=batch["senders"], receivers=batch["receivers"],
                       x=batch["x"], edge_mask=batch["edge_mask"],
                       node_mask=batch["node_mask"],
                       graph_ids=batch["graph_ids"], n_graphs=n_graphs)
            e_node = jnp.where(gg.node_mask, model(params, gg)[..., 0], 0.0)
            e = jax.ops.segment_sum(e_node, gg.graph_ids, n_graphs)
            return jnp.mean(jnp.square(e - batch["targets"]))

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, opt_state = opt.update(opt_state, grads, params, 1e-3)
            return apply_updates(params, upd), opt_state, loss
    else:
        step = make_gnn_train_step(model, sh,
                                   needs_pos=spec.name in ("nequip", "dimenet"),
                                   needs_triplets=spec.name == "dimenet")
    opt_state = adam().init(params)
    new_params, new_opt, loss = step(params, opt_state, batch)
    assert jnp.isfinite(loss), f"{arch}/{shape} loss not finite"
    assert _finite(new_params)


def test_recsys_reduced_step():
    spec = get_arch("two-tower-retrieval")
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    step = spec.step(model, "train_batch")
    B = 32
    c = model.cfg
    uids = jax.random.randint(jax.random.key(1),
                              (B, c.user_fields, c.max_ids_per_field), -1,
                              c.user_vocab)
    iids = jax.random.randint(jax.random.key(2),
                              (B, c.item_fields, c.max_ids_per_field), -1,
                              c.item_vocab)
    logq = jnp.zeros((B,))
    opt_state = adam().init(params)
    new_params, _, loss = step(params, opt_state,
                               {"user_ids": uids, "item_ids": iids,
                                "item_logq": logq})
    assert jnp.isfinite(loss)
    assert _finite(new_params)
    # serving paths
    u = model.user_tower(params, uids)
    assert u.shape == (B, c.tower_mlp[-1])
    scores = model.retrieval_scores(params, uids[:1], iids[:8])
    assert scores.shape == (1, 8)


def test_all_arch_input_specs_wellformed():
    for arch in ARCH_IDS:
        spec = get_arch(arch)
        for shape in spec.shapes:
            model = spec.build(shape)
            specs = spec.input_specs(model, shape)
            flat = jax.tree.leaves(specs)
            assert flat, f"{arch}/{shape} produced no input specs"
            for leaf in flat:
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
