"""Delta-gated incremental propagation (ISSUE 6 tentpole).

Three contracts pinned here:

  * EXACT mode — `delta_eps=0` (the default) is bit-for-bit the ungated
    PR 5 program: identical embeddings (assert_array_equal, not
    allclose), identical integer TickStats, suppressed == 0 — across all
    four window policies, both drivers, and both routers (the golden
    matrix the delivery/router suites use, plus the delta_eps lane).

  * APPROXIMATE mode — at eps > 0 a sub-eps update stream is (a) largely
    suppressed (suppressed > 0, reduce_msgs strictly below the exact
    run's), (b) error-BOUNDED: the sink differs from the static oracle
    on the final snapshot by at most the Lipschitz chain bound
        e1    = ||W1_neigh||_2 * eps          (layer-0 agg residual)
        bound = ||W2_self||_2 * e1 + ||W2_neigh||_2 * (e1 + eps)
    for the 2-layer SAGE stack (phi = identity, relu 1-Lipschitz,
    counts never gated), and (c) still TERMINATING: suppressed-but-
    pending vertices count as quiet, so flush()/flush_super() return.

  * The building blocks — aggregator gates (core/aggregators.GATES) and
    same-destination coalescing (core/events.coalesce_msg_batch) — keep
    their local semantics: monotonic MAX/MIN short-circuit vs the L2
    norm, and sum-preserving per-destination compaction.

Module rides the `pallas` marker like the other golden matrices so the
CI pallas lane (forced 4-device CPU backend) exercises the mesh cells.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import needs_devices
from repro.core import aggregators
from repro.core import windowing as win
from repro.core.events import MsgBatch, coalesce_msg_batch
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GCNLayer, GraphSAGE, SAGELayer
from repro.launch.mesh import make_stream_mesh

pytestmark = pytest.mark.pallas

N_NODES, D_IN = 32, 8

needs4 = needs_devices(4)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window=None, delta_eps=None, mesh=None):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    kw = {} if delta_eps is None else {"delta_eps": delta_eps}
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         window=window or win.WindowConfig(kind=win.STREAMING),
                         **kw)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def run_per_tick(pipe, edges, feats):
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=96)
    return pipe


def run_super(pipe, edges, feats):
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=96, T=4)
    return pipe


def assert_bit_identical(ref, other):
    """The eps=0 contract: EXACT embeddings and integer telemetry."""
    assert other.metrics.suppressed == ref.metrics.suppressed == 0
    assert other.metrics.reduce_msgs == ref.metrics.reduce_msgs
    assert other.metrics.broadcast_msgs == ref.metrics.broadcast_msgs
    assert other.metrics.cross_part_msgs == ref.metrics.cross_part_msgs
    assert other.metrics.emitted_total == ref.metrics.emitted_total
    assert other.metrics.dropped == ref.metrics.dropped
    np.testing.assert_array_equal(other.metrics.busy_logical,
                                  ref.metrics.busy_logical)
    a, b = ref.embeddings(), other.embeddings()
    assert set(a) == set(b)
    for vid in a:
        np.testing.assert_array_equal(b[vid], a[vid])


# ------------------------------------------------------------ gate semantics

def test_l2_gate_mean_sum():
    old = jnp.zeros((3, 4))
    new = jnp.asarray([[0.0, 0.0, 0.0, 0.0],        # ||d|| = 0
                       [4e-4, 4e-4, 4e-4, 4e-4],    # ||d|| = 8e-4
                       [2e-3, 0.0, 0.0, 0.0]])      # ||d|| = 2e-3
    for kind in ("mean", "sum"):
        g = np.asarray(aggregators.GATES[kind](new, old, 1e-3))
        np.testing.assert_array_equal(g, [True, True, False])


def test_max_min_gates_are_one_sided():
    """MAX synopsis grows only: a new message can only move the synopsis
    when some coordinate EXCEEDS the old value by more than eps — large
    drops are free (the old max still covers them). MIN mirrors it."""
    old = jnp.asarray([[1.0, 1.0], [1.0, 1.0], [1.0, 1.0]])
    new = jnp.asarray([[0.0, -9.0],        # big DROP: max can't shrink
                       [1.0 + 5e-4, 1.0],  # sub-eps growth
                       [1.0, 1.0 + 2e-3]]) # real growth
    g = np.asarray(aggregators.GATES["max"](new, old, 1e-3))
    np.testing.assert_array_equal(g, [True, True, False])
    g = np.asarray(aggregators.GATES["min"](-new, -old, 1e-3))
    np.testing.assert_array_equal(g, [True, True, False])
    # the L2 gate would NOT suppress the big drop — the short-circuit is
    # strictly more permissive for monotonic synopses
    assert not bool(aggregators.GATES["mean"](new, old, 1e-3)[0])


def test_layers_declare_their_gate_kind():
    assert SAGELayer(4, 4).agg_kind == "mean"
    assert GCNLayer(4, 4).agg_kind == "sum"
    assert set(aggregators.GATES) >= {"mean", "sum", "max", "min"}


def test_negative_or_nan_delta_eps_rejected():
    with pytest.raises(ValueError, match="delta_eps"):
        PipelineConfig(delta_eps=-1e-3).validate()
    with pytest.raises(ValueError, match="delta_eps"):
        PipelineConfig(delta_eps=float("nan")).validate()


# ----------------------------------------------------- coalescing semantics

def _dense_sums(b: MsgBatch, n_parts, n_slots):
    """Per-destination ground truth: dense scatter-add of a MsgBatch."""
    vec = np.zeros((n_parts * n_slots, b.vec.shape[-1]), np.float64)
    cnt = np.zeros((n_parts * n_slots,), np.float64)
    for i in range(b.part.shape[0]):
        if bool(b.valid[i]):
            k = int(b.part[i]) * n_slots + int(b.slot[i])
            vec[k] += np.asarray(b.vec[i], np.float64)
            cnt[k] += float(b.cnt[i])
    return vec, cnt


def test_coalesce_preserves_per_destination_sums():
    rng = np.random.default_rng(3)
    C, n_parts, n_slots, d = 64, 4, 8, 5
    b = MsgBatch(
        part=jnp.asarray(rng.integers(0, n_parts, C), jnp.int32),
        slot=jnp.asarray(rng.integers(0, n_slots, C), jnp.int32),
        vec=jnp.asarray(rng.normal(size=(C, d)).astype(np.float32)),
        cnt=jnp.asarray(rng.integers(0, 2, C).astype(np.float32)),
        src_part=jnp.asarray(rng.integers(0, n_parts, C), jnp.int32),
        valid=jnp.asarray(rng.random(C) < 0.7))
    out = coalesce_msg_batch(b, n_slots)
    assert out.part.shape == b.part.shape          # wire shape is fixed
    ref_vec, ref_cnt = _dense_sums(b, n_parts, n_slots)
    got_vec, got_cnt = _dense_sums(out, n_parts, n_slots)
    np.testing.assert_allclose(got_vec, ref_vec, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_cnt, ref_cnt, rtol=0, atol=0)
    # one live row per DISTINCT live destination, and no duplicates left
    keys = {int(b.part[i]) * n_slots + int(b.slot[i])
            for i in range(C) if bool(b.valid[i])}
    live = np.flatnonzero(np.asarray(out.valid))
    out_keys = [int(out.part[i]) * n_slots + int(out.slot[i]) for i in live]
    assert sorted(out_keys) == sorted(keys)


def test_coalesce_all_invalid_and_all_distinct():
    d = 3
    dead = MsgBatch(part=jnp.zeros(8, jnp.int32), slot=jnp.zeros(8, jnp.int32),
                    vec=jnp.ones((8, d)), cnt=jnp.ones(8),
                    src_part=jnp.zeros(8, jnp.int32),
                    valid=jnp.zeros(8, bool))
    assert not bool(jnp.any(coalesce_msg_batch(dead, 4).valid))
    uniq = MsgBatch(part=jnp.asarray([0, 1, 2, 3], jnp.int32),
                    slot=jnp.asarray([1, 1, 1, 1], jnp.int32),
                    vec=jnp.arange(8.0).reshape(4, 2),
                    cnt=jnp.asarray([1.0, 0.0, 1.0, 0.0]),
                    src_part=jnp.asarray([3, 2, 1, 0], jnp.int32),
                    valid=jnp.ones(4, bool))
    out = coalesce_msg_batch(uniq, 4)
    ref_vec, ref_cnt = _dense_sums(uniq, 4, 4)
    got_vec, got_cnt = _dense_sums(out, 4, 4)
    np.testing.assert_array_equal(got_vec, ref_vec)
    np.testing.assert_array_equal(got_cnt, ref_cnt)
    assert int(jnp.sum(out.valid)) == 4


# --------------------------------- golden matrix: eps=0 is bit-for-bit PR 5

@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_eps0_golden_matrix_local(window):
    """Explicit delta_eps=0.0 == default config, bit-identical, both
    drivers, LocalRouter — the gate and the coalescer compile away."""
    edges, feats = make_stream()
    _, _, ref = build_pipe(window)                  # default (eps unset)
    run_per_tick(ref, edges, feats)
    _, _, per = build_pipe(window, delta_eps=0.0)
    run_per_tick(per, edges, feats)
    assert_bit_identical(ref, per)
    _, _, sup = build_pipe(window, delta_eps=0.0)
    run_super(sup, edges, feats)
    assert_bit_identical(ref, sup)


@needs4
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_eps0_golden_matrix_mesh(window):
    """Same lane on a real 4-device mesh: the gate threads through the
    shard_map'd program without disturbing the all_to_all exchange."""
    edges, feats = make_stream()
    mesh = make_stream_mesh(4)
    _, _, ref = build_pipe(window, mesh=mesh)
    run_per_tick(ref, edges, feats)
    _, _, per = build_pipe(window, delta_eps=0.0, mesh=mesh)
    run_per_tick(per, edges, feats)
    assert_bit_identical(ref, per)
    _, _, sup = build_pipe(window, delta_eps=0.0, mesh=mesh)
    run_super(sup, edges, feats)
    assert_bit_identical(ref, sup)


# ----------------------------------------- eps > 0: suppression + the bound

def _tiny_update_waves(rng, feats, n_waves=6, scale=2e-4):
    """Waves of sub-eps feature perturbations (the gate's target traffic).
    Returns (per-wave event lists, the final feature dict)."""
    cur = {v: np.asarray(f, np.float32).copy() for v, f in feats.items()}
    waves = []
    for _ in range(n_waves):
        events = []
        for v in sorted(cur):
            delta = rng.normal(size=D_IN).astype(np.float32)
            delta *= scale / max(float(np.linalg.norm(delta)), 1e-12)
            cur[v] = cur[v] + delta
            events.append((v, cur[v].copy()))
        waves.append(events)
    return waves, cur


def _run_update_stream(pipe, edges, feats, waves):
    """Build the graph, then stream the update waves, then drain."""
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=96)
    for events in waves:
        pipe.tick(feats=events)
    pipe.flush(max_ticks=96)
    return pipe


def sage_error_bound(params, eps: float) -> float:
    """Lipschitz chain bound for the 2-layer SAGE stack (module doc)."""
    s1n = np.linalg.norm(np.asarray(params["l0"]["neigh"]["w"]), 2)
    s2s = np.linalg.norm(np.asarray(params["l1"]["self"]["w"]), 2)
    s2n = np.linalg.norm(np.asarray(params["l1"]["neigh"]["w"]), 2)
    e1 = s1n * eps
    return float(s2s * e1 + s2n * (e1 + eps))


def test_eps_suppresses_subthreshold_updates_and_bounds_error():
    eps = 1e-3
    rng = np.random.default_rng(7)
    edges, feats = make_stream()
    waves, final_feats = _tiny_update_waves(rng, feats, scale=2e-4)

    model, params, exact = build_pipe()
    _run_update_stream(exact, edges, feats, waves)
    _, _, gated = build_pipe(delta_eps=eps)
    _run_update_stream(gated, edges, feats, waves)

    # (a) the gate fired, and it SAVED messages (volume strictly below the
    # exact run; emission-time invariant: gated + suppressed never exceeds
    # what the exact schedule emitted)
    assert gated.metrics.suppressed > 0
    assert gated.metrics.reduce_msgs < exact.metrics.reduce_msgs
    assert (gated.metrics.reduce_msgs + gated.metrics.suppressed
            <= exact.metrics.reduce_msgs)
    assert exact.metrics.suppressed == 0

    # (b) error vs the static oracle on the FINAL snapshot stays under the
    # Lipschitz chain bound (small f32 slack: the exact pipeline itself
    # sits ~1e-6 off the oracle)
    g, _ = build_snapshot(edges, final_feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    bound = sage_error_bound(params, eps)
    emb = gated.embeddings()
    assert emb, "gated pipeline materialized no embeddings"
    worst = max(float(np.linalg.norm(emb[v] - oracle[v])) for v in emb)
    assert worst <= bound * 1.01 + 1e-5, \
        f"gated error {worst:.3e} exceeds the eps-derived bound {bound:.3e}"
    # the bound is meaningful: well above f32 noise, well below the
    # embedding scale
    assert 1e-5 < bound < float(np.linalg.norm(oracle))


def test_eps0_run_matches_oracle_after_updates():
    """Control for the bound test: the exact pipeline tracks the oracle to
    f32 tolerance through the same update waves."""
    rng = np.random.default_rng(7)
    edges, feats = make_stream()
    waves, final_feats = _tiny_update_waves(rng, feats, n_waves=2)
    model, params, exact = build_pipe()
    _run_update_stream(exact, edges, feats, waves)
    g, _ = build_snapshot(edges, final_feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    emb = exact.embeddings()
    for v in emb:
        np.testing.assert_allclose(emb[v], oracle[v], rtol=1e-4, atol=1e-4)


def test_flush_terminates_with_suppressed_residuals():
    """Termination contract: a suppressed-but-pending vertex is QUIET.
    A stream that ends on sub-eps updates must still quiesce under both
    drivers — the residual stays un-sent forever, by design."""
    eps = 1e-3
    rng = np.random.default_rng(11)
    edges, feats = make_stream()
    waves, _ = _tiny_update_waves(rng, feats, n_waves=2, scale=1e-4)

    _, _, per = build_pipe(delta_eps=eps)
    per.run_stream(edges, feats, tick_edges=24)
    per.flush(max_ticks=96)
    for events in waves:
        per.tick(feats=events)
    ran = per.flush(max_ticks=16)        # tight budget: must quiesce fast
    assert ran <= 16
    assert per.metrics.suppressed > 0

    _, _, sup = build_pipe(delta_eps=eps)
    sup.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    sup.flush_super(max_ticks=96, T=4)
    for events in waves:
        sup.run_super_tick(feat_chunks=[events], T=1)
    ran = sup.flush_super(max_ticks=16, T=4)
    assert ran <= 16
    assert sup.metrics.suppressed > 0


@needs4
def test_eps_gating_on_mesh_suppresses_and_terminates():
    """Approximate mode through the MeshRouter: suppression counts psum
    across devices, coalescing feeds the capped all_to_all, flush ends."""
    eps = 1e-3
    rng = np.random.default_rng(13)
    edges, feats = make_stream()
    waves, _ = _tiny_update_waves(rng, feats, n_waves=2, scale=1e-4)
    mesh = make_stream_mesh(4)
    _, _, pipe = build_pipe(delta_eps=eps, mesh=mesh)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=96)
    for events in waves:
        pipe.tick(feats=events)
    assert pipe.flush(max_ticks=16) <= 16
    assert pipe.metrics.suppressed > 0
