"""Hybrid-parallel 2-D mesh (ISSUE 7 tentpole).

Contracts pinned here:

  * stage=1 is the UNTOUCHED 1-D program: a `PipelineConfig(n_stages=1)`
    pipeline on a 1-device mesh is bit-for-bit (`assert_array_equal`,
    exact integer stats) the LocalRouter reference across all four
    window policies and both drivers — the refactor (psum_vote /
    extra_work plumbing shared with the pipelined path) must be
    HLO-invisible at stage=1.

  * stage>1 is a SCHEDULE-SKEWED but convergent program: per-tick
    behaviour differs from the 1-D program (layer l sees the stream l
    hops late), but after flush the quiescent state is the same fixed
    point — embeddings match the LocalRouter reference to f32 round-off
    and the static oracle, and the integer aggregator counts match
    EXACTLY (each edge contributes once, arrival-order independent).

  * the inter-stage ring is real pending work: it is non-empty mid-
    stream, `flush`/`flush_super` refuse to terminate over it, and it is
    EMPTY at quiescence (both drivers).

  * fail-loud config plane: every invalid (mesh, n_stages, layer-stack)
    combination raises a clear ValueError instead of misrouting.

  * the serve and checkpoint planes survive stage parallelism: point
    queries answer correctly from the stage-replicated sink, and a
    mid-stream snapshot (including in-flight ring rows) restores into a
    run that converges identically.

Execution tiers mirror test_mesh_router: units + the stage=1 matrix on
the suite's single CPU device; @needs2/@needs4/@needs8 in-process cells
(CI pipeline lane forces an 8-device CPU backend = stage 2 x data 4); a
forced-2 subprocess smoke in the fast lane; the forced-8 matrix in the
slow lane.
"""
from pathlib import Path

import numpy as np
import jax
import pytest

from conftest import needs_devices, run_forced_devices
from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh

N_NODES, D = 32, 8

needs2 = needs_devices(2)
needs4 = needs_devices(4)
needs8 = needs_devices(8)

ALL_POLICIES = [win.WindowConfig(kind=win.STREAMING),
                win.WindowConfig(kind=win.TUMBLING, interval=3),
                win.WindowConfig(kind=win.SESSION, interval=3),
                win.WindowConfig(kind=win.ADAPTIVE)]


def make_stream(seed=0, n_edges=100):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window, mesh=None, n_stages=1, n_layers=2, route_cap=None,
               query_cap=0):
    # uniform dims (in == out == D on every layer): the stage-parallel
    # SPMD-uniformity contract
    model = GraphSAGE((D,) * (n_layers + 1))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=N_NODES,
                         window=window, n_stages=n_stages,
                         route_cap=route_cap, query_cap=query_cap)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def run_ref(window, n_layers=2, tick_edges=24, seed=0):
    edges, feats = make_stream(seed=seed)
    model, params, ref = build_pipe(window, n_layers=n_layers)
    ref.run_stream(edges, feats, tick_edges=tick_edges)
    ref.flush(max_ticks=128)
    return edges, feats, model, params, ref


def assert_embeddings_close(a, b, rtol=1e-5, atol=1e-5):
    assert set(a) == set(b)
    for vid in a:
        np.testing.assert_allclose(b[vid], a[vid], rtol=rtol, atol=atol)


# --------------------------------------------------- fail-loud config plane

def test_validate_rejects_bad_stage_configs():
    with pytest.raises(ValueError, match="must be >= 1"):
        PipelineConfig(n_stages=0).validate()
    # a stage-parallel config on the LocalRouter would silently run
    # layer-sequentially — reject
    with pytest.raises(ValueError, match="LocalRouter"):
        PipelineConfig(n_stages=2).validate(n_devices=2, n_layers=2,
                                            local=True)
    with pytest.raises(ValueError, match="multiple of the stage count"):
        PipelineConfig(n_stages=2).validate(n_devices=3, n_layers=2)
    with pytest.raises(ValueError, match="round-robin"):
        PipelineConfig(n_stages=2).validate(n_devices=4, n_layers=3)
    # stage=1 keeps the 1-D semantics of every existing check
    PipelineConfig(n_parts=4, feat_cap=4).validate(n_devices=1)


def test_make_stream_mesh_stage_shapes():
    # stage must divide the device budget, whatever the machine has
    with pytest.raises(ValueError, match="multiple of the stage count"):
        make_stream_mesh(1, stage=2)
    m1 = make_stream_mesh(1, stage=1)
    assert m1.axis_names == ("data",), "stage=1 stays a 1-D mesh"


@needs2
def test_mesh_config_stage_mismatch_rejected():
    mesh = make_stream_mesh(2, stage=2)
    assert dict(mesh.shape) == {"stage": 2, "data": 1}
    with pytest.raises(ValueError, match="must agree"):
        build_pipe(win.WindowConfig(kind=win.STREAMING), mesh=mesh,
                   n_stages=1)


@needs2
def test_nonuniform_layer_stack_rejected():
    mesh = make_stream_mesh(2, stage=2)
    model = GraphSAGE((D, 16, D))         # in != out on both layers
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128,
                         repl_cap=128, feat_cap=128, edge_tick_cap=32,
                         max_nodes=N_NODES, n_stages=2)
    with pytest.raises(ValueError, match="SPMD-uniform"):
        D3Pipeline(model, params, cfg, mesh=mesh)


# ------------------------------------------- stage=1 bit-identity (1 dev)

@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_stage1_golden_bit_identity(window):
    """n_stages=1 on a mesh must stay BIT-identical to the LocalRouter
    1-D program — embeddings via assert_array_equal and exact integer
    stats, both drivers. Pins that the hybrid-parallel refactor is
    unreachable (not just numerically harmless) at stage=1."""
    edges, feats, _, _, ref = run_ref(window)
    e_ref = ref.embeddings()

    mesh = make_stream_mesh(1, stage=1)
    for driver in ("tick", "super"):
        _, _, pipe = build_pipe(window, mesh=mesh, n_stages=1)
        assert pipe.n_stages == 1 and pipe.stage_ring is None
        if driver == "tick":
            pipe.run_stream(edges, feats, tick_edges=24)
            pipe.flush(max_ticks=128)
        else:
            pipe.run_stream_super(edges, feats, tick_edges=24,
                                  super_ticks=4)
            pipe.flush_super(max_ticks=128, T=4)
        emb = pipe.embeddings()
        assert set(emb) == set(e_ref)
        for vid in emb:
            np.testing.assert_array_equal(emb[vid], e_ref[vid])
        m, r = pipe.metrics, ref.metrics
        assert (m.reduce_msgs, m.broadcast_msgs, m.cross_part_msgs,
                m.emitted_total, m.dropped) == \
               (r.reduce_msgs, r.broadcast_msgs, r.cross_part_msgs,
                r.emitted_total, r.dropped)
        np.testing.assert_array_equal(m.busy_logical, r.busy_logical)
        assert m.stage_idle == 0 and pipe.bubble_fraction() == 0.0


# --------------------------------------------- stage=2 golden (>= 2 devs)

@needs2
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_stage2_golden_matrix(window):
    """stage=2 x data=1: schedule-skewed, but the quiescent state equals
    the LocalRouter reference and the static oracle — both drivers,
    exact integer aggregator counts."""
    edges, feats, model, params, ref = run_ref(window)
    e_ref = ref.embeddings()

    mesh = make_stream_mesh(2, stage=2)
    for driver in ("tick", "super"):
        _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2)
        if driver == "tick":
            pipe.run_stream(edges, feats, tick_edges=24)
            pipe.flush(max_ticks=160)
        else:
            pipe.run_stream_super(edges, feats, tick_edges=24,
                                  super_ticks=4)
            pipe.flush_super(max_ticks=160, T=4)
        assert pipe._ring_occupancy_host() == 0, \
            "quiescence must drain the inter-stage ring"
        assert_embeddings_close(e_ref, pipe.embeddings())
        # each edge reaches every layer's aggregator exactly once,
        # whatever the inter-stage schedule
        for r, ls in enumerate(pipe.states):
            got = np.asarray(ls.agg_cnt)       # [S, P, N] stacked rounds
            for s in range(2):
                li = r * 2 + s
                np.testing.assert_array_equal(
                    got[s], np.asarray(ref.states[li].agg_cnt))
        assert pipe.metrics.dropped == ref.metrics.dropped
        assert pipe.metrics.route_dropped == 0

    g, _ = build_snapshot(edges, feats, D, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


@needs2
def test_stage2_four_layers_two_rounds():
    """R = L // S = 2 rounds per stage: exercises the deeper ring (slot
    r > 0 reads, the stage-0 wrap hop) against a 4-layer reference."""
    window = win.WindowConfig(kind=win.STREAMING)
    edges, feats, model, params, ref = run_ref(window, n_layers=4)
    mesh = make_stream_mesh(2, stage=2)
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2, n_layers=4)
    assert pipe._n_rounds == 2
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=160, T=4)
    assert_embeddings_close(ref.embeddings(), pipe.embeddings())
    g, _ = build_snapshot(edges, feats, D, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


@needs2
@pytest.mark.parametrize("driver", ["tick", "super"])
def test_flush_drains_inflight_stage_ring(driver):
    """Mid-stream the ring holds live rows; quiescence must wait for the
    skewed tail to telescope through every stage (regression: a flush
    that ignored ring occupancy would terminate early and lose the last
    L-1 hops of every in-flight update)."""
    window = win.WindowConfig(kind=win.STREAMING)
    edges, feats = make_stream()
    mesh = make_stream_mesh(2, stage=2)
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2)
    if driver == "tick":
        pipe.run_stream(edges, feats, tick_edges=24)
    else:
        pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    assert pipe._ring_occupancy_host() > 0, \
        "a just-streamed pipeline must have rows in flight between stages"
    if driver == "tick":
        pipe.flush(max_ticks=160)
    else:
        pipe.flush_super(max_ticks=160, T=4)
    assert pipe._ring_occupancy_host() == 0
    # the drained rows materialized: every vertex has an embedding
    assert len(pipe.embeddings()) == N_NODES


@needs2
def test_stage2_bubble_telemetry():
    window = win.WindowConfig(kind=win.STREAMING)
    edges, feats = make_stream()
    mesh = make_stream_mesh(2, stage=2)
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=160)
    # warm-up and drain ticks necessarily bubble (stage 1 idles on tick
    # 0; stage 0 idles while the tail drains)
    assert pipe.metrics.stage_idle > 0
    assert 0.0 < pipe.bubble_fraction() <= 1.0


@needs2
def test_stage2_query_plane():
    """Point queries served from the stage-replicated sink: stale_ok
    embedding reads bit-match read_nodes, link queries answer, nothing
    strands."""
    from repro.serve.query import KIND_EMBED, KIND_LINK
    window = win.WindowConfig(kind=win.STREAMING)
    edges, feats = make_stream()
    mesh = make_stream_mesh(2, stage=2)
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2, query_cap=8)
    pipe.run_stream(edges, feats, tick_edges=24)
    pipe.flush(max_ticks=160)
    vids = sorted(pipe.embeddings())[:4]
    qs = [(i, KIND_EMBED, v, False) for i, v in enumerate(vids)]
    qs.append((len(qs), KIND_LINK, vids[0], vids[1], False))
    pipe.tick(queries=qs)
    pipe.flush(max_ticks=160)
    ans = pipe.drain_answers()
    assert sorted(ans["qid"].tolist()) == list(range(len(qs)))
    assert ans["ok"].all()
    snap = pipe.read_nodes(vids)
    for qid, v in enumerate(vids):
        row = np.flatnonzero(ans["qid"] == qid)[0]
        np.testing.assert_array_equal(ans["vec"][row], snap[v])


@needs2
def test_stage2_checkpoint_roundtrip(tmp_path):
    """A mid-stream snapshot carries the in-flight ring rows: restoring
    it and replaying the tail converges to the uninterrupted run."""
    from repro.ft.checkpoint import CheckpointManager
    window = win.WindowConfig(kind=win.STREAMING)
    edges, feats = make_stream()
    mesh = make_stream_mesh(2, stage=2)
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2)
    half = len(edges) // 2
    pipe.run_stream(edges[:half], feats, tick_edges=24)
    assert pipe._ring_occupancy_host() > 0
    mgr = CheckpointManager(tmp_path)
    mgr.save_pipeline(0, pipe)
    seen = set(int(v) for v in edges[:half].reshape(-1))

    def finish(p):
        e_chunks, f_chunks = p.chunk_stream(edges[half:], feats, 24,
                                            seen=set(seen))
        for chunk, f_events in zip(e_chunks, f_chunks):
            p.tick(chunk, f_events)
        p.flush(max_ticks=160)
        return p.embeddings()

    e_straight = finish(pipe)

    _, _, fresh = build_pipe(window, mesh=mesh, n_stages=2)
    mgr.restore_pipeline(fresh)
    assert fresh._ring_occupancy_host() == pipe._ring_occupancy_host() or \
        fresh._ring_occupancy_host() > 0
    e_restored = finish(fresh)
    assert set(e_restored) == set(e_straight)
    for vid in e_straight:
        np.testing.assert_allclose(e_restored[vid], e_straight[vid],
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------- stage=2 x data>1 (>= 4 / 8 devs)

@needs4
def test_stage2_data2_capped_route_backpressure():
    """The full hybrid plane: 2 stages x 2 data shards with a tiny
    route_cap on hub-heavy traffic — capped lanes defer (never drop),
    re-emit, and still converge to the 1-D reference and oracle."""
    window = win.WindowConfig(kind=win.STREAMING)
    rng = np.random.default_rng(1)
    src = rng.integers(1, N_NODES, 120)
    dst = np.where(rng.random(120) < 0.75, rng.integers(0, 3, 120),
                   rng.integers(0, N_NODES, 120))
    edges = np.stack([src, dst], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D).astype(np.float32)
             for v in range(N_NODES)}

    model, params, ref = build_pipe(window)
    ref.run_stream(edges, feats, tick_edges=24)
    ref.flush(max_ticks=160)

    mesh = make_stream_mesh(4, stage=2)
    assert dict(mesh.shape) == {"stage": 2, "data": 2}
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2, route_cap=8)
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=256, T=8)
    assert pipe.metrics.route_dropped == 0
    assert_embeddings_close(ref.embeddings(), pipe.embeddings(),
                            rtol=1e-4, atol=1e-4)
    g, _ = build_snapshot(edges, feats, D, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


@needs8
@pytest.mark.parametrize("window", ALL_POLICIES,
                         ids=[w.kind for w in ALL_POLICIES])
def test_stage2_data4_golden_matrix(window):
    """The ISSUE target shape — stage=2 x data=4 — over every window
    policy (super-tick driver; the CI pipeline lane runs this
    in-process on a forced 8-device CPU backend)."""
    edges, feats, model, params, ref = run_ref(window)
    mesh = make_stream_mesh(8, stage=2)
    assert dict(mesh.shape) == {"stage": 2, "data": 4}
    _, _, pipe = build_pipe(window, mesh=mesh, n_stages=2)
    pipe.run_stream_super(edges, feats, tick_edges=24, super_ticks=4)
    pipe.flush_super(max_ticks=160, T=4)
    assert pipe._ring_occupancy_host() == 0
    assert_embeddings_close(ref.embeddings(), pipe.embeddings())
    g, _ = build_snapshot(edges, feats, D, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe.embeddings().items():
        np.testing.assert_allclose(vec, oracle[vid], rtol=1e-4, atol=1e-4)


# ------------------------------------------------- subprocess (forced N)

def test_stage_smoke_forced2_subprocess():
    """Fast-lane smoke on any machine: a forced 2-device CPU backend runs
    the STREAMING stage=2 golden + the ring-drain regression."""
    r = run_forced_devices(
        2, Path(__file__),
        ["-k", "(test_stage2_golden_matrix and streaming) or "
               "test_flush_drains_inflight_stage_ring"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_stage_full_matrix_forced8_subprocess():
    """Slow lane: the complete stage matrix — including the 2x4 target
    shape — under a forced 8-device CPU backend (the CI pipeline lane
    runs the same cells in-process)."""
    r = run_forced_devices(
        8, Path(__file__),
        ["-k", "test_stage2_data4_golden_matrix or "
               "test_stage2_data2_capped_route_backpressure or "
               "test_stage2_golden_matrix"],
        timeout=1200)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
