"""Streaming vertex-cut partitioner invariants + Alg. 5 properties."""
import numpy as np
import pytest

# hypothesis is an optional [test] extra: the property tests below are only
# defined when it is importable; the deterministic tests always run
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.explosion import (imbalance_factor, layer_parallelisms,
                                  physical_busy, physical_part)
from repro.core.partitioner import StreamingPartitioner
from repro.graph.graphs import powerlaw_edges


@pytest.mark.parametrize("method", ["hdrf", "clda", "random"])
def test_partitioner_invariants(method):
    rng = np.random.default_rng(0)
    edges = powerlaw_edges(rng, 200, 1000)
    part = StreamingPartitioner(8, 200, method=method)
    e_rows, r_rows, v_rows = part.ingest_edges(edges)
    # every edge assigned exactly once
    assert len(e_rows["part"]) == len(edges)
    assert (e_rows["part"] >= 0).all() and (e_rows["part"] < 8).all()
    # masters unique & stable
    t = part.t
    seen = t.master >= 0
    assert seen.sum() == len(np.unique(edges))
    # replication factor >= 1 and every replica row points at a real master
    assert part.replication_factor() >= 1.0
    for mp, ms in zip(r_rows["part"], r_rows["master_slot"]):
        assert 0 <= mp < 8
    # edge slots unique per part
    for p in range(8):
        slots = e_rows["edge_slot"][e_rows["part"] == p]
        assert len(slots) == len(set(slots.tolist()))


def test_hdrf_beats_random_on_replication():
    """Paper §6: HDRF/CLDA surpass Random on communication metrics; the
    driver of that is the replication factor."""
    rng = np.random.default_rng(1)
    edges = powerlaw_edges(rng, 300, 3000)
    rf = {}
    for method in ("hdrf", "clda", "random"):
        p = StreamingPartitioner(8, 300, method=method)
        p.ingest_edges(edges)
        rf[method] = p.replication_factor()
    assert rf["hdrf"] < rf["random"]
    assert rf["clda"] < rf["random"]


def test_hdrf_balance():
    rng = np.random.default_rng(2)
    edges = powerlaw_edges(rng, 300, 3000)
    p = StreamingPartitioner(8, 300, method="hdrf")
    p.ingest_edges(edges)
    assert p.load_imbalance() < 1.5


# ------------------------------------------------------------- Algorithm 5
if HAS_HYPOTHESIS:
    @given(st.integers(0, 10_000), st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_alg5_physical_in_range(logical, par):
        max_par = 64
        phys = physical_part(logical, par, max_par)
        assert 0 <= phys < par

    @given(st.integers(1, 64))
    @settings(max_examples=64, deadline=None)
    def test_alg5_no_idle_operator(par):
        """Paper: 'Each operator is assigned at least one key'."""
        max_par = 64
        phys = physical_part(np.arange(max_par), par, max_par)
        assert set(phys.tolist()) == set(range(par))
else:
    @pytest.mark.skip(reason="property tests need the optional [test] extra")
    def test_alg5_properties():
        pytest.importorskip("hypothesis")


def test_alg5_contiguity_and_rescale():
    max_par = 32
    logical = np.arange(max_par)
    p8 = physical_part(logical, 8, max_par)
    # contiguous key ranges (monotone non-decreasing)
    assert (np.diff(p8) >= 0).all()
    # rescale 8 -> 16: each logical part maps deterministically, no state
    # exchange outside the part granularity
    p16 = physical_part(logical, 16, max_par)
    assert (np.diff(p16) >= 0).all()
    assert len(set(p16.tolist())) == 16


def test_explosion_parallelisms():
    pars = layer_parallelisms(4, 3.0, 3, max_parallelism=256)
    assert pars == [4, 12, 36]
    pars_capped = layer_parallelisms(64, 3.0, 3, max_parallelism=128)
    assert pars_capped[-1] == 128


def test_physical_busy_aggregation():
    busy = np.arange(8, dtype=np.int64)
    agg = physical_busy(busy, 4, 8)
    assert agg.sum() == busy.sum()
    assert imbalance_factor(np.array([2.0, 2.0])) == 1.0
