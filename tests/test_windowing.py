"""Unit tests for window deadline semantics (paper §4.2.4) and the
exponentially-decayed CountMinSketch behind the adaptive-session policy."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import windowing as win


def _dl(cfg, now, cur=0, pending=False, freq=0.0):
    out = win.next_deadline(
        cfg, jnp.asarray(now, jnp.int32),
        jnp.asarray([cur], jnp.int32), jnp.asarray([pending]),
        jnp.asarray([freq], jnp.float32))
    return int(out[0])


def test_streaming_deadline_is_now():
    cfg = win.WindowConfig(kind=win.STREAMING)
    for now in (0, 3, 17):
        assert _dl(cfg, now) == now


def test_tumbling_bucket_stability():
    """All touches within one bucket land on the SAME boundary, and an
    earlier scheduled deadline never moves later (buckets don't slide)."""
    cfg = win.WindowConfig(kind=win.TUMBLING, interval=4)
    # ticks 0..3 all map to boundary 4; 4..7 to 8
    assert [_dl(cfg, t) for t in range(4)] == [4, 4, 4, 4]
    assert [_dl(cfg, t) for t in range(4, 8)] == [8, 8, 8, 8]
    # vertex already pending with deadline 4, touched again at tick 5:
    # the earlier bucket boundary must win
    assert _dl(cfg, 5, cur=4, pending=True) == 4
    # not pending: old deadline is stale, new bucket applies
    assert _dl(cfg, 5, cur=4, pending=False) == 8


def test_session_touch_extension():
    """Every touch pushes eviction back by a full interval."""
    cfg = win.WindowConfig(kind=win.SESSION, interval=5)
    assert _dl(cfg, 0) == 5
    # re-touch at tick 3 while pending: deadline moves to 8 (extends)
    assert _dl(cfg, 3, cur=5, pending=True) == 8
    assert _dl(cfg, 7) == 12


def test_adaptive_clip_bounds():
    cfg = win.WindowConfig(kind=win.ADAPTIVE, adaptive_min=2, adaptive_max=9,
                           adaptive_alpha=8.0)
    # very hot vertex -> clipped at min
    assert _dl(cfg, 10, freq=1e6) == 12
    # very cold vertex -> clipped at max
    assert _dl(cfg, 10, freq=1e-9) == 19
    # mid-frequency: alpha/freq inside the clip range
    assert _dl(cfg, 10, freq=4.0) == 12  # 8/4 = 2 == min
    assert _dl(cfg, 10, freq=2.0) == 14  # 8/2 = 4


def test_adaptive_fractional_interval_rounds_up():
    """Truncation regression (ISSUE 6): alpha/freq in (0, 1) used to cast
    to int32 as 0 BEFORE the clip, silently collapsing every hot vertex
    onto adaptive_min by accident. With explicit ceil the boundary is a
    policy decision: fractional intervals round UP to the next tick."""
    cfg = win.WindowConfig(kind=win.ADAPTIVE, adaptive_min=1,
                           adaptive_max=16, adaptive_alpha=8.0)
    # 8/16 = 0.5 -> ceil 1 (the old trunc gave 0 -> clip 1: same value,
    # but only by the min=1 accident — pin it anyway)
    assert _dl(cfg, 10, freq=16.0) == 11
    # 8/3 = 2.67 -> ceil 3, NOT trunc 2: the mid-range boundary the old
    # cast got wrong without any clip to hide it
    assert _dl(cfg, 10, freq=3.0) == 13
    # exact integers are untouched by ceil
    assert _dl(cfg, 10, freq=2.0) == 14
    # with min=2, 8/5=1.6 ceils to 2 directly — the deadline no longer
    # depends on the clip floor catching a truncated-to-1 interval
    cfg2 = win.WindowConfig(kind=win.ADAPTIVE, adaptive_min=2,
                            adaptive_max=16, adaptive_alpha=8.0)
    assert _dl(cfg2, 10, freq=5.0) == 12


def test_adaptive_hot_vertices_evict_sooner_than_cold():
    cfg = win.WindowConfig(kind=win.ADAPTIVE)
    hot = _dl(cfg, 0, freq=100.0)
    cold = _dl(cfg, 0, freq=0.1)
    assert hot < cold


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        win.next_deadline(win.WindowConfig(kind="nope"), 0,
                          jnp.zeros(1, jnp.int32), jnp.zeros(1, bool),
                          jnp.zeros(1))


# ---------------------------------------------------------------- sketch
def test_cms_estimate_is_monotone_overestimate():
    """CMS never under-counts, and estimates grow monotonically with
    repeated updates of the same key (no decay)."""
    cms = jnp.zeros((4, 256), jnp.float32)
    key = jnp.asarray([42])
    prev = 0.0
    for step in range(1, 6):
        cms = win.cms_update(cms, key, jnp.asarray([1.0]), decay=1.0)
        est = float(win.cms_query(cms, key)[0])
        assert est >= step - 1e-6          # overestimate property
        assert est >= prev                 # monotone in updates
        prev = est


def test_cms_counts_distinct_keys_independently_enough():
    cms = jnp.zeros((4, 2048), jnp.float32)
    keys = jnp.arange(32)
    weights = jnp.ones((32,), jnp.float32)
    for _ in range(3):
        cms = win.cms_update(cms, keys, weights, decay=1.0)
    ests = np.asarray(win.cms_query(cms, keys))
    assert (ests >= 3 - 1e-6).all()
    # wide sketch, few keys: collisions should be rare
    assert np.median(ests) == pytest.approx(3.0)


def test_cms_decay_shrinks_stale_counts():
    cms = jnp.zeros((4, 256), jnp.float32)
    key = jnp.asarray([7])
    cms = win.cms_update(cms, key, jnp.asarray([8.0]), decay=1.0)
    before = float(win.cms_query(cms, key)[0])
    # decay-only update (zero weight on an untouched key)
    cms = win.cms_update(cms, jnp.asarray([9]), jnp.asarray([0.0]), decay=0.5)
    after = float(win.cms_query(cms, key)[0])
    assert after == pytest.approx(before * 0.5)


def test_cms_delta_batched_scatter_matches_per_depth_loop():
    """Regression for the ISSUE 5 vectorization: cms_delta's single
    batched scatter over [depth, n] flattened indices must reproduce the
    old per-depth Python loop of scatters exactly (counts are small exact
    f32 integers, so order cannot matter)."""
    rng = np.random.default_rng(0)
    depth, width, n = 4, 512, 200
    keys = jnp.asarray(rng.integers(0, 10_000, n))
    weights = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    got = win.cms_delta((depth, width), keys, weights)
    idx = win.cms_hash(keys, depth, width)
    ref = jnp.stack([jnp.zeros((width,), jnp.float32).at[idx[d]].add(weights)
                     for d in range(depth)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert got.shape == (depth, width)
