"""Chaos plane (ISSUE 10): live elastic resharding goldens, fault
injection scenarios, and degraded-mode serving.

The reshard goldens pin the headline invariant: an UNCAPPED 1-D run is
bit-equal across ANY device count (canonical delivery order), so a
mid-stream `D3Pipeline.reshard` — in either direction, under either
driver, with in-flight windows, defer rings, and held consistent
queries — must leave the flushed sink bit-equal to the local
single-device run, with identical logical integer stats and zero drops.

The chaos scenarios (`repro.ft.chaos`) then make something go WRONG on
purpose — fail-stop shard loss, a torn checkpoint write, a fail-slow
shard, an admission storm — and assert the declared recovery behavior,
deterministically (seeded streams, tick-indexed fault schedules, no
wall clock).

Multi-device tests carry `needs_devices`; the subprocess smokes at the
bottom re-run them on a forced 4-device CPU so single-device machines
still cover the matrix (fast lane: one golden; slow lane: everything).
"""
from dataclasses import asdict
from pathlib import Path

import numpy as np
import jax
import pytest

from conftest import needs_devices, run_forced_devices

needs4 = needs_devices(4)

# logical (device-count-invariant) integer stats: equal across local /
# meshed / resharded runs of the same stream
STAT_KEYS = ("ticks", "emitted_total", "reduce_msgs", "broadcast_msgs",
             "cross_part_msgs", "dropped", "route_dropped",
             "queries_admitted", "queries_answered", "suppressed")


def _stats(pipe):
    m = asdict(pipe.metrics)
    return {k: m[k] for k in STAT_KEYS}


def _stream(n=32, d_in=8, n_events=150, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, n_events),
                      rng.integers(0, n, n_events)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=d_in).astype(np.float32) for v in range(n)}
    return edges, feats


def _build(D, S=1, n=32, d_in=8, **cfg_kw):
    from repro.core import windowing as win
    from repro.core.pipeline import D3Pipeline, PipelineConfig
    from repro.graph.sage import GraphSAGE
    from repro.launch.mesh import make_stream_mesh
    model = GraphSAGE((d_in, d_in, d_in))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=32, edge_cap=128, repl_cap=128,
                         feat_cap=128, edge_tick_cap=32, max_nodes=n,
                         n_stages=S,
                         window=win.WindowConfig(kind=win.SESSION,
                                                 interval=3), **cfg_kw)
    mesh = make_stream_mesh(D * S, stage=S) if D else None
    return D3Pipeline(model, params, cfg, mesh=mesh)


def _feed(pipe, edges, feats, driver, tick_edges=16):
    chunks = [edges[i:i + tick_edges]
              for i in range(0, len(edges), tick_edges)]
    rows = [[(int(v), feats[int(v)]) for e in c for v in set(map(int, e))]
            for c in chunks]
    if driver == "tick":
        for c, r in zip(chunks, rows):
            pipe.tick(c, r)
    else:
        pipe.run_super_tick(chunks, rows)


def _run(D, edges, feats, driver="tick", reshard_mesh=None, S=1, **cfg_kw):
    pipe = _build(D, S=S, **cfg_kw)
    half = (len(edges) // 32) * 16          # chunk-aligned midpoint
    _feed(pipe, edges[:half], feats, driver)
    if reshard_mesh is not None:
        pipe.reshard(reshard_mesh() if callable(reshard_mesh)
                     else reshard_mesh)
    _feed(pipe, edges[half:], feats, driver)
    pipe.flush(max_ticks=128)
    return np.asarray(jax.device_get(pipe.sink)), _stats(pipe), pipe


# ----------------------------------------------------- reshard goldens
@pytest.fixture(scope="module")
def golden_case():
    edges, feats = _stream()
    sink, stats, _ = _run(None, edges, feats)
    return edges, feats, sink, stats


@needs4
@pytest.mark.parametrize("driver", ["tick", "super"])
@pytest.mark.parametrize("d_old,d_new", [(4, 2), (2, 4)],
                         ids=["down", "up"])
def test_reshard_mid_stream_golden(golden_case, driver, d_old, d_new):
    """Mid-stream reshard (scale-down AND scale-up, both drivers) with
    in-flight windows: the flushed sink is BIT-equal to the local run and
    every logical integer stat matches exactly. Nothing dropped."""
    from repro.launch.mesh import make_stream_mesh
    edges, feats, base_sink, base_stats = golden_case
    sink, stats, _ = _run(d_old, edges, feats, driver,
                          reshard_mesh=lambda: make_stream_mesh(d_new))
    np.testing.assert_array_equal(base_sink, sink)
    assert stats == base_stats
    assert stats["dropped"] == 0 and stats["route_dropped"] == 0


@needs4
def test_reshard_to_local_and_survivors(golden_case):
    """Degenerate directions: mesh -> LocalRouter, and a survivor mesh
    built from the live mesh minus 'lost' shards."""
    from repro.launch.mesh import make_stream_mesh, survivor_mesh
    edges, feats, base_sink, _ = golden_case
    sink, _, pipe = _run(4, edges, feats, reshard_mesh=lambda: None)
    np.testing.assert_array_equal(base_sink, sink)
    assert pipe.mesh is None
    sink2, stats2, pipe2 = _run(
        4, edges, feats,
        reshard_mesh=lambda: survivor_mesh(make_stream_mesh(4), [1, 3]))
    np.testing.assert_array_equal(base_sink, sink2)
    assert pipe2._n_data == 2 and stats2["route_dropped"] == 0


@needs4
def test_reshard_capped_defer_rings_survive(golden_case):
    """Capped wire (route_cap set, unbounded defer): the defer rings hold
    in-flight rows across the reshard — ZERO route drops. Deferral shifts
    rows across tick boundaries, so vs the uncapped local run the sink is
    fixed-point (allclose), not bit, equal."""
    from repro.launch.mesh import make_stream_mesh
    edges, feats, base_sink, _ = golden_case
    sink, stats, _ = _run(4, edges, feats,
                          reshard_mesh=lambda: make_stream_mesh(2),
                          route_cap=8, route_defer_cap=None)
    np.testing.assert_allclose(base_sink, sink, rtol=1e-5, atol=1e-5)
    assert stats["route_dropped"] == 0 and stats["dropped"] == 0


@needs4
@pytest.mark.parametrize("driver", ["tick", "super"])
def test_reshard_stage_grid_data_axis(golden_case, driver):
    """2-D grid, data-axis reshard (S=2, D=2 -> D=1): bit-equal to the
    uninterrupted SAME-stage-count run (S>1 schedules are fixed-point,
    not bit, equal to S=1 — PR7), allclose to the local run."""
    from repro.launch.mesh import make_stream_mesh
    edges, feats, base_sink, _ = golden_case
    ref, ref_stats, _ = _run(2, edges, feats, driver, S=2)
    sink, stats, _ = _run(2, edges, feats, driver, S=2,
                          reshard_mesh=lambda: make_stream_mesh(2, stage=2))
    np.testing.assert_array_equal(ref, sink)
    assert stats == ref_stats
    np.testing.assert_allclose(base_sink, sink, rtol=1e-5, atol=1e-5)


@needs4
def test_reshard_stage_change_needs_quiescence(golden_case):
    """Changing the STAGE count with rows still in the stage ring raises
    (flush to quiescence first); after a flush it succeeds, and the
    result is allclose to the local run (stage-count change re-schedules
    the float reductions — fixed-point, not bit, equality)."""
    from repro.launch.mesh import make_stream_mesh
    edges, feats, base_sink, _ = golden_case
    pipe = _build(2, S=2)
    _feed(pipe, edges[:96], feats, "tick")   # leaves rows in the ring
    with pytest.raises(RuntimeError, match="flush"):
        pipe.reshard(make_stream_mesh(4))
    pipe.flush(max_ticks=128)
    pipe.reshard(make_stream_mesh(4))
    _feed(pipe, edges[96:], feats, "tick")
    pipe.flush(max_ticks=128)
    sink = np.asarray(jax.device_get(pipe.sink))
    np.testing.assert_allclose(base_sink, sink, rtol=1e-5, atol=1e-5)


@needs4
def test_straggler_remap_on_stage_grid():
    """Fail-slow shard under a 2-stage grid: the synthetic wall schedule
    flags the slow data shard, `mitigate_stragglers()` reshards onto the
    survivors, and `parts_per_shard()` re-maps end-to-end."""
    from repro.ft.chaos import ChaosConfig, scenario_slow_shard
    rep = scenario_slow_shard(ChaosConfig(), d_old=2, n_stages=2)
    assert rep["plan"] is not None and rep["n_data_after"] == 1
    assert [p.tolist() for p in rep["parts_after"]] == [[0, 1, 2, 3]]
    assert rep["dropped"] == 0 and rep["route_dropped"] == 0


# ----------------------------------------------------- chaos scenarios
@needs4
@pytest.mark.parametrize("driver", ["tick", "super"])
def test_chaos_failstop_recovery_bit_equal(tmp_path, driver):
    """The ISSUE 10 acceptance scenario: hub-heavy spike + fail-stop loss
    of 2/4 shards mid-stream -> checkpoint-restore + reshard onto the
    survivor mesh + replay. dropped == 0, route_dropped == 0, the held
    consistent answers are bit-equal to the uninterrupted oracle's, and
    the post-recovery sink is bit-equal to the oracle run."""
    from repro.ft.chaos import ChaosConfig, scenario_failstop
    rep = scenario_failstop(ChaosConfig(driver=driver), tmp_path)
    assert rep["dropped"] == 0 and rep["route_dropped"] == 0
    np.testing.assert_array_equal(rep["oracle_sink"], rep["chaos_sink"])
    assert rep["oracle_answers"] and (set(rep["oracle_answers"])
                                      == set(rep["chaos_answers"]))
    for qid, oa in rep["oracle_answers"].items():
        ca = rep["chaos_answers"][qid]
        assert ca.ok and oa.ok
        np.testing.assert_array_equal(oa.vec, ca.vec)
    assert rep["restored_step"] == rep["cut"]
    assert rep["stats"]["degraded"] is None         # restored to normal
    assert rep["stats"]["degraded_ticks"] > 0       # but it WAS degraded


def test_chaos_truncated_checkpoint(tmp_path):
    """Torn checkpoint write: explicit-step restore fails loudly with
    step + path; latest-restore warns and falls back a generation."""
    from repro.ft.chaos import ChaosConfig, scenario_truncated_checkpoint
    rep = scenario_truncated_checkpoint(ChaosConfig(), tmp_path)
    assert rep["explicit_error"] is not None
    assert f"step {rep['torn_step']}" in rep["explicit_error"]
    assert ".ckpt" in rep["explicit_error"]
    assert rep["restored_step"] == rep["torn_step"] - 1
    assert rep["fallback_warned"]


@needs4
def test_chaos_slow_shard_mitigated():
    """Fail-slow shard: flagged by the deterministic wall schedule, then
    resharded away — it owns zero parts afterwards, nothing dropped."""
    from repro.ft.chaos import ChaosConfig, scenario_slow_shard
    cfg = ChaosConfig()
    rep = scenario_slow_shard(cfg)
    assert rep["plan"] is not None and rep["mitigated_at_chunk"] is not None
    assert rep["n_data_after"] == 2                 # 4 -> 2 (divisor of 4)
    assert sum(len(p) for p in rep["parts_after"]) == cfg.n_parts
    assert rep["dropped"] == 0 and rep["route_dropped"] == 0


def test_chaos_admission_storm_degrades_observably():
    """A 96-query burst against an 8/tick admission budget: the session
    sheds beyond the threshold, bound-retries the retriable failures,
    late-materializing endpoints answer ok on a retry, and every counter
    lands in latency_stats(). Nothing silent, nothing stuck."""
    from repro.ft.chaos import ChaosConfig, scenario_admission_storm
    rep = scenario_admission_storm(ChaosConfig())
    st = rep["stats"]
    assert st["shed"] > 0 and st["retried"] > 0
    assert rep["storm_resolved"] == rep["n_storm"]
    assert rep["late_ok"] and all(rep["late_ok"].values())
    assert rep["outstanding"] == 0
    assert rep["dropped"] == 0 and rep["route_dropped"] == 0


# ------------------------------------------- ServeSession degraded mode
def _serve(**kw):
    from repro.ft.chaos import ChaosConfig, build_pipeline
    from repro.serve.session import ServeSession
    return ServeSession(build_pipeline(ChaosConfig()), driver="tick", **kw)


def _tick_edges(session, edges, feats):
    rows = [(int(v), feats[int(v)]) for e in edges for v in set(map(int, e))]
    session.advance(edges, rows)


def test_session_shed_threshold():
    """Submissions beyond shed_threshold get an immediate ok=False shed
    answer instead of unbounded queue growth."""
    s = _serve(shed_threshold=4)
    qids = s.submit_embed(range(8))
    st = s.latency_stats()
    assert st["shed"] == 6                # 2 queued count double (known)
    shed = [q for q in qids if q in s.answers]
    assert len(shed) == 6 and all(not s.answers[q].ok for q in shed)


def test_session_degraded_holds_consistent():
    """degrade(): stale_ok flows, consistent held until restore_normal();
    the declared reason + degraded tick count surface in stats."""
    from repro.ft.chaos import ChaosConfig, hub_heavy_stream
    cfg = ChaosConfig()
    edges, feats, _ = hub_heavy_stream(cfg)
    s = _serve()
    _tick_edges(s, edges[:32], feats)
    s.flush()                              # materialize some embeddings
    vid = int(edges[0, 0])
    s.degrade("drill")
    q_stale = s.submit_embed([vid])
    q_cons = s.submit_embed([vid], consistent=True)
    for _ in range(3):
        s.advance(None, None)
    assert s.degraded == "drill"
    assert q_stale[0] in s.answers and s.answers[q_stale[0]].ok
    assert q_cons[0] not in s.answers      # held in the host queue
    st = s.latency_stats()
    assert st["degraded"] == "drill" and st["degraded_ticks"] == 3
    s.restore_normal()
    for _ in range(3):
        s.advance(None, None)
    s.flush()
    assert q_cons[0] in s.answers and s.answers[q_cons[0]].ok
    assert s.latency_stats()["degraded"] is None


def test_session_bounded_retry_backoff():
    """A retriable ok=False answer (unknown vertex) is resubmitted under
    the SAME qid with exponential tick backoff, capped at max_retries;
    exhaustion surfaces as a final failed answer + counter."""
    s = _serve(max_retries=2, retry_backoff_ticks=1)
    q = s.submit_embed([47])               # never materializes
    ticks = 0
    while q[0] not in s.answers and ticks < 32:
        s.advance(None, None)
        ticks += 1
    st = s.latency_stats()
    assert q[0] in s.answers and not s.answers[q[0]].ok
    assert st["retried"] == 2 and st["retry_exhausted"] == 1
    assert s.outstanding == 0


def test_session_retry_state_capped_by_max_retained():
    """Retry state rides the max_retained bound: beyond it the OLDEST
    retry gives up with a final failed answer (counted), so a hostile
    failure stream cannot grow host state without bound."""
    s = _serve(max_retries=8, retry_backoff_ticks=4, max_retained=2)
    qids = s.submit_embed([44, 45, 46, 47])   # all unknown -> all retry
    for _ in range(3):
        s.advance(None, None)
    assert len(s._retry_queue) <= 2
    assert s.latency_stats()["retry_exhausted"] >= 2
    assert all(not s.answers[q].ok for q in qids if q in s.answers)


# ------------------------------------------------- subprocess (forced 4)
def _run_forced4(pytest_args, timeout=540):
    return run_forced_devices(4, Path(__file__), pytest_args, timeout)


def test_reshard_golden_forced4_subprocess():
    """Fast-lane smoke on any machine: one scale-down golden + the
    truncation scenario under a forced 4-device CPU."""
    r = _run_forced4(["-k", "test_reshard_mid_stream_golden and tick "
                            "and down"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_chaos_full_matrix_forced4_subprocess():
    """Slow lane (CI `chaos` job runs this in-process): the full reshard
    golden matrix + every chaos scenario on a forced 4-device CPU."""
    r = _run_forced4(["-k", "not subprocess"], timeout=1800)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
