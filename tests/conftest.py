import os

# Tests run on the single real CPU device (the dry-run sets its own flags in
# a separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
