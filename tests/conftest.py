import os

# Tests run on the single real CPU device (the dry-run sets its own flags in
# a separate process). Keep XLA quiet and deterministic; optimization level
# 0 cuts compile time ~25% across the suite with identical semantics (the
# suite asserts numerics, never runtime perf).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false "
                                   "--xla_backend_optimization_level=0")

import subprocess
import sys
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def needs_devices(n: int):
    """Skip marker: the test needs >= n jax devices. The suite's default
    environment has ONE real CPU device; CI's forced-device lanes (and the
    subprocess smokes below) set
    XLA_FLAGS=--xla_force_host_platform_device_count=<n> so these tests run
    there in-process. Usage: `needs4 = needs_devices(4)` at module scope."""
    import jax
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs >={n} devices (CI lane forces an {n}-device "
               "CPU backend)")


def run_forced_devices(n: int, test_file, pytest_args=(), timeout=540):
    """Re-run `test_file` under pytest in a subprocess whose XLA backend is
    forced to n CPU devices — the shared smoke harness for multi-device
    suites on single-device machines (jax device count is fixed at backend
    init, so a fresh process is the only way to widen it mid-suite)."""
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": os.environ.get("HOME", "/root"), "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n} "
                        "--xla_backend_optimization_level=0"}
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(test_file)] + list(pytest_args),
        env=env, cwd=str(REPO), capture_output=True, text=True,
        timeout=timeout)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# ------------------------------------------------------- shared pipelines
# Building + streaming + flushing a pipeline costs seconds (jit compiles
# dominate); read-only assertions share ONE session-scoped instance instead
# of rebuilding per test. Tests that mutate pipeline state must build their
# own via the factories inside each test module.

@pytest.fixture(scope="session")
def stream_case():
    """The canonical small stream (seed 0): 60 nodes, ~200 edges, d_in 8."""
    rng = np.random.default_rng(0)
    n_nodes, n_edges, d_in = 60, 200, 8
    edges = np.stack([rng.integers(0, n_nodes, n_edges),
                      rng.integers(0, n_nodes, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    return SimpleNamespace(edges=edges, feats=feats,
                           n_nodes=n_nodes, d_in=d_in)


def _build_pipe(case, window):
    import jax
    from repro.core.pipeline import D3Pipeline, PipelineConfig
    from repro.graph.sage import GraphSAGE
    model = GraphSAGE((case.d_in, 16, 16))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=64, edge_cap=256, repl_cap=256,
                         feat_cap=512, edge_tick_cap=64,
                         max_nodes=case.n_nodes, window=window)
    return model, params, D3Pipeline(model, params, cfg)


@pytest.fixture(scope="session")
def streamed_pipeline(stream_case):
    """stream_case fully streamed (per-tick driver) + flushed, STREAMING
    policy. READ-ONLY: do not tick or mutate it."""
    from repro.core import windowing as win
    model, params, pipe = _build_pipe(
        stream_case, win.WindowConfig(kind=win.STREAMING))
    pipe.run_stream(stream_case.edges, stream_case.feats, tick_edges=32)
    pipe.flush(max_ticks=128)
    return SimpleNamespace(model=model, params=params, pipe=pipe,
                           case=stream_case)


@pytest.fixture(scope="session")
def super_streamed_pipeline(stream_case):
    """Same stream driven by the super-tick driver. READ-ONLY."""
    from repro.core import windowing as win
    model, params, pipe = _build_pipe(
        stream_case, win.WindowConfig(kind=win.STREAMING))
    pipe.run_stream_super(stream_case.edges, stream_case.feats,
                          tick_edges=32, super_ticks=4)
    pipe.flush_super(max_ticks=128, T=4)
    return SimpleNamespace(model=model, params=params, pipe=pipe,
                           case=stream_case)
