"""Checkpoint/restore (incl. mid-window in-flight state), elastic rescale,
straggler planning."""
import numpy as np
import jax
import pytest

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import rescale_parts, shard_views
from repro.ft.stragglers import StragglerMitigator, speculative_chunks
from repro.graph.sage import GraphSAGE


def make_pipe(window=None, seed=0, n_nodes=40):
    model = GraphSAGE((6, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=64, edge_cap=256, repl_cap=256,
                         feat_cap=256, edge_tick_cap=64, max_nodes=n_nodes,
                         window=window or win.WindowConfig(kind=win.SESSION,
                                                           interval=4),
                         seed=seed)
    return model, params, D3Pipeline(model, params, cfg)


def make_stream(seed=0, n_nodes=40, n_edges=120, d=6):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n_nodes, n_edges),
                      rng.integers(0, n_nodes, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=d).astype(np.float32) for v in range(n_nodes)}
    return edges, feats


def test_checkpoint_restart_mid_stream(tmp_path):
    """Kill the pipeline mid-stream (with windows pending = in-flight
    events) and restore into a FRESH pipeline; the continued run must equal
    the uninterrupted run AND the static oracle."""
    edges, feats = make_stream()
    half = len(edges) // 2

    model, params, pipe = make_pipe()
    pipe.run_stream(edges[:half], feats, tick_edges=16)
    mgr = CheckpointManager(tmp_path / "ckpt")
    mgr.save_pipeline(step=1, pipe=pipe)     # windows still pending here

    # "crash": build a brand-new pipeline and restore
    _, _, pipe2 = make_pipe()
    got = mgr.restore_pipeline(pipe2)
    assert got == 1
    pipe2.run_stream(edges[half:], feats, tick_edges=16)
    pipe2.flush(max_ticks=128)

    g, _ = build_snapshot(edges, feats, 6, 40)
    ref = np.asarray(oracle_embeddings(model, params, g))
    emb = pipe2.embeddings()
    touched = set(np.unique(edges).tolist())   # isolated vertices never emit
    assert len(emb) == len(touched)
    for vid, vec in emb.items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("where", ["local", "mesh"])
def test_checkpoint_restores_pending_consistent_queries(tmp_path, where):
    """A carry checkpointed with HELD `consistent` point queries (the
    query plane's in-flight state) must restore into a fresh pipeline and
    answer them identically — same qids, same answer ticks, bit-equal
    payloads — on the LocalRouter and on a mesh."""
    from repro.launch.mesh import make_stream_mesh
    from repro.serve.query import KIND_EMBED, KIND_LINK

    edges, feats = make_stream()
    mesh = make_stream_mesh(1) if where == "mesh" else None

    def make_qpipe():
        model = GraphSAGE((6, 12, 12))
        params = model.init(jax.random.key(0))
        cfg = PipelineConfig(
            n_parts=4, node_cap=64, edge_cap=256, repl_cap=256,
            feat_cap=256, edge_tick_cap=64, max_nodes=40, query_cap=8,
            window=win.WindowConfig(kind=win.TUMBLING, interval=4))
        return D3Pipeline(model, params, cfg, mesh=mesh)

    u, v = int(edges[0, 0]), int(edges[0, 1])
    pipe = make_qpipe()
    pipe.run_stream(edges[:80], feats, tick_edges=16)
    pipe.tick(edges[80:], queries=[(1, KIND_EMBED, u, True),
                                   (2, KIND_LINK, u, v, True),
                                   (3, KIND_EMBED, v, False)])
    pipe.drain_answers()                   # anything already answered
    held = int(np.asarray(jax.device_get(pipe.queries.pending)).sum())
    assert held > 0, "test needs queries still pending at the cut"

    mgr = CheckpointManager(tmp_path / "ckpt")
    mgr.save_pipeline(step=1, pipe=pipe)
    pipe2 = make_qpipe()
    assert mgr.restore_pipeline(pipe2) == 1
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(pipe2.queries.pending)),
        np.asarray(jax.device_get(pipe.queries.pending)))

    def finish(p):
        p.flush(max_ticks=128)
        ans = p.drain_answers()
        order = np.argsort(ans["qid"])
        return {k: val[order] for k, val in ans.items()}

    a, b = finish(pipe), finish(pipe2)
    assert a["qid"].size == held
    np.testing.assert_array_equal(b["qid"], a["qid"])
    np.testing.assert_array_equal(b["tick"], a["tick"])
    np.testing.assert_array_equal(b["ok"], a["ok"])
    np.testing.assert_array_equal(b["vec"], a["vec"])
    np.testing.assert_array_equal(b["score"], a["score"])


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": np.arange(4)})
    assert mgr.latest().step == 4
    assert len(list(tmp_path.glob("*.ckpt"))) == 2
    tree, step = mgr.restore({"a": np.zeros(4, np.int64)})
    assert step == 4
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.arange(4))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    mgr.save(7, {"x": np.ones((8, 8))})
    mgr.wait()
    tree, step = mgr.restore({"x": np.zeros((8, 8))})
    assert step == 7


def test_checkpoint_crc_detects_corruption(tmp_path):
    """ISSUE 10: every checkpoint payload carries a CRC32 of the
    compressed blob. Flip ONE byte of a real checkpoint: an explicit-step
    restore fails loudly (step + path in the message), and a latest-step
    restore warns and falls back to the previous kept generation."""
    from repro.ft.checkpoint import CheckpointCorruptError
    mgr = CheckpointManager(tmp_path, keep=3)
    x1 = np.arange(64, dtype=np.float32).reshape(8, 8)
    mgr.save(1, {"x": x1})
    mgr.save(2, {"x": x1 + 1.0})
    info = mgr.latest()
    blob = bytearray(info.path.read_bytes())
    blob[len(blob) // 2] ^= 0xFF                      # one flipped byte
    info.path.write_bytes(bytes(blob))

    with pytest.raises(CheckpointCorruptError, match=r"step 2"):
        mgr.restore({"x": np.zeros((8, 8), np.float32)}, step=2)
    with pytest.warns(UserWarning, match="falling back"):
        tree, step = mgr.restore({"x": np.zeros((8, 8), np.float32)})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["x"]), x1)


def test_rescale_plan_properties():
    plan = rescale_parts(8, 16, 64)
    # every logical part lands on a valid new shard; moves are minimal-ish
    for lp, old, new in plan.moves:
        assert 0 <= new < 16
    # scale-up never leaves a new shard empty
    views = shard_views(64, 16, 64)
    assert all(len(v) > 0 for v in views)
    # scale-down to 5 (non-divisor) still covers all shards
    views5 = shard_views(64, 5, 64)
    assert all(len(v) > 0 for v in views5)
    assert sum(len(v) for v in views5) == 64


def test_failure_recovery_rescale(tmp_path):
    """Checkpoint, 'lose a machine' (parallelism 2 -> 1), restore, verify
    exactness — the Alg. 5 remap moves keyed state without repartitioning."""
    edges, feats = make_stream(seed=2)
    model, params, pipe = make_pipe(seed=2)
    pipe.cfg.base_parallelism = 2
    pipe.run_stream(edges[:60], feats, tick_edges=16)
    mgr = CheckpointManager(tmp_path)
    mgr.save_pipeline(step=5, pipe=pipe)

    _, _, pipe2 = make_pipe(seed=2)
    from repro.ft.elastic import simulate_failure_and_recover
    cfg_before = pipe2.cfg
    step, plan, new_cfg = simulate_failure_and_recover(pipe2, mgr, 5,
                                                       new_parallelism=1)
    assert step == 5 and pipe2.cfg.base_parallelism == 1
    # the recovery must NOT mutate the old config in place: it returns a
    # fresh validated PipelineConfig and installs it on the pipeline
    assert new_cfg is pipe2.cfg and new_cfg is not cfg_before
    assert cfg_before.base_parallelism == 2
    pipe2.run_stream(edges[60:], feats, tick_edges=16)
    pipe2.flush(max_ticks=128)
    g, _ = build_snapshot(edges, feats, 6, 40)
    ref = np.asarray(oracle_embeddings(model, params, g))
    for vid, vec in pipe2.embeddings().items():
        np.testing.assert_allclose(vec, ref[vid], rtol=1e-4, atol=1e-4)


def test_straggler_detection_and_steal():
    m = StragglerMitigator(n_shards=4, patience=2)
    busy = np.array([10, 10, 10, 100])
    m.observe_tick(1.0, busy)          # establishes EWMA
    for _ in range(3):
        m.observe_tick(5.0, busy)      # shard 3 consistently slow
    assert 3 in m.persistent_stragglers()
    parts = [np.arange(i * 16, (i + 1) * 16) for i in range(4)]
    overrides = m.plan_work_steal(parts, busy)
    assert overrides and all(v != 3 for v in overrides.values())


def test_drivers_feed_straggler_mitigator():
    """ISSUE 9: with the telemetry plane on, BOTH pipeline drivers feed
    `observe_tick` (per-tick wall + per-shard busy proxies) and a
    synthetically slowed shard is flagged and re-mapped off itself via
    the pipeline's own part map."""
    from dataclasses import replace
    edges, feats = make_stream()
    model = GraphSAGE((6, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=64, edge_cap=256,
                         repl_cap=256, feat_cap=256, edge_tick_cap=64,
                         max_nodes=40, telemetry=True)
    pipe = D3Pipeline(model, params, cfg)
    assert pipe.straggler is not None and pipe.straggler.ticks_observed == 0
    pipe.run_stream(edges[:48], feats, tick_edges=16)     # per-tick driver
    n1 = pipe.straggler.ticks_observed
    assert n1 == 3 and pipe.straggler._ewma > 0.0
    pipe.run_super_tick(T=4)                              # scan driver
    assert pipe.straggler.ticks_observed == n1 + 1
    # telemetry off: the mitigator is not even constructed
    off = D3Pipeline(model, params, replace(cfg, telemetry=False))
    assert off.straggler is None

    # synthetically slow shard 2: inflate the wall clock past threshold x
    # EWMA with shard 2 carrying the busy mass, past the patience window
    m = StragglerMitigator(n_shards=4, patience=2)
    parts = [np.arange(d, 16, 4) for d in range(4)]       # pipeline-style map
    busy = np.array([5, 5, 80, 5])
    m.observe_tick(0.01, np.array([20, 20, 20, 20]))      # healthy baseline
    for _ in range(3):
        flagged = m.observe_tick(0.05, busy)
        assert flagged == [2]
    assert m.persistent_stragglers() == [2]
    overrides = m.plan_work_steal(parts, busy)
    moved = {lp for lp in overrides}
    assert moved and moved.issubset(set(parts[2].tolist()))
    assert all(tgt != 2 for tgt in overrides.values())


def test_speculative_chunks():
    started = {0: 0.0, 1: 5.0, 2: 9.0}
    assert speculative_chunks([0, 1, 2], started, now_s=10.0,
                              timeout_s=4.0) == [0, 1]
