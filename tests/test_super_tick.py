"""Golden equivalence of the two pipeline drivers (ISSUE 1 tentpole).

The super-tick driver (`run_super_tick`: one jitted `lax.scan` over T
micro-ticks x L layers) must produce the SAME materialized embeddings as
the per-tick reference driver (`tick()`), and both must match the static
oracle on the final snapshot — across all four window policies.
"""
import numpy as np
import jax
import pytest

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.graph.sage import GraphSAGE

N_NODES, D_IN = 48, 8


def make_stream(seed=0, n_edges=160):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, N_NODES, n_edges),
                      rng.integers(0, N_NODES, n_edges)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=D_IN).astype(np.float32)
             for v in range(N_NODES)}
    return edges, feats


def build_pipe(window):
    model = GraphSAGE((D_IN, 12, 12))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=4, node_cap=48, edge_cap=192, repl_cap=192,
                         feat_cap=256, edge_tick_cap=48, max_nodes=N_NODES,
                         window=window)
    return model, params, D3Pipeline(model, params, cfg)


def test_super_tick_matches_per_tick_and_oracle_streaming(
        streamed_pipeline, super_streamed_pipeline):
    """STREAMING golden triplet on the shared session pipelines: the two
    drivers ran the SAME stream with the SAME tick boundaries, so their
    sinks must agree bit-for-bit at fp tolerance, and both match the static
    oracle."""
    ref, sup = streamed_pipeline, super_streamed_pipeline
    e_ref, e_sup = ref.pipe.embeddings(), sup.pipe.embeddings()
    assert set(e_ref) == set(e_sup)
    for vid in e_ref:
        np.testing.assert_allclose(e_sup[vid], e_ref[vid],
                                   rtol=1e-5, atol=1e-5)
    g, _ = build_snapshot(ref.case.edges, ref.case.feats,
                          ref.case.d_in, ref.case.n_nodes)
    oracle = np.asarray(oracle_embeddings(ref.model, ref.params, g))
    for vid in e_sup:
        np.testing.assert_allclose(e_sup[vid], oracle[vid],
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", [win.TUMBLING, win.SESSION, win.ADAPTIVE])
def test_super_tick_matches_per_tick_and_oracle(kind):
    edges, feats = make_stream()
    w = win.WindowConfig(kind=kind, interval=3)

    model, params, ref = build_pipe(w)
    ref.run_stream(edges, feats, tick_edges=32)
    ref.flush(max_ticks=96)

    _, _, sup = build_pipe(w)
    sup.run_stream_super(edges, feats, tick_edges=32, super_ticks=4)
    sup.flush_super(max_ticks=96, T=4)

    e_ref, e_sup = ref.embeddings(), sup.embeddings()
    assert set(e_ref) == set(e_sup)
    for vid in e_ref:
        np.testing.assert_allclose(e_sup[vid], e_ref[vid],
                                   rtol=1e-5, atol=1e-5)

    g, _ = build_snapshot(edges, feats, D_IN, N_NODES)
    oracle = np.asarray(oracle_embeddings(model, params, g))
    for vid in e_sup:
        np.testing.assert_allclose(e_sup[vid], oracle[vid],
                                   rtol=1e-4, atol=1e-4)


def test_super_tick_single_sync_stats_match_per_tick():
    """The summed TickStats carried through the scan equal the per-tick
    driver's accumulation when tick boundaries line up exactly."""
    edges, feats = make_stream(seed=3, n_edges=128)
    w = win.WindowConfig(kind=win.STREAMING)

    _, _, ref = build_pipe(w)
    ref.run_stream(edges, feats, tick_edges=32)

    _, _, sup = build_pipe(w)
    n_chunks = -(-len(edges) // 32)
    sup.run_stream_super(edges, feats, tick_edges=32, super_ticks=n_chunks)

    # identical tick boundaries -> identical counters (no fp involved)
    assert sup.metrics.ticks == ref.metrics.ticks
    assert sup.metrics.reduce_msgs == ref.metrics.reduce_msgs
    assert sup.metrics.broadcast_msgs == ref.metrics.broadcast_msgs
    assert sup.metrics.cross_part_msgs == ref.metrics.cross_part_msgs
    assert sup.metrics.emitted_total == ref.metrics.emitted_total
    np.testing.assert_array_equal(sup.metrics.busy_logical,
                                  ref.metrics.busy_logical)


def test_flush_super_reports_quiescence():
    edges, feats = make_stream(seed=5, n_edges=96)
    _, _, pipe = build_pipe(win.WindowConfig(kind=win.SESSION, interval=4))
    pipe.run_stream_super(edges, feats, tick_edges=48, super_ticks=2)
    n = pipe.flush_super(max_ticks=64, T=4)
    assert n >= 2
    from repro.core.tick import has_work
    assert not any(bool(has_work(ls)) for ls in pipe.states)
    # a fresh empty super-tick on a quiescent pipeline stays quiescent
    _, quiet = pipe.run_super_tick(T=4)
    assert quiet >= 4


def test_stacked_batches_pad_short_super_tick():
    """Fewer staged ticks than T: the tail is padded with empty ticks and
    the embeddings still match the per-tick reference."""
    edges, feats = make_stream(seed=7, n_edges=64)
    w = win.WindowConfig(kind=win.STREAMING)
    _, _, ref = build_pipe(w)
    ref.run_stream(edges, feats, tick_edges=32)
    ref.flush(max_ticks=64)

    _, _, sup = build_pipe(w)
    sup.run_stream_super(edges, feats, tick_edges=32, super_ticks=8)
    sup.flush_super(max_ticks=64, T=4)
    e_ref, e_sup = ref.embeddings(), sup.embeddings()
    assert set(e_ref) == set(e_sup)
    for vid in e_ref:
        np.testing.assert_allclose(e_sup[vid], e_ref[vid],
                                   rtol=1e-5, atol=1e-5)
