"""Error-feedback gradient compression: conservation + dtype contracts.

The fixed-dtype regression here pins the ISSUE 8 bugfix in
`_compress_leaf`: the error-feedback accumulator runs in f32 internally,
but `sent` and the carried residual must come back in their INPUT dtypes.
Before the fix a bf16/f16 gradient silently promoted both to f32 via
`dequantize_int8` — a dtype-drifting carry that broke fixed-dtype
donation and any `lax.scan` on the second step (exactly where the online
training plane now carries the residual).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dist.grad_compression import (compress_decompress,
                                         init_error_feedback)


def _rand_tree(rng, dtype=jnp.float32):
    return {"w": jnp.asarray(rng.normal(size=(32, 16)), dtype),
            "b": jnp.asarray(rng.normal(size=(16,)), dtype)}


@pytest.mark.parametrize("int8", [True, False], ids=["int8", "f32-wire"])
def test_conservation_invariant(int8):
    """Per step, compression only MOVES mass between the wire and the
    residual: sent + new_res == g + res exactly (f32), so the telescoped
    sum of sent updates + final residual equals the true gradient sum."""
    rng = np.random.default_rng(0)
    res = init_error_feedback(_rand_tree(rng))
    total_sent = jax.tree.map(jnp.zeros_like, res)
    total_true = jax.tree.map(jnp.zeros_like, res)
    for _ in range(8):
        g = _rand_tree(rng)
        sent, new_res = compress_decompress(g, res, int8=int8,
                                            topk_frac=0.25)
        for k in g:
            np.testing.assert_array_equal(
                np.asarray(sent[k] + new_res[k]), np.asarray(g[k] + res[k]))
        total_sent = jax.tree.map(jnp.add, total_sent, sent)
        total_true = jax.tree.map(jnp.add, total_true, g)
        res = new_res
    for k in res:
        np.testing.assert_allclose(np.asarray(total_sent[k] + res[k]),
                                   np.asarray(total_true[k]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32],
                         ids=["bf16", "f16", "f32"])
def test_fixed_dtype_carry(dtype):
    """sent comes back in the gradient's dtype and the residual in the
    residual's dtype — int8 round-trip included (the path that used to
    promote everything to f32)."""
    rng = np.random.default_rng(1)
    g = _rand_tree(rng, dtype)
    res = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    sent, new_res = compress_decompress(g, res, int8=True, topk_frac=0.25)
    for k in g:
        assert sent[k].dtype == dtype
        assert new_res[k].dtype == jnp.float32
    # mixed low-precision residual too: the carry must be a fixed point
    res_lp = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    sent, new_res = compress_decompress(g, res_lp, int8=True)
    for k in g:
        assert sent[k].dtype == dtype and new_res[k].dtype == dtype


def test_scan_carry_is_donation_safe():
    """The residual must survive a lax.scan carry — the shape/dtype
    stability contract the online plane's donated TrainState relies on
    (pre-fix this raised a carry-dtype mismatch on bf16 inputs)."""
    rng = np.random.default_rng(2)
    g = _rand_tree(rng, jnp.bfloat16)
    res0 = jax.tree.map(jnp.zeros_like, g)

    def body(res, _):
        sent, new_res = compress_decompress(g, res, int8=True,
                                            topk_frac=0.5)
        return new_res, jax.tree.map(
            lambda s: jnp.sum(s.astype(jnp.float32)), sent)

    final, sums = jax.lax.scan(body, res0, None, length=4)
    for k in g:
        assert final[k].dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(sums[k])).all()


def test_vmapped_per_part_usage():
    """The training plane vmaps the compressor over the part axis; every
    part must carry its own independent residual."""
    rng = np.random.default_rng(3)
    P = 4
    g = {"w": jnp.asarray(rng.normal(size=(P, 8, 8)), jnp.float32)}
    res = jax.tree.map(jnp.zeros_like, g)
    sent, new_res = jax.vmap(
        lambda gg, rr: compress_decompress(gg, rr, int8=True,
                                           topk_frac=0.25))(g, res)
    assert sent["w"].shape == (P, 8, 8)
    for p in range(P):
        one_s, one_r = compress_decompress(
            {"w": g["w"][p]}, {"w": res["w"][p]}, int8=True, topk_frac=0.25)
        np.testing.assert_allclose(np.asarray(sent["w"][p]),
                                   np.asarray(one_s["w"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(new_res["w"][p]),
                                   np.asarray(one_r["w"]),
                                   rtol=1e-6, atol=1e-6)
