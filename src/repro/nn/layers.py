"""Core layers: Linear, norms, Embedding, MLPs."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.module import Module


@dataclass(frozen=True)
class Linear(Module):
    in_dim: int
    out_dim: int
    use_bias: bool = True
    kernel_init: Callable = init.lecun_normal
    name: str = "linear"

    def init(self, key):
        kk, kb = jax.random.split(key)
        p = {"w": self.kernel_init(kk, (self.in_dim, self.out_dim))}
        if self.use_bias:
            p["b"] = init.zeros(kb, (self.out_dim,))
        return p

    def __call__(self, params, x):
        y = x @ params["w"].astype(x.dtype)
        if self.use_bias:
            y = y + params["b"].astype(x.dtype)
        return y


@dataclass(frozen=True)
class RMSNorm(Module):
    dim: int
    eps: float = 1e-6

    def init(self, key):
        return {"scale": init.ones(key, (self.dim,))}

    def __call__(self, params, x):
        # reduce in f32 for stability regardless of compute dtype
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps).astype(x.dtype)
        return y * params["scale"].astype(x.dtype)


@dataclass(frozen=True)
class LayerNorm(Module):
    dim: int
    eps: float = 1e-5
    use_bias: bool = True

    def init(self, key):
        p = {"scale": init.ones(key, (self.dim,))}
        if self.use_bias:
            p["bias"] = init.zeros(key, (self.dim,))
        return p

    def __call__(self, params, x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = ((xf - mean) * jax.lax.rsqrt(var + self.eps)).astype(x.dtype)
        y = y * params["scale"].astype(x.dtype)
        if self.use_bias:
            y = y + params["bias"].astype(x.dtype)
        return y


@dataclass(frozen=True)
class Embedding(Module):
    vocab: int
    dim: int
    emb_init: Callable = init.normal(0.02)

    def init(self, key):
        return {"table": self.emb_init(key, (self.vocab, self.dim))}

    def __call__(self, params, ids):
        return jnp.take(params["table"], ids, axis=0)

    def attend(self, params, x):
        """Tied-output-head logits: x @ table.T."""
        return x @ params["table"].astype(x.dtype).T


@dataclass(frozen=True)
class MLP(Module):
    """Plain MLP with configurable hidden widths and activation."""
    dims: Sequence[int]                      # [in, h1, ..., out]
    act: Callable = jax.nn.relu
    use_bias: bool = True
    final_act: bool = False
    layers: tuple = field(init=False)

    def __post_init__(self):
        ls = tuple(
            Linear(self.dims[i], self.dims[i + 1], use_bias=self.use_bias)
            for i in range(len(self.dims) - 1)
        )
        object.__setattr__(self, "layers", ls)

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return {f"l{i}": l.init(k) for i, (l, k) in enumerate(zip(self.layers, keys))}

    def __call__(self, params, x):
        n = len(self.layers)
        for i, l in enumerate(self.layers):
            x = l(params[f"l{i}"], x)
            if i < n - 1 or self.final_act:
                x = self.act(x)
        return x


@dataclass(frozen=True)
class SwiGLU(Module):
    """Gated FFN: (silu(x W_g) * x W_u) W_d — the LLaMA-family FFN."""
    dim: int
    hidden: int

    def init(self, key):
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "wg": init.lecun_normal(kg, (self.dim, self.hidden)),
            "wu": init.lecun_normal(ku, (self.dim, self.hidden)),
            "wd": init.lecun_normal(kd, (self.hidden, self.dim)),
        }

    def __call__(self, params, x):
        g = jax.nn.silu(x @ params["wg"].astype(x.dtype))
        u = x @ params["wu"].astype(x.dtype)
        return (g * u) @ params["wd"].astype(x.dtype)
