"""Neural-network substrate: a small functional module system on JAX pytrees.

No flax / optax in this environment — the substrate is built here:
  module.py       parameter-pytree module protocol
  initializers.py weight initializers
  layers.py       Linear / RMSNorm / LayerNorm / Embedding / MLP / SwiGLU
  rotary.py       rotary position embeddings
  attention.py    GQA attention with optional KV cache + distributed decode
  moe.py          top-k token-choice MoE with capacity-sorted dispatch
  transformer.py  scanned decoder-only transformer (dense + MoE)
"""
from repro.nn import initializers, layers, rotary, attention, moe, transformer  # noqa: F401
from repro.nn.module import Module  # noqa: F401
