"""Grouped-query attention with RoPE and KV-cache decode.

The training/prefill path can route through the Pallas flash-attention
kernel (kernels/flash_attention) when `use_flash=True`; the pure-jnp path is
the oracle and the CPU default. Decode attends one (or a few) new tokens
against a cache; the distributed sequence-sharded decode lives in
repro/dist/decode.py (LSE-combine across shards).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.module import Module
from repro.nn.rotary import apply_rope

NEG_INF = -1e30


def causal_mask(q_len: int, kv_len: int, q_offset=0) -> jnp.ndarray:
    """[q_len, kv_len] boolean mask; True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def mha(q, k, v, mask=None, scale=None):
    """Reference attention. q: [B,S,H,D]; k/v: [B,T,Kh,D] with H % Kh == 0."""
    B, S, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh  # queries per kv head
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D)
    qg = q.reshape(B, S, Kh, G, D)
    # scores in f32 for numerical stability
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


def mha_chunked(q, k, v, q_chunk: int = 256, causal: bool = True,
                q_offset=0):
    """Query-chunked attention: scan over q blocks, full softmax over KV per
    block. Peak memory O(B * H * q_chunk * T) instead of O(B * H * S * T) —
    the XLA-native analogue of flash attention's outer loop (the Pallas
    kernel in kernels/flash_attention is the TPU fused version; this path
    lowers on every backend and bounds dry-run memory).

    q: [B,S,H,D]; k/v: [B,T,Kh,D]. Returns [B,S,H,D].
    """
    B, S, H, D = q.shape
    T, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    nq = S // q_chunk
    assert nq * q_chunk == S, (S, q_chunk)
    scale = 1.0 / jnp.sqrt(D)
    qs = q.reshape(B, nq, q_chunk, Kh, G, D).transpose(1, 0, 3, 4, 2, 5)

    def block(carry, xs):
        qi, idx = xs                                  # [B,Kh,G,qc,D], scalar
        logits = jnp.einsum("bkgqd,btkd->bkgqt", qi, k).astype(jnp.float32)
        logits = logits * scale
        if causal:
            q_pos = idx * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
            kv_pos = jnp.arange(T)[None, :]
            logits = jnp.where(kv_pos <= q_pos, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqt,btkd->bkgqd", w, v)
        return carry, out

    _, outs = jax.lax.scan(block, 0, (qs, jnp.arange(nq)))
    # outs: [nq, B, Kh, G, qc, D] -> [B, S, H, D]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)


@dataclass(frozen=True)
class GQAAttention(Module):
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    use_flash: bool = False  # route prefill through Pallas kernel (TPU target)
    q_chunk: int = 0         # >0: chunked attention (memory-bounded)

    def init(self, key):
        kq, kk, kv, ko = jax.random.split(key, 4)
        return {
            "wq": init.lecun_normal(kq, (self.d_model, self.n_heads * self.head_dim)),
            "wk": init.lecun_normal(kk, (self.d_model, self.n_kv * self.head_dim)),
            "wv": init.lecun_normal(kv, (self.d_model, self.n_kv * self.head_dim)),
            "wo": init.lecun_normal(
                ko, (self.n_heads * self.head_dim, self.d_model)),
        }

    def _qkv(self, params, x, positions):
        B, S, _ = x.shape
        q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, self.n_heads, self.head_dim)
        k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, self.n_kv, self.head_dim)
        v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, self.n_kv, self.head_dim)
        q = apply_rope(q, positions, self.rope_theta)
        k = apply_rope(k, positions, self.rope_theta)
        return q, k, v

    def __call__(self, params, x, positions=None):
        """Full (training/prefill) causal self-attention. x: [B,S,d_model]."""
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k, v = self._qkv(params, x, positions)
        if self.use_flash:
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, k, v, causal=True)
        elif self.q_chunk and S > self.q_chunk:
            out = mha_chunked(q, k, v, q_chunk=self.q_chunk, causal=True)
        else:
            out = mha(q, k, v, mask=causal_mask(S, S))
        out = out.reshape(B, S, self.n_heads * self.head_dim)
        return out @ params["wo"].astype(x.dtype)

    def decode(self, params, x, cache_k, cache_v, cache_len):
        """One-token decode. x: [B,1,d]; cache_k/v: [B,T,Kh,D]; cache_len: [B].

        Returns (out [B,1,d], new_cache_k, new_cache_v).
        """
        B, S, _ = x.shape
        assert S == 1
        positions = cache_len[:, None]
        q, k, v = self._qkv(params, x, positions)

        # write the new kv at cache_len: per-row dynamic_update_slice under
        # vmap (a scatter) — a full-tensor where() here makes XLA rewrite
        # (and, fused with mixed dtypes, f32-roundtrip) the entire cache
        # every step (§Perf cell B, iteration 2)
        def _write_row(cache_b, val_b, pos_b):
            return jax.lax.dynamic_update_slice(
                cache_b, val_b[None].astype(cache_b.dtype), (pos_b, 0, 0))

        cache_k = jax.vmap(_write_row)(cache_k, k[:, 0], cache_len)
        cache_v = jax.vmap(_write_row)(cache_v, v[:, 0], cache_len)
        valid = (jnp.arange(cache_k.shape[1])[None, :] <= cache_len[:, None])
        out = decode_attend(q, cache_k, cache_v, valid)
        out = out.reshape(B, 1, self.n_heads * self.head_dim)
        return out @ params["wo"].astype(x.dtype), cache_k, cache_v


def decode_attend(q, cache_k, cache_v, valid):
    """Attend q [B,1,H,D] over cache [B,T,Kh,D] with validity mask [B,T]."""
    B, _, H, D = q.shape
    Kh = cache_k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k).astype(jnp.float32)
    logits = logits / jnp.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", w, cache_v)
    return out.reshape(B, 1, H, D)


def decode_attend_partial(q, cache_k, cache_v, valid):
    """Partial decode attention for sequence-sharded caches.

    Returns (unnormalized out [B,1,H,D] f32, lse-style (max, sumexp)) so shards
    can be combined with a global log-sum-exp reduction (flash-decoding).
    """
    B, _, H, D = q.shape
    Kh = cache_k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k).astype(jnp.float32)
    logits = logits / jnp.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)                  # [B,Kh,G,1]
    # guard fully-masked shards
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(logits - m_safe)
    s = jnp.sum(p, axis=-1, keepdims=True)                       # [B,Kh,G,1]
    out = jnp.einsum("bkgt,btkd->bkgd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, 1, H, D), m_safe.reshape(B, 1, H, 1), s.reshape(B, 1, H, 1)


def combine_partial_decodes(outs, ms, ss):
    """Combine per-shard partial attention (lists or stacked axis 0)."""
    m_all = jnp.max(ms, axis=0)                                   # [B,1,H,1]
    corr = jnp.exp(ms - m_all)
    s_all = jnp.sum(ss * corr, axis=0)
    o_all = jnp.sum(outs * corr, axis=0)
    return o_all / jnp.maximum(s_all, 1e-30)
