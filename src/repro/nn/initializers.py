"""Weight initializers (lecun/glorot/he/truncated-normal), f32 by default.

Params are created in float32 and cast to the compute dtype at the edge of
the step function; optimizer state stays f32 (mixed-precision discipline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _fans(shape: tuple[int, ...], in_axis: int = -2, out_axis: int = -1):
    if len(shape) < 1:
        return 1, 1
    if len(shape) == 1:
        return shape[0], shape[0]
    receptive = int(np.prod([s for i, s in enumerate(shape)
                             if i not in (in_axis % len(shape), out_axis % len(shape))]))
    return shape[in_axis] * receptive, shape[out_axis] * receptive


def variance_scaling(scale: float, mode: str, distribution: str,
                     in_axis: int = -2, out_axis: int = -1):
    def init(key, shape, dtype=jnp.float32, in_axis=in_axis, out_axis=out_axis,
             batch_axes: tuple[int, ...] = ()):
        fans_shape = tuple(s for i, s in enumerate(shape)
                           if i not in {a % len(shape) for a in batch_axes})
        fan_in, fan_out = _fans(fans_shape, in_axis, out_axis)
        denom = {"fan_in": fan_in, "fan_out": fan_out,
                 "fan_avg": (fan_in + fan_out) / 2}[mode]
        var = scale / max(1.0, denom)
        if distribution == "truncated_normal":
            # stddev correction for truncation at 2 sigma
            std = jnp.sqrt(var) / 0.87962566103423978
            return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
        if distribution == "normal":
            return jnp.sqrt(var) * jax.random.normal(key, shape, dtype)
        if distribution == "uniform":
            lim = jnp.sqrt(3 * var)
            return jax.random.uniform(key, shape, dtype, -lim, lim)
        raise ValueError(distribution)

    return init


lecun_normal = variance_scaling(1.0, "fan_in", "truncated_normal")
glorot_uniform = variance_scaling(1.0, "fan_avg", "uniform")
glorot_normal = variance_scaling(1.0, "fan_avg", "truncated_normal")
he_normal = variance_scaling(2.0, "fan_in", "truncated_normal")


def normal(std: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return std * jax.random.normal(key, shape, dtype)

    return init


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)
