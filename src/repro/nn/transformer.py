"""Decoder-only transformer (dense + MoE) with scan-over-layers.

Layers are grouped into repeating patterns so MoE-every-N archs scan over
homogeneous "groups" (e.g. llama4-maverick: [dense, moe] × 24). Parameters
for all groups are stacked on a leading axis and consumed by jax.lax.scan —
this keeps the HLO size O(1) in depth (critical for the 88-layer config and
for CPU compile times in the dry-run).

The loss head is chunked (scan over sequence chunks, rematerialized) so the
[tokens, vocab] logits tensor is never fully materialized — with 202k vocab
that tensor would otherwise dominate memory.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.attention import GQAAttention
from repro.nn.layers import Embedding, RMSNorm, SwiGLU
from repro.nn.moe import MoEConfig, MoELayer
from repro.nn.module import Module


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    moe: Optional[MoEConfig] = None
    rope_theta: float = 500000.0
    dtype: str = "bfloat16"
    loss_chunks: int = 8          # sequence chunks for the CE loss head
    remat: bool = True
    q_chunk: int = 256            # chunked-attention block (0 = full)
    act_pspec: Optional[tuple] = None  # residual-stream sharding constraint
                                       # e.g. (("data",), None, "model")

    @property
    def pattern(self) -> tuple[str, ...]:
        """Block pattern within one scan group."""
        if self.moe is None:
            return ("dense",)
        every = self.moe.every
        return tuple(["dense"] * (every - 1) + ["moe"])

    @property
    def n_groups(self) -> int:
        p = len(self.pattern)
        assert self.n_layers % p == 0, (self.n_layers, p)
        return self.n_layers // p


@dataclass(frozen=True)
class Block(Module):
    """Pre-norm block: x += attn(norm(x)); x += ffn(norm(x))."""
    cfg: TransformerConfig
    kind: str  # "dense" | "moe"

    def __post_init__(self):
        c = self.cfg
        object.__setattr__(self, "attn", GQAAttention(
            c.d_model, c.n_heads, c.n_kv, c.head_dim, c.rope_theta,
            q_chunk=c.q_chunk))
        object.__setattr__(self, "norm1", RMSNorm(c.d_model))
        object.__setattr__(self, "norm2", RMSNorm(c.d_model))
        if self.kind == "moe":
            object.__setattr__(self, "ffn", MoELayer(c.d_model, c.moe))
        else:
            object.__setattr__(self, "ffn", SwiGLU(c.d_model, c.d_ff))

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"norm1": self.norm1.init(k1), "attn": self.attn.init(k2),
                "norm2": self.norm2.init(k3), "ffn": self.ffn.init(k4)}

    def __call__(self, params, x, positions):
        h = self.attn(params["attn"], self.norm1(params["norm1"], x), positions)
        x = x + h
        h_in = self.norm2(params["norm2"], x)
        if self.kind == "moe":
            B, S, d = h_in.shape
            h, aux = self.ffn(params["ffn"], h_in.reshape(B * S, d))
            h = h.reshape(B, S, d)
        else:
            h, aux = self.ffn(params["ffn"], h_in), jnp.zeros((), jnp.float32)
        return x + h, aux

    def decode(self, params, x, ck, cv, cache_len):
        h, ck, cv = self.attn.decode(
            params["attn"], self.norm1(params["norm1"], x), ck, cv, cache_len)
        x = x + h
        h_in = self.norm2(params["norm2"], x)
        if self.kind == "moe":
            B, S, d = h_in.shape
            h, _ = self.ffn(params["ffn"], h_in.reshape(B * S, d))
            h = h.reshape(B, S, d)
        else:
            h = self.ffn(params["ffn"], h_in)
        return x + h, ck, cv


@dataclass(frozen=True)
class TransformerLM(Module):
    cfg: TransformerConfig

    def __post_init__(self):
        blocks = tuple(Block(self.cfg, kind) for kind in self.cfg.pattern)
        object.__setattr__(self, "blocks", blocks)
        object.__setattr__(self, "embed", Embedding(self.cfg.vocab, self.cfg.d_model))
        object.__setattr__(self, "final_norm", RMSNorm(self.cfg.d_model))

    def init(self, key):
        c = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        gkeys = jax.random.split(kb, c.n_groups)

        def one_group(k):
            ks = jax.random.split(k, len(self.blocks))
            return {f"b{i}": b.init(ks[i]) for i, b in enumerate(self.blocks)}

        return {
            "embed": self.embed.init(ke),
            "groups": jax.vmap(one_group)(gkeys),   # stacked [n_groups, ...]
            "final_norm": self.final_norm.init(kh),
            "lm_head": init.lecun_normal(kh, (c.d_model, c.vocab)),
        }

    # ---- forward ----
    def hidden_states(self, params, tokens):
        """tokens [B,S] -> final hidden [B,S,d]."""
        c = self.cfg
        dtype = jnp.dtype(c.dtype)
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self.embed(params["embed"], tokens).astype(dtype)

        def group_fn(x, gp):
            aux = jnp.zeros((), jnp.float32)
            for i, b in enumerate(self.blocks):
                x, a = b(gp[f"b{i}"], x, positions)
                aux = aux + a
            if c.act_pspec is not None:
                from jax.sharding import PartitionSpec
                x = jax.lax.with_sharding_constraint(
                    x, PartitionSpec(*c.act_pspec))
            return x, aux

        if c.remat:
            group_fn = jax.checkpoint(group_fn,
                                      policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(lambda h, gp: group_fn(h, gp), x, params["groups"])
        x = self.final_norm(params["final_norm"], x)
        return x, jnp.sum(auxs)

    def loss(self, params, tokens, labels):
        """Mean next-token CE (labels = tokens shifted by caller; -100 = pad)."""
        c = self.cfg
        x, aux = self.hidden_states(params, tokens)
        B, S, d = x.shape
        n_chunks = min(c.loss_chunks, S)
        while S % n_chunks:
            n_chunks -= 1
        xc = x.reshape(B, n_chunks, S // n_chunks, d).swapaxes(0, 1)
        lc = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)
        head = params["lm_head"]

        @jax.checkpoint
        def chunk_loss(carry, xl):
            xi, li = xl
            logits = (xi @ head.astype(xi.dtype)).astype(jnp.float32)
            valid = li >= 0
            li = jnp.maximum(li, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + jnp.sum(ce), carry[1] + jnp.sum(valid)), None

        (tot, cnt), _ = jax.lax.scan(chunk_loss, (0.0, 0), (xc, lc))
        lb = 0.01 * aux if c.moe is not None else 0.0
        return tot / jnp.maximum(cnt, 1) + lb

    def logits(self, params, tokens):
        x, _ = self.hidden_states(params, tokens)
        return (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)

    # ---- decode ----
    def init_cache(self, batch: int, max_len: int, dtype=None):
        c = self.cfg
        dtype = dtype or jnp.dtype(c.dtype)
        shape = (c.n_groups, len(self.blocks), batch, max_len, c.n_kv, c.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "len": jnp.zeros((batch,), jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens [B,1] -> (logits [B,1,vocab], new cache).

        The cache rides in the scan CARRY and is updated with per-layer
        dynamic_update_slice — one aliased buffer instead of the xs/ys
        double-buffer pair (§Perf cell B iteration 3: the ys-stacking form
        makes XLA shuffle two full cache-sized buffers per step)."""
        c = self.cfg
        dtype = jnp.dtype(c.dtype)
        x = self.embed(params["embed"], tokens).astype(dtype)
        cache_len = cache["len"]

        def group_fn(carry, xs):
            x, ck_all, cv_all, gi = carry
            gp = xs
            for i, b in enumerate(self.blocks):
                ck = ck_all[gi, i]
                cv = cv_all[gi, i]
                x, nk, nv = b.decode(gp[f"b{i}"], x, ck, cv, cache_len)
                ck_all = jax.lax.dynamic_update_slice(
                    ck_all, nk[None, None], (gi, i, 0, 0, 0, 0))
                cv_all = jax.lax.dynamic_update_slice(
                    cv_all, nv[None, None], (gi, i, 0, 0, 0, 0))
            return (x, ck_all, cv_all, gi + 1), None

        (x, nk, nv, _), _ = jax.lax.scan(
            group_fn, (x, cache["k"], cache["v"], jnp.asarray(0, jnp.int32)),
            params["groups"])
        x = self.final_norm(params["final_norm"], x)
        logits = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
        new_cache = {"k": nk, "v": nv, "len": cache_len + 1}
        return logits, new_cache
