"""Minimal functional module protocol.

A Module is a plain Python object carrying *configuration only* (dims,
dtypes, flags). Parameters live in explicit pytrees:

    mod = Linear(4, 8)
    params = mod.init(jax.random.key(0))
    y = mod(params, x)

This keeps everything jit/vmap/scan-friendly: stacking `vmap(mod.init)`
over a key batch yields scanned per-layer parameters.
"""
from __future__ import annotations

from typing import Any

import jax

PyTree = Any


class Module:
    """Base class; subclasses implement init(key)->params and __call__(params, ...)."""

    def init(self, key: jax.Array) -> PyTree:  # pragma: no cover - interface
        raise NotImplementedError

    def __call__(self, params: PyTree, *args, **kwargs):  # pragma: no cover
        raise NotImplementedError


def param_count(params: PyTree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_cast(params: PyTree, dtype) -> PyTree:
    """Cast all floating leaves to `dtype` (leave ints alone)."""
    import jax.numpy as jnp

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, params)
