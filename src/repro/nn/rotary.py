"""Rotary position embeddings (RoPE), half-rotation convention."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2] (f32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Rotate last dim of x ([..., seq, heads, head_dim]) by position.

    positions: [..., seq] int32. Computed in f32 and cast back.
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]                          # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)
