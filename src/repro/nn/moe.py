"""Token-choice top-k Mixture-of-Experts with capacity-sorted dispatch.

Two execution paths:
  * `moe_dense_oracle` — every token through every expert, exact; used by
    tests as the reference (equals sorted dispatch when nothing is dropped).
  * sorted dispatch — tokens argsorted by expert id, packed into a static
    [E, C, d] buffer (capacity C), batched expert GEMMs, scattered back with
    router weights. This is the MegaBlocks-style static-shape TPU mapping;
    overflowing tokens are dropped (standard capacity-factor semantics).

Expert parallelism over the `model` mesh axis lives in repro/dist/moe_ep.py
(shard_map all_to_all dispatch); this module is the single-shard compute.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.module import Module


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden
    every: int = 1            # MoE layer every `every` layers (rest dense)
    n_shared: int = 0         # shared experts always applied
    capacity_factor: float = 1.25
    # explicit expert parallelism: shard_map all_to_all dispatch over this
    # mesh axis (None = let GSPMD infer — it falls back to all-gathers)
    ep_axis: tuple = ()       # e.g. ("model",); dp axes for the token dim
    dp_axes: tuple = ()       # e.g. ("data",) or ("pod", "data")


@dataclass(frozen=True)
class MoELayer(Module):
    d_model: int
    cfg: MoEConfig

    def init(self, key):
        E, d, h = self.cfg.num_experts, self.d_model, self.cfg.d_ff
        kr, kg, ku, kd, ks = jax.random.split(key, 5)
        p = {
            "router": init.normal(0.006)(kr, (d, E)),
            "wg": init.lecun_normal(kg, (E, d, h), batch_axes=(0,)),
            "wu": init.lecun_normal(ku, (E, d, h), batch_axes=(0,)),
            "wd": init.lecun_normal(kd, (E, h, d), batch_axes=(0,)),
        }
        if self.cfg.n_shared:
            kgs, kus, kds = jax.random.split(ks, 3)
            hs = self.cfg.d_ff * self.cfg.n_shared
            p["shared"] = {
                "wg": init.lecun_normal(kgs, (d, hs)),
                "wu": init.lecun_normal(kus, (d, hs)),
                "wd": init.lecun_normal(kds, (hs, d)),
            }
        return p

    def route(self, params, x):
        """x: [T, d] → (expert ids [T,k], weights [T,k], router probs [T,E])."""
        logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, self.cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        return ids, w.astype(x.dtype), probs

    def __call__(self, params, x):
        """x: [T, d] (caller flattens batch×seq). Returns (out [T,d], aux)."""
        if self.cfg.ep_axis:
            return self._ep_call(params, x)
        T, d = x.shape
        cfg = self.cfg
        E, K = cfg.num_experts, cfg.top_k
        ids, w, probs = self.route(params, x)

        # ---- sorted capacity dispatch ----
        # small token counts (decode steps) get dropless capacity: the
        # buffer is tiny there and capacity drops would corrupt decoding.
        if T <= 4 * E:
            C = T * K
        else:
            C = max(1, int(T * K * cfg.capacity_factor / E))
        e_flat = ids.reshape(-1)                                   # [T*K]
        tok_flat = jnp.repeat(jnp.arange(T), K)                    # [T*K]
        w_flat = w.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        tok_sorted = tok_flat[order]
        w_sorted = w_flat[order]
        # position of each entry within its expert segment
        seg_pos = _segment_positions(e_sorted, E)
        keep = seg_pos < C
        slot = jnp.where(keep, e_sorted * C + seg_pos, E * C)      # E*C = trash slot
        # gather tokens into [E*C+1, d] buffer
        buf = jnp.zeros((E * C + 1, d), x.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], x[tok_sorted], 0))
        xe = buf[: E * C].reshape(E, C, d)
        # expert FFN (SwiGLU) as batched GEMMs
        g = jax.nn.silu(jnp.einsum("ecd,edh->ech", xe, params["wg"].astype(x.dtype)))
        u = jnp.einsum("ecd,edh->ech", xe, params["wu"].astype(x.dtype))
        ye = jnp.einsum("ech,ehd->ecd", g * u, params["wd"].astype(x.dtype))
        # scatter back, weighted
        y_flat = ye.reshape(E * C, d)
        contrib = jnp.where(keep[:, None], y_flat[jnp.minimum(slot, E * C - 1)]
                            * w_sorted[:, None], 0)
        out = jnp.zeros_like(x).at[tok_sorted].add(contrib)

        if cfg.n_shared:
            sp = params["shared"]
            sg = jax.nn.silu(x @ sp["wg"].astype(x.dtype))
            su = x @ sp["wu"].astype(x.dtype)
            out = out + (sg * su) @ sp["wd"].astype(x.dtype)

        aux = load_balance_loss(probs, ids, E)
        return out, aux

    def _ep_call(self, params, x):
        """Explicit expert parallelism: shard_map all_to_all dispatch over
        cfg.ep_axis (tokens sharded over cfg.dp_axes). Wire bytes are
        2 x tokens x d instead of GSPMD's all-gather fallbacks."""
        from jax.sharding import PartitionSpec as P
        from repro.dist.moe_ep import moe_ep_apply
        cfg = self.cfg
        ep = cfg.ep_axis[0]
        p_specs = {"router": P(), "wg": P(ep), "wu": P(ep), "wd": P(ep)}
        if cfg.n_shared:
            p_specs["shared"] = {k: P() for k in ("wg", "wu", "wd")}
        fn = jax.shard_map(
            lambda p, xx: moe_ep_apply(self, p, xx, ep),
            in_specs=(p_specs, P(cfg.dp_axes, None)),
            out_specs=P(cfg.dp_axes, None), check_vma=False)
        return fn(params, x), jnp.zeros((), jnp.float32)

    def dense_oracle(self, params, x):
        """Exact MoE (no capacity drops): all experts, weighted combine."""
        ids, w, probs = self.route(params, x)
        g = jax.nn.silu(jnp.einsum("td,edh->teh", x, params["wg"].astype(x.dtype)))
        u = jnp.einsum("td,edh->teh", x, params["wu"].astype(x.dtype))
        y = jnp.einsum("teh,ehd->ted", g * u, params["wd"].astype(x.dtype))
        mask = jax.nn.one_hot(ids, self.cfg.num_experts, dtype=x.dtype)  # [T,K,E]
        comb = jnp.einsum("tke,tk->te", mask, w)
        out = jnp.einsum("ted,te->td", y, comb)
        if self.cfg.n_shared:
            sp = params["shared"]
            sg = jax.nn.silu(x @ sp["wg"].astype(x.dtype))
            su = x @ sp["wu"].astype(x.dtype)
            out = out + (sg * su) @ sp["wd"].astype(x.dtype)
        return out, load_balance_loss(probs, ids, self.cfg.num_experts)


def _segment_positions(sorted_ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    """Rank of each element within its (sorted) segment: 0,1,2,... per id."""
    n = sorted_ids.shape[0]
    counts = jnp.zeros((num_segments,), jnp.int32).at[sorted_ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    return jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]


def load_balance_loss(probs: jnp.ndarray, ids: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style aux loss: E * <f_e . p_e> over experts."""
    T = probs.shape[0]
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * ids.shape[-1])
    p = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * p)
