"""Session-style host APIs over the live sharded state.

`serve/query.py` — event records + the device-side query stage (the
fourth plane of the streaming tick); `serve/session.py` — the host-side
ServeSession that interleaves update chunks with query admissions over
both pipeline drivers and reports end-to-end latency percentiles;
`serve/train_session.py` — the host-side TrainSession that interleaves
update chunks with label admissions for the fifth (training) plane and
reports online-training diagnostics.
"""
from repro.serve.query import (KIND_EMBED, KIND_LINK, AnswerBatch,
                               QueryBatch, QueryState, QueryStats)
from repro.serve.session import ServeSession
from repro.serve.train_session import TrainSession

__all__ = ["KIND_EMBED", "KIND_LINK", "AnswerBatch", "QueryBatch",
           "QueryState", "QueryStats", "ServeSession", "TrainSession"]
