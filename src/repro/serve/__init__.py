"""The query plane: on-device point queries over the live sharded state.

`serve/query.py` — event records + the device-side query stage (the
fourth plane of the streaming tick); `serve/session.py` — the host-side
ServeSession that interleaves update chunks with query admissions over
both pipeline drivers and reports end-to-end latency percentiles.
"""
from repro.serve.query import (KIND_EMBED, KIND_LINK, AnswerBatch,
                               QueryBatch, QueryState, QueryStats)
from repro.serve.session import ServeSession

__all__ = ["KIND_EMBED", "KIND_LINK", "AnswerBatch", "QueryBatch",
           "QueryState", "QueryStats", "ServeSession"]
