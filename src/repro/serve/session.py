"""Host-side serving loop: interleave graph updates and point queries.

`ServeSession` wraps a query-enabled `D3Pipeline` (cfg.query_cap > 0) and
drives EITHER pipeline driver with queries aboard:

  * driver="tick"  — per-tick reference path: queued submissions admit in
    the very next micro-tick (`advance(edges, feats)`);
  * driver="super" — the donated super-tick `lax.scan`: `advance_super`
    stages T update micro-ticks and spreads the queued submissions over
    them, so queries admit while updates are still flowing through the
    same device launch. Answers come back in the launch's single host
    sync.

The session keeps the host-side truth the device never sees: wall-clock
enqueue times per qid. Every harvested answer gets an end-to-end
enqueue->answer latency (submission to host-visible result, INCLUDING the
super-tick batching delay — that is the serving latency a client would
observe) plus tick-domain staleness (answer_tick - issue_tick).
`latency_stats()` reports p50/p95/p99 histogram summaries; when the
pipeline runs with the telemetry plane on (cfg.telemetry, ISSUE 9)
they are also annotated into the trace recorder's metadata
(`serving_p50_ms`/`p95`/`p99`) so a recorded trace carries the serving
latency alongside the per-tick occupancy rows.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.serve.query import KIND_EMBED, KIND_LINK


@dataclass
class Answer:
    """One resolved point query (host view)."""
    qid: int
    kind: int                 # KIND_EMBED | KIND_LINK
    ok: bool                  # False: endpoint never materialized, the
                              # vertex was unknown, or the pending table
                              # overflowed (re-submit in that case)
    vec: np.ndarray           # embedding (KIND_EMBED; zeros otherwise)
    score: float              # link score (KIND_LINK; 0.0 otherwise)
    issue_tick: int
    answer_tick: int
    latency_s: float          # wall-clock enqueue -> host-visible answer;
                              # None for adopted answers (queries restored
                              # from a checkpoint another session issued)

    @property
    def staleness_ticks(self) -> int:
        return self.answer_tick - self.issue_tick


@dataclass
class _PendingMeta:
    enqueued_at: float
    kind: int


@dataclass
class ServeSession:
    pipe: object                                   # a query-enabled D3Pipeline
    driver: str = "super"                          # "super" | "tick"
    super_ticks: int = 8                           # T per device launch
    qid_base: int = 0                              # first qid this session
                                                   # assigns — hand over the
                                                   # previous session's
                                                   # _next_qid when restoring
                                                   # a checkpoint that holds
                                                   # its pending queries
    max_retained: int = 65536                      # retention bound on
                                                   # `answers`: a long-lived
                                                   # serving loop would grow
                                                   # the dict per answer
                                                   # forever; beyond the
                                                   # bound the OLDEST
                                                   # harvested answers are
                                                   # evicted (dict insertion
                                                   # order). Read results
                                                   # promptly or raise it.
    answers: dict = field(default_factory=dict)    # qid -> Answer
    _queue: list = field(default_factory=list)     # un-admitted submissions
    _meta: dict = field(default_factory=dict)      # qid -> _PendingMeta
    _next_qid: int = 0

    def __post_init__(self):
        if self.pipe.cfg.query_cap <= 0:
            raise ValueError(
                "ServeSession needs a query-enabled pipeline: set "
                "PipelineConfig.query_cap > 0 (the query plane is "
                "compiled away at query_cap=0)")
        if self.driver not in ("super", "tick"):
            raise ValueError(f"driver={self.driver!r}: 'super' or 'tick'")
        if self.max_retained <= 0:
            raise ValueError(
                f"max_retained={self.max_retained} must be > 0 (it bounds "
                "the retained-answer dict, not whether answers arrive)")
        self._next_qid = max(self._next_qid, int(self.qid_base))

    # ------------------------------------------------------------- submit
    def _submit(self, rows) -> list:
        now = time.perf_counter()
        qids = []
        for row in rows:
            qid = self._next_qid
            self._next_qid += 1
            self._queue.append((qid,) + row)
            self._meta[qid] = _PendingMeta(enqueued_at=now, kind=row[0])
            qids.append(qid)
        return qids

    def submit_embed(self, vids, consistent: bool = False) -> list:
        """Enqueue embedding reads; returns the assigned qids."""
        return self._submit([(KIND_EMBED, int(v), 0, consistent)
                             for v in np.asarray(vids).reshape(-1)])

    def submit_link(self, pairs, consistent: bool = False) -> list:
        """Enqueue link-score queries for (u, v) pairs; returns qids."""
        return self._submit([(KIND_LINK, int(u), int(v), consistent)
                             for u, v in pairs])

    # ------------------------------------------------------------ advance
    def advance(self, edges=None, feats=None, window=None):
        """One micro-tick (driver='tick'): queued submissions admit now,
        up to the per-tick admission budget (the rest stay queued)."""
        cap = self.pipe.cfg.capacities().query_admissions
        q, self._queue = self._queue[:cap], self._queue[cap:]
        stats = self.pipe.tick(edges, feats, window=window,
                               queries=q or None)
        self._harvest()
        return stats

    def advance_super(self, edge_chunks=None, feat_chunks=None,
                      T=None, window=None, quiet0: int = 0):
        """One super-tick (driver='super'): queued submissions spread
        over the launch's T micro-ticks (earliest first, at most
        `capacities().query_admissions` per tick), so admission
        interleaves with
        the update stream on device. Submissions beyond the launch's
        admission budget stay queued for the next advance — they never
        overflow a tick's fixed-capacity query batch."""
        edge_chunks = list(edge_chunks) if edge_chunks is not None else []
        feat_chunks = list(feat_chunks) if feat_chunks is not None else []
        n = max(len(edge_chunks), len(feat_chunks), 1)
        T = int(T) if T is not None else n
        per_tick = self.pipe.cfg.capacities().query_admissions
        q, self._queue = self._queue[:per_tick * T], self._queue[per_tick * T:]
        q_chunks = [q[i * per_tick: (i + 1) * per_tick] for i in range(T)]
        out = self.pipe.run_super_tick(edge_chunks, feat_chunks, T=T,
                                       window=window, quiet0=quiet0,
                                       query_chunks=q_chunks)
        self._harvest()
        return out

    def step(self, edges=None, feats=None, **kw):
        """Driver-agnostic advance: one tick or one super-tick."""
        if self.driver == "tick":
            return self.advance(edges, feats, **kw)
        e = [edges] if edges is not None else None
        f = [feats] if feats is not None else None
        return self.advance_super(e, f, T=self.super_ticks, **kw)

    def flush(self, max_ticks: int = 128):
        """Drain the pipeline (and any held consistent queries answer at
        the first silent tick)."""
        if self.driver == "tick":
            ran = self.pipe.flush(max_ticks=max_ticks)
        else:
            ran = self.pipe.flush_super(max_ticks=max_ticks,
                                        T=self.super_ticks)
        self._harvest()
        return ran

    # ------------------------------------------------------------ results
    def _harvest(self):
        cols = self.pipe.drain_answers()
        t_now = time.perf_counter()
        for i in range(len(cols["qid"])):
            qid = int(cols["qid"][i])
            meta = self._meta.pop(qid, None)
            self.answers[qid] = Answer(
                qid=qid, kind=int(cols["kind"][i]), ok=bool(cols["ok"][i]),
                vec=np.asarray(cols["vec"][i]),
                score=float(cols["score"][i]),
                issue_tick=int(cols["issue"][i]),
                answer_tick=int(cols["tick"][i]),
                # adopted answers (restored pending queries another session
                # issued) have no enqueue time — excluded from percentiles
                latency_s=(t_now - meta.enqueued_at) if meta else None)
        # retention bound: evict the oldest harvested answers (dict
        # preserves insertion order) so an always-on loop stays bounded
        overflow = len(self.answers) - self.max_retained
        if overflow > 0:
            for qid in list(self.answers)[:overflow]:
                del self.answers[qid]

    @property
    def outstanding(self) -> int:
        """Submitted but not yet answered (queued + held on device)."""
        return len(self._meta) + len(self._queue)

    def latency_stats(self) -> dict:
        """p50/p95/p99 end-to-end latency (ms) + staleness + counts.

        Latency AND staleness percentiles are computed over the SAME
        population: answers this session issued itself (latency_s set).
        Adopted answers (queries restored from another session's
        checkpoint, latency_s=None) have no enqueue time here, so mixing
        them into only one of the two distributions would silently skew
        the comparison — they are excluded from both and reported in the
        separate `adopted` count."""
        timed = [a for a in self.answers.values()
                 if a.latency_s is not None]
        if not timed:
            return {"answered": len(self.answers),
                    "adopted": len(self.answers),
                    "outstanding": self.outstanding}
        lats = np.asarray([a.latency_s for a in timed])
        stale = np.asarray([a.staleness_ticks for a in timed])
        out = {
            "answered": len(self.answers),
            "adopted": len(self.answers) - len(timed),
            "outstanding": self.outstanding,
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p95_ms": float(np.percentile(lats, 95) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "staleness_ticks_p50": float(np.percentile(stale, 50)),
            "staleness_ticks_max": int(stale.max()),
        }
        # telemetry plane: stamp the serving percentiles into the trace
        # meta so a saved trace carries them next to the occupancy rows
        if getattr(self.pipe, "trace", None) is not None:
            self.pipe.trace.annotate(
                serving_p50_ms=out["p50_ms"], serving_p95_ms=out["p95_ms"],
                serving_p99_ms=out["p99_ms"],
                serving_answered=out["answered"])
        return out
