"""Host-side serving loop: interleave graph updates and point queries.

`ServeSession` wraps a query-enabled `D3Pipeline` (cfg.query_cap > 0) and
drives EITHER pipeline driver with queries aboard:

  * driver="tick"  — per-tick reference path: queued submissions admit in
    the very next micro-tick (`advance(edges, feats)`);
  * driver="super" — the donated super-tick `lax.scan`: `advance_super`
    stages T update micro-ticks and spreads the queued submissions over
    them, so queries admit while updates are still flowing through the
    same device launch. Answers come back in the launch's single host
    sync.

The session keeps the host-side truth the device never sees: wall-clock
enqueue times per qid. Every harvested answer gets an end-to-end
enqueue->answer latency (submission to host-visible result, INCLUDING the
super-tick batching delay — that is the serving latency a client would
observe) plus tick-domain staleness (answer_tick - issue_tick).
`latency_stats()` reports p50/p95/p99 histogram summaries; when the
pipeline runs with the telemetry plane on (cfg.telemetry, ISSUE 9)
they are also annotated into the trace recorder's metadata
(`serving_p50_ms`/`p95`/`p99`) so a recorded trace carries the serving
latency alongside the per-tick occupancy rows.

Degraded-mode serving (ISSUE 10): under overload or mid-recovery the
session sheds instead of stalling —

  * `degrade(reason)` declares degraded mode (e.g. around a
    `pipe.reshard`): `stale_ok` submissions keep flowing while
    `consistent` submissions are HELD in the host queue until
    `restore_normal()` (consistent queries already admitted ride the
    device QueryState across the reshard and answer normally);
  * `shed_threshold` bounds `outstanding`: submissions beyond it get an
    immediate ok=False shed answer instead of unbounded queue growth;
  * `max_retries > 0` gives retriable ok=False answers (admission
    overflow, endpoint not yet materialized) an in-session bounded
    retry: same qid resubmitted after an exponential tick backoff
    (`retry_backoff_ticks * 2**attempt`), capped at `max_retries`
    attempts, retry state capped by the existing `max_retained` bound.

All of it is observable, never silent: `latency_stats()` carries
retried/shed/retry_exhausted/degraded_ticks counters and the declared
degraded reason.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.serve.query import KIND_EMBED, KIND_LINK


@dataclass
class Answer:
    """One resolved point query (host view)."""
    qid: int
    kind: int                 # KIND_EMBED | KIND_LINK
    ok: bool                  # False: endpoint never materialized, the
                              # vertex was unknown, or the pending table
                              # overflowed (re-submit in that case)
    vec: np.ndarray           # embedding (KIND_EMBED; zeros otherwise)
    score: float              # link score (KIND_LINK; 0.0 otherwise)
    issue_tick: int
    answer_tick: int
    latency_s: float          # wall-clock enqueue -> host-visible answer;
                              # None for adopted answers (queries restored
                              # from a checkpoint another session issued)

    @property
    def staleness_ticks(self) -> int:
        return self.answer_tick - self.issue_tick


@dataclass
class _PendingMeta:
    enqueued_at: float
    kind: int
    row: tuple = None         # (kind, u, v, consistent) — the original
                              # submission, kept so a failed answer can
                              # be resubmitted under the same qid
    attempts: int = 0         # bounded-retry attempts consumed so far


@dataclass
class ServeSession:
    pipe: object                                   # a query-enabled D3Pipeline
    driver: str = "super"                          # "super" | "tick"
    super_ticks: int = 8                           # T per device launch
    qid_base: int = 0                              # first qid this session
                                                   # assigns — hand over the
                                                   # previous session's
                                                   # _next_qid when restoring
                                                   # a checkpoint that holds
                                                   # its pending queries
    max_retained: int = 65536                      # retention bound on
                                                   # `answers`: a long-lived
                                                   # serving loop would grow
                                                   # the dict per answer
                                                   # forever; beyond the
                                                   # bound the OLDEST
                                                   # harvested answers are
                                                   # evicted (dict insertion
                                                   # order). Read results
                                                   # promptly or raise it.
    max_retries: int = 0                           # bounded in-session retry
                                                   # of ok=False answers
                                                   # (0 = off)
    retry_backoff_ticks: int = 2                   # exponential backoff base:
                                                   # attempt k waits
                                                   # base * 2**(k-1) ticks
    shed_threshold: int | None = None              # outstanding bound: beyond
                                                   # it new submissions shed
                                                   # (immediate ok=False)
    answers: dict = field(default_factory=dict)    # qid -> Answer
    counters: dict = field(default_factory=lambda: {
        "retried": 0, "shed": 0, "retry_exhausted": 0,
        "degraded_ticks": 0})
    _queue: list = field(default_factory=list)     # un-admitted submissions
    _meta: dict = field(default_factory=dict)      # qid -> _PendingMeta
    _retry_queue: list = field(default_factory=list)  # (due_tick, qid)
    _degraded: str | None = None                   # declared reason or None
    _next_qid: int = 0

    def __post_init__(self):
        if self.pipe.cfg.query_cap <= 0:
            raise ValueError(
                "ServeSession needs a query-enabled pipeline: set "
                "PipelineConfig.query_cap > 0 (the query plane is "
                "compiled away at query_cap=0)")
        if self.driver not in ("super", "tick"):
            raise ValueError(f"driver={self.driver!r}: 'super' or 'tick'")
        if self.max_retained <= 0:
            raise ValueError(
                f"max_retained={self.max_retained} must be > 0 (it bounds "
                "the retained-answer dict, not whether answers arrive)")
        self._next_qid = max(self._next_qid, int(self.qid_base))

    # --------------------------------------------------------- degradation
    @property
    def degraded(self) -> str | None:
        """The declared degraded-mode reason, or None when normal."""
        return self._degraded

    def degrade(self, reason: str = "recovery") -> None:
        """Declare degraded mode (overload / mid-recovery): `stale_ok`
        submissions keep admitting, `consistent` submissions are held in
        the host queue until `restore_normal()`. Queries already admitted
        are untouched — held consistent queries ride the device state
        (incl. across a `pipe.reshard`) and answer normally."""
        self._degraded = str(reason)

    def restore_normal(self) -> None:
        self._degraded = None

    def _shed(self, qid: int, kind: int) -> None:
        self.counters["shed"] += 1
        self.answers[qid] = Answer(
            qid=qid, kind=kind, ok=False,
            vec=np.zeros(getattr(self.pipe, "d_out", 0), np.float32),
            score=0.0, issue_tick=-1, answer_tick=-1, latency_s=None)

    def _release_due_retries(self) -> None:
        """Move retries whose backoff expired to the queue front (same
        qid, original enqueue time — end-to-end latency stays honest)."""
        if not self._retry_queue:
            return
        now = self.pipe.now
        due = sorted(x for x in self._retry_queue if x[0] <= now)
        self._retry_queue = [x for x in self._retry_queue if x[0] > now]
        released = [(qid,) + self._meta[qid].row for _, qid in due
                    if qid in self._meta]
        self._queue = released + self._queue

    def _take(self, n: int) -> list:
        """Dequeue up to n submissions for admission; degraded mode holds
        `consistent` submissions back (row = (qid, kind, u, v, cons))."""
        if self._degraded is None:
            q, self._queue = self._queue[:n], self._queue[n:]
            return q
        take, keep = [], []
        for row in self._queue:
            if len(take) < n and not row[4]:
                take.append(row)
            else:
                keep.append(row)
        self._queue = keep
        return take

    # ------------------------------------------------------------- submit
    def _submit(self, rows) -> list:
        now = time.perf_counter()
        qids = []
        for row in rows:
            qid = self._next_qid
            self._next_qid += 1
            qids.append(qid)
            if (self.shed_threshold is not None
                    and self.outstanding >= self.shed_threshold):
                self._shed(qid, row[0])
                continue
            self._queue.append((qid,) + row)
            self._meta[qid] = _PendingMeta(enqueued_at=now, kind=row[0],
                                           row=tuple(row))
        return qids

    def submit_embed(self, vids, consistent: bool = False) -> list:
        """Enqueue embedding reads; returns the assigned qids."""
        return self._submit([(KIND_EMBED, int(v), 0, consistent)
                             for v in np.asarray(vids).reshape(-1)])

    def submit_link(self, pairs, consistent: bool = False) -> list:
        """Enqueue link-score queries for (u, v) pairs; returns qids."""
        return self._submit([(KIND_LINK, int(u), int(v), consistent)
                             for u, v in pairs])

    # ------------------------------------------------------------ advance
    def advance(self, edges=None, feats=None, window=None):
        """One micro-tick (driver='tick'): queued submissions admit now,
        up to the per-tick admission budget (the rest stay queued)."""
        cap = self.pipe.cfg.capacities().query_admissions
        self._release_due_retries()
        q = self._take(cap)
        if self._degraded is not None:
            self.counters["degraded_ticks"] += 1
        stats = self.pipe.tick(edges, feats, window=window,
                               queries=q or None)
        self._harvest()
        return stats

    def advance_super(self, edge_chunks=None, feat_chunks=None,
                      T=None, window=None, quiet0: int = 0):
        """One super-tick (driver='super'): queued submissions spread
        over the launch's T micro-ticks (earliest first, at most
        `capacities().query_admissions` per tick), so admission
        interleaves with
        the update stream on device. Submissions beyond the launch's
        admission budget stay queued for the next advance — they never
        overflow a tick's fixed-capacity query batch."""
        edge_chunks = list(edge_chunks) if edge_chunks is not None else []
        feat_chunks = list(feat_chunks) if feat_chunks is not None else []
        n = max(len(edge_chunks), len(feat_chunks), 1)
        T = int(T) if T is not None else n
        per_tick = self.pipe.cfg.capacities().query_admissions
        self._release_due_retries()
        q = self._take(per_tick * T)
        if self._degraded is not None:
            self.counters["degraded_ticks"] += T
        q_chunks = [q[i * per_tick: (i + 1) * per_tick] for i in range(T)]
        out = self.pipe.run_super_tick(edge_chunks, feat_chunks, T=T,
                                       window=window, quiet0=quiet0,
                                       query_chunks=q_chunks)
        self._harvest()
        return out

    def step(self, edges=None, feats=None, **kw):
        """Driver-agnostic advance: one tick or one super-tick."""
        if self.driver == "tick":
            return self.advance(edges, feats, **kw)
        e = [edges] if edges is not None else None
        f = [feats] if feats is not None else None
        return self.advance_super(e, f, T=self.super_ticks, **kw)

    def flush(self, max_ticks: int = 128):
        """Drain the pipeline (and any held consistent queries answer at
        the first silent tick)."""
        if self.driver == "tick":
            ran = self.pipe.flush(max_ticks=max_ticks)
        else:
            ran = self.pipe.flush_super(max_ticks=max_ticks,
                                        T=self.super_ticks)
        self._harvest()
        return ran

    # ------------------------------------------------------------ results
    def _harvest(self):
        cols = self.pipe.drain_answers()
        t_now = time.perf_counter()
        for i in range(len(cols["qid"])):
            qid = int(cols["qid"][i])
            ok = bool(cols["ok"][i])
            meta = self._meta.get(qid)
            if (not ok and self.max_retries > 0 and meta is not None
                    and meta.row is not None
                    and meta.attempts < self.max_retries):
                # bounded in-session retry: resubmit the same qid after
                # an exponential tick backoff instead of surfacing the
                # retriable failure (admission overflow / endpoint not
                # yet materialized) to the client
                meta.attempts += 1
                due = int(self.pipe.now) + self.retry_backoff_ticks * (
                    2 ** (meta.attempts - 1))
                self._retry_queue.append((due, qid))
                self.counters["retried"] += 1
                # retry state rides the max_retained bound too — beyond
                # it the OLDEST retry gives up with a final failed answer
                while len(self._retry_queue) > self.max_retained:
                    _, old = self._retry_queue.pop(0)
                    m = self._meta.pop(old, None)
                    self.counters["retry_exhausted"] += 1
                    self.answers[old] = Answer(
                        qid=old, kind=m.kind if m else 0, ok=False,
                        vec=np.zeros(getattr(self.pipe, "d_out", 0),
                                     np.float32),
                        score=0.0, issue_tick=-1, answer_tick=-1,
                        latency_s=None)
                continue
            self._meta.pop(qid, None)
            if not ok and meta is not None and meta.attempts > 0:
                self.counters["retry_exhausted"] += 1
            self.answers[qid] = Answer(
                qid=qid, kind=int(cols["kind"][i]), ok=ok,
                vec=np.asarray(cols["vec"][i]),
                score=float(cols["score"][i]),
                issue_tick=int(cols["issue"][i]),
                answer_tick=int(cols["tick"][i]),
                # adopted answers (restored pending queries another session
                # issued) have no enqueue time — excluded from percentiles
                latency_s=(t_now - meta.enqueued_at) if meta else None)
        # retention bound: evict the oldest harvested answers (dict
        # preserves insertion order) so an always-on loop stays bounded
        overflow = len(self.answers) - self.max_retained
        if overflow > 0:
            for qid in list(self.answers)[:overflow]:
                del self.answers[qid]

    @property
    def outstanding(self) -> int:
        """Submitted but not yet answered (queued + held on device)."""
        return len(self._meta) + len(self._queue)

    def latency_stats(self) -> dict:
        """p50/p95/p99 end-to-end latency (ms) + staleness + counts.

        Latency AND staleness percentiles are computed over the SAME
        population: answers this session issued itself (latency_s set).
        Adopted answers (queries restored from another session's
        checkpoint, latency_s=None) have no enqueue time here, so mixing
        them into only one of the two distributions would silently skew
        the comparison — they are excluded from both and reported in the
        separate `adopted` count."""
        timed = [a for a in self.answers.values()
                 if a.latency_s is not None]
        degr = {"degraded": self._degraded, **self.counters}
        if not timed:
            return {"answered": len(self.answers),
                    "adopted": len(self.answers),
                    "outstanding": self.outstanding, **degr}
        lats = np.asarray([a.latency_s for a in timed])
        stale = np.asarray([a.staleness_ticks for a in timed])
        out = {
            "answered": len(self.answers),
            "adopted": len(self.answers) - len(timed),
            "outstanding": self.outstanding,
            **degr,
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p95_ms": float(np.percentile(lats, 95) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "staleness_ticks_p50": float(np.percentile(stale, 50)),
            "staleness_ticks_max": int(stale.max()),
        }
        # telemetry plane: stamp the serving percentiles into the trace
        # meta so a saved trace carries them next to the occupancy rows
        if getattr(self.pipe, "trace", None) is not None:
            self.pipe.trace.annotate(
                serving_p50_ms=out["p50_ms"], serving_p95_ms=out["p95_ms"],
                serving_p99_ms=out["p99_ms"],
                serving_answered=out["answered"])
        return out
