"""Host-side online-training loop: interleave graph updates and labels.

`TrainSession` is the training-plane twin of `ServeSession`: it wraps a
training-enabled `D3Pipeline` (cfg.train_cap > 0 + a `TrainConfig`) and
drives EITHER pipeline driver with label admissions aboard:

  * driver="tick"  — per-tick reference path: queued labels admit in the
    very next micro-tick (`advance(edges, feats)`);
  * driver="super" — the donated super-tick `lax.scan`: `advance_super`
    stages T update micro-ticks and spreads the queued labels over them,
    so the windowed online training step (fire-masked backprop +
    Algorithm 3) runs inside the same device launch as the update
    stream — still ONE host sync per super-tick.

Labels queue host-side until a tick has budget (`capacities().train_cap`
per tick); vids the partitioner has never seen are silently dropped at
admission (there is no master slot to label). Training progress — loss,
gradient norm, fired steps — is read on demand via `train_stats()`,
which adds the host-side label backlog. Unlike the halt-flush
`TrainingCoordinator` (core/training.py), nothing here stops the stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TrainSession:
    pipe: object                                 # a training-enabled D3Pipeline
    driver: str = "super"                        # "super" | "tick"
    super_ticks: int = 8                         # T per device launch
    _queue: list = field(default_factory=list)   # un-admitted (vid, gold)

    def __post_init__(self):
        if getattr(self.pipe, "train_cfg", None) is None:
            raise ValueError(
                "TrainSession needs a training-enabled pipeline: set "
                "PipelineConfig.train_cap > 0 and pass "
                "D3Pipeline(..., train=TrainConfig(...)) (the training "
                "plane is compiled away at train_cap=0)")
        if self.driver not in ("super", "tick"):
            raise ValueError(f"driver={self.driver!r}: 'super' or 'tick'")

    # ------------------------------------------------------------- labels
    def observe_labels(self, labels):
        """Enqueue ground-truth labels: {vid: gold_class} or
        [(vid, gold_class), ...]. They admit into the device-side sliding
        window on the next advance, oldest first."""
        pairs = labels.items() if isinstance(labels, dict) else labels
        for vid, y in pairs:
            self._queue.append((int(vid), int(y)))

    # ------------------------------------------------------------ advance
    def advance(self, edges=None, feats=None, window=None):
        """One micro-tick (driver='tick'): queued labels admit now, up to
        the per-tick label budget (the rest stay queued)."""
        cap = self.pipe.cfg.capacities().train_cap
        l, self._queue = self._queue[:cap], self._queue[cap:]
        return self.pipe.tick(edges, feats, window=window,
                              labels=l or None)

    def advance_super(self, edge_chunks=None, feat_chunks=None,
                      T=None, window=None, quiet0: int = 0):
        """One super-tick (driver='super'): queued labels spread over the
        launch's T micro-ticks (earliest first, at most
        `capacities().train_cap` per tick), interleaving label ingest
        with the update stream on device. Labels beyond the launch's
        admission budget stay queued — they never overflow a tick's
        fixed-capacity label batch."""
        edge_chunks = list(edge_chunks) if edge_chunks is not None else []
        feat_chunks = list(feat_chunks) if feat_chunks is not None else []
        n = max(len(edge_chunks), len(feat_chunks), 1)
        T = int(T) if T is not None else n
        per_tick = self.pipe.cfg.capacities().train_cap
        l, self._queue = self._queue[:per_tick * T], self._queue[per_tick * T:]
        l_chunks = [l[i * per_tick: (i + 1) * per_tick] for i in range(T)]
        return self.pipe.run_super_tick(edge_chunks, feat_chunks, T=T,
                                        window=window, quiet0=quiet0,
                                        label_chunks=l_chunks)

    def step(self, edges=None, feats=None, **kw):
        """Driver-agnostic advance: one tick or one super-tick."""
        if self.driver == "tick":
            return self.advance(edges, feats, **kw)
        e = [edges] if edges is not None else None
        f = [feats] if feats is not None else None
        return self.advance_super(e, f, T=self.super_ticks, **kw)

    def flush(self, max_ticks: int = 128):
        """Drain the pipeline: the label backlog admits first (labels
        only enter with tick budget), then the normal flush runs until
        device quiescence — so the final fire at the quiescent fixed
        point sees every label submitted so far."""
        ran = 0
        while self._queue and ran < max_ticks:
            if self.driver == "tick":
                self.advance()
                ran += 1
            else:
                self.advance_super(T=self.super_ticks)
                ran += self.super_ticks
        remaining = max(max_ticks - ran, 8)
        if self.driver == "tick":
            return ran + self.pipe.flush(max_ticks=remaining)
        return ran + self.pipe.flush_super(max_ticks=remaining,
                                           T=self.super_ticks)

    # ------------------------------------------------------------ results
    @property
    def backlog(self) -> int:
        """Labels submitted but not yet admitted on device."""
        return len(self._queue)

    def train_stats(self) -> dict:
        """Device training diagnostics (one host sync) + label backlog."""
        out = dict(self.pipe.train_stats())
        out["backlog"] = self.backlog
        return out
