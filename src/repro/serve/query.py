"""The QUERY plane (ISSUE 4 tentpole): on-device point queries over the
live sharded state — the paper's "online query setting".

The streaming tick is four planes: COMPUTE (core/tick.py) emits
part-addressed records, ROUTING (dist/router.py) moves them to the owning
device, DELIVERY (core/delivery.py) lands them in state — and QUERY
(here) answers point reads from the state the other three maintain,
without ever materializing the sink to host.

Event records follow the core/events.py MsgBatch conventions: fixed
capacity, mask-padded struct-of-arrays, pre-addressed by the host to
master (part, slot) coordinates so the device never hashes a vertex id.

  QueryBatch  : admissions (host-built, replicated-injected like the
                FeatBatch inbox; each part filters its own rows) AND the
                wire format of the link-score forwarding hop, which rides
                the Router FUSED into layer 0's round-B exchange (ISSUE 5
                lane fusion: one all_to_all launch carries the RMI lane
                and the query wire).
  QueryState  : the per-part pending-query table inside PipelineCarry —
                fixed [P, Q] slots, so held `consistent` queries survive
                super-ticks, donation, sharding and checkpoints.
  AnswerBatch : one row per pending slot per tick; `valid` marks the
                queries answered this tick. The super-tick scan stacks
                these as its ys, so answers ride the existing single
                host sync per super-tick.

Query kinds:

  KIND_EMBED : read the sink embedding of one vertex.
  KIND_LINK  : score an edge (u, v) = <h_u, h_v>, computed ON DEVICE in
               two hops: the query lands at u's master part, gathers h_u
               when ready, and forwards a wire record (vec = h_u) to v's
               master part, where the dot product fires. Both hops can
               complete within one tick when both endpoints are ready.

Freshness modes (per query, the `consistent` flag):

  stale_ok   : answer in the admission tick from the current sink — the
               bounded-staleness read of InkStream/Ripple; bit-equal to
               a host `read_nodes` of the same tick by construction.
  consistent : hold while the target still has dirty/pending window
               state (red_pending | fwd_pending at any layer) OR the
               tick was not globally silent (a message moved, or ANY
               vertex anywhere still holds pending window state whose
               eviction could reach the target) — i.e. answer only at a
               quiescent tick, when every ingested update has fully
               propagated. A consequence: at such a tick every flag is
               clear, so a consistent link's head and tail hops fire in
               the SAME tick — the score is a consistent snapshot.
               The answer tick is recorded for staleness accounting;
               after a drain flush the answers equal the static oracle.

Admission overflow (a full pending table) is never silent: the dropped
records come back as ok=False answer rows in the same tick, so the
client keeps a retriable qid, and QueryStats counts them.

Tick placement (ISSUE 5): the plane runs as TWO stages. Admissions and
the link HEAD hop run at the START of the tick (`query_admit_stage`) so
the wire can share layer 0's round-B all_to_all; the head's h_u read is
therefore the start-of-tick sink (one tick of bounded staleness on the
head endpoint for stale_ok links — the tail endpoint and every EMBED
read stay end-of-tick fresh). `consistent` heads only fire at a
START-silent tick (no pending window state, no deferred wire rows, an
empty update batch), at which nothing can move during the tick, so the
head value equals the end-of-tick value and the two hops of a
consistent link still answer within ONE tick with a consistent
snapshot. Answers (`query_answer_stage`) run after the sink update,
exactly as before. Host qids must stay below 2**24: the packed wire
value-casts ints to f32 (dist/wire.py).

Under wire-lane backpressure (`route_cap` smaller than the tick's wire
traffic) tail records can arrive a tick late; a consistent link then
scores the snapshot of its (quiet) head tick rather than its answer
tick — after a drain flush the two coincide.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.termination import pending_work

# query kinds (host submits EMBED/LINK; LINK_TAIL is the device-internal
# second hop of a link-score query, never admitted from host)
KIND_EMBED = 0
KIND_LINK = 1
KIND_LINK_TAIL = 2


@dataclass(frozen=True)
class QueryBatch:
    """Fixed-capacity query records — admissions and the link-tail wire.

    `part`/`slot` address the record's target master; `part2`/`slot2`
    carry the second endpoint of a KIND_LINK query (the tail hop's
    destination). `vec` is zero on admission and carries h_u on the
    KIND_LINK_TAIL wire. `ok` accumulates the seen-flags of gathered
    endpoints (host sets True; the tail hop ANDs in sink_seen[u]).
    """
    qid: jnp.ndarray          # [C] int32 host-assigned query id
    kind: jnp.ndarray         # [C] int32 KIND_*
    part: jnp.ndarray         # [C] int32 target master part (routing key)
    slot: jnp.ndarray         # [C] int32 target master slot
    part2: jnp.ndarray        # [C] int32 second endpoint master part (LINK)
    slot2: jnp.ndarray        # [C] int32
    consistent: jnp.ndarray   # [C] bool  freshness mode
    ok: jnp.ndarray           # [C] bool  seen-flag accumulator
    issue: jnp.ndarray        # [C] int32 issue tick (host-stamped)
    vec: jnp.ndarray          # [C, d] float payload (tail hop: h_u)
    valid: jnp.ndarray        # [C] bool

    @property
    def capacity(self):
        return self.part.shape[0]


@dataclass(frozen=True)
class QueryState:
    """Per-part pending-query table (the query plane's operator state).

    All arrays are [P, Q] (vec: [P, Q, d]) — part-leading like every
    other carry table, so the same block-sharding, donation and
    checkpoint rules apply. `pending` marks occupied slots; answered or
    forwarded slots free immediately for reuse.
    """
    qid: jnp.ndarray          # [P, Q] int32
    kind: jnp.ndarray         # [P, Q] int32
    slot: jnp.ndarray         # [P, Q] int32 local target slot in this part
    part2: jnp.ndarray        # [P, Q] int32
    slot2: jnp.ndarray        # [P, Q] int32
    consistent: jnp.ndarray   # [P, Q] bool
    ok: jnp.ndarray           # [P, Q] bool
    issue: jnp.ndarray        # [P, Q] int32
    vec: jnp.ndarray          # [P, Q, d] float (h_u for tail-hop rows)
    pending: jnp.ndarray      # [P, Q] bool
    # wire-lane backpressure ring (ISSUE 5): packed QueryBatch rows that
    # overflowed the capped fused exchange, re-entering next tick
    # (dist/wire.py format; [D * K, W] global, block-sharded; K = 0 under
    # the dense default / LocalRouter)
    wire_defer: jnp.ndarray   # [DK, W] f32
    wire_defer_ok: jnp.ndarray  # [DK] bool

    @property
    def query_cap(self):
        return self.qid.shape[1]


@dataclass(frozen=True)
class AnswerBatch:
    """One tick's answers — one row per pending slot, `valid` = answered.

    `vec` holds the embedding for KIND_EMBED rows, `score` the link score
    for KIND_LINK rows (the kind field reports the HOST-facing kind: tail
    hops answer as KIND_LINK). `ok` is False when any gathered endpoint
    had never materialized in the sink.
    """
    qid: jnp.ndarray          # [A] int32
    kind: jnp.ndarray         # [A] int32 (KIND_EMBED | KIND_LINK)
    ok: jnp.ndarray           # [A] bool
    tick: jnp.ndarray         # [A] int32 answer tick
    issue: jnp.ndarray        # [A] int32 issue tick (staleness = tick-issue)
    vec: jnp.ndarray          # [A, d] float
    score: jnp.ndarray        # [A] float
    valid: jnp.ndarray        # [A] bool


@dataclass(frozen=True)
class QueryStats:
    """Per-tick query-plane telemetry (scalars, globally psum'd)."""
    admitted: jnp.ndarray     # queries that found a pending slot
    answered: jnp.ndarray     # answers emitted this tick
    dropped: jnp.ndarray      # admissions lost to a full pending table
    held_ticks: jnp.ndarray   # pending-query-ticks (backlog integral)
    wire_backlog: jnp.ndarray  # wire rows still deferred after this tick
                               # (a gauge: the host flush loop must keep
                               # ticking while it is non-zero)


for _cls, _fields in (
    (QueryBatch, ["qid", "kind", "part", "slot", "part2", "slot2",
                  "consistent", "ok", "issue", "vec", "valid"]),
    (QueryState, ["qid", "kind", "slot", "part2", "slot2", "consistent",
                  "ok", "issue", "vec", "pending", "wire_defer",
                  "wire_defer_ok"]),
    (AnswerBatch, ["qid", "kind", "ok", "tick", "issue", "vec", "score",
                   "valid"]),
    (QueryStats, ["admitted", "answered", "dropped", "held_ticks",
                  "wire_backlog"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])


def wire_width(d: int) -> int:
    """Packed row width of the QueryBatch wire lane (dist/wire.py)."""
    from repro.dist.wire import lane_width
    return lane_width(empty_query_batch(1, d))


def init_query_state(n_parts: int, query_cap: int, d: int,
                     wire_defer_rows: int = 0) -> QueryState:
    """wire_defer_rows: GLOBAL (n_devices * per-device) rows of the wire
    lane's backpressure ring — 0 (dense default / off-mesh) compiles the
    deferral path away."""
    zi = lambda: jnp.zeros((n_parts, query_cap), jnp.int32)
    zb = lambda: jnp.zeros((n_parts, query_cap), bool)
    return QueryState(qid=zi(), kind=zi(), slot=zi(), part2=zi(),
                      slot2=zi(), consistent=zb(), ok=zb(), issue=zi(),
                      vec=jnp.zeros((n_parts, query_cap, d), jnp.float32),
                      pending=zb(),
                      wire_defer=jnp.zeros((wire_defer_rows, wire_width(d)),
                                           jnp.float32),
                      wire_defer_ok=jnp.zeros((wire_defer_rows,), bool))


def zero_query_stats() -> QueryStats:
    z = jnp.zeros((), jnp.int32)
    return QueryStats(admitted=z, answered=z, dropped=z, held_ticks=z,
                      wire_backlog=z)


def add_query_stats(a: QueryStats, b: QueryStats) -> QueryStats:
    return jax.tree.map(jnp.add, a, b)


def empty_query_batch(cap: int, d: int, device: bool = True) -> QueryBatch:
    conv = jnp.asarray if device else (lambda a: a)
    zi = conv(np.zeros((cap,), np.int32))
    zb = conv(np.zeros((cap,), bool))
    return QueryBatch(qid=zi, kind=zi, part=zi, slot=zi, part2=zi,
                      slot2=zi, consistent=zb, ok=zb, issue=zi,
                      vec=conv(np.zeros((cap, d), np.float32)), valid=zb)


def query_batch_from_numpy(rows: dict, cap: int, d: int,
                           device: bool = True) -> QueryBatch:
    """rows: {qid, kind, part, slot, part2, slot2, consistent, issue}
    numpy columns (vec is always zero on admission; ok starts True)."""
    n = len(rows["qid"])
    assert n <= cap, f"query batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)

    def pad(a, dtype=np.int32):
        out = np.zeros((cap,), dtype)
        out[:n] = a
        return conv(out)

    valid = np.zeros((cap,), bool)
    valid[:n] = True
    ok = np.zeros((cap,), bool)
    ok[:n] = True
    return QueryBatch(qid=pad(rows["qid"]), kind=pad(rows["kind"]),
                      part=pad(rows["part"]), slot=pad(rows["slot"]),
                      part2=pad(rows["part2"]), slot2=pad(rows["slot2"]),
                      consistent=pad(rows["consistent"], bool),
                      ok=conv(ok), issue=pad(rows["issue"]),
                      vec=conv(np.zeros((cap, d), np.float32)),
                      valid=conv(valid))


# ===================================================== device-side stages

def admit(qs: QueryState, qb: QueryBatch, part0):
    """Land incoming query records in free pending-table slots.

    Each part ranks its valid arrivals (cumsum over a one-hot membership)
    and assigns them its free slots in ascending order — deterministic
    regardless of router, driver or delivery backend, because the rank
    only depends on record order and LocalRouter/MeshRouter both present
    records in global (source part, slot) order. Arrivals beyond the free
    capacity are DROPPED — the caller turns the returned drop mask into
    ok=False answer rows so the client learns WHICH qids to re-submit.

    Returns (new state, n_admitted, dropped mask [C]).
    """
    P_loc, Q = qs.qid.shape
    lp = qb.part - part0
    ok = qb.valid & (lp >= 0) & (lp < P_loc)
    member = (jnp.where(ok, lp, P_loc)[:, None]
              == jnp.arange(P_loc)[None, :])                     # [C, P]
    rank = jnp.cumsum(member.astype(jnp.int32), axis=0) - 1
    r = jnp.sum(jnp.where(member, rank, 0), axis=1)              # [C]
    # free slot ids per part, ascending (occupied slots sort to the tail)
    free = jnp.sort(jnp.where(qs.pending, Q,
                              jnp.arange(Q)[None, :]), axis=1)   # [P, Q]
    dest = free[jnp.minimum(jnp.maximum(lp, 0), P_loc - 1),
                jnp.minimum(r, Q - 1)]
    admitted = ok & (r < Q) & (dest < Q)
    flat = jnp.where(admitted, lp * Q + dest, P_loc * Q)

    def scat(tbl, val):
        return tbl.reshape(P_loc * Q).at[flat].set(
            val, mode="drop").reshape(P_loc, Q)

    d = qs.vec.shape[-1]
    new = QueryState(
        qid=scat(qs.qid, qb.qid), kind=scat(qs.kind, qb.kind),
        slot=scat(qs.slot, qb.slot), part2=scat(qs.part2, qb.part2),
        slot2=scat(qs.slot2, qb.slot2),
        consistent=scat(qs.consistent, qb.consistent),
        ok=scat(qs.ok, qb.ok), issue=scat(qs.issue, qb.issue),
        vec=qs.vec.reshape(P_loc * Q, d).at[flat].set(
            qb.vec, mode="drop").reshape(P_loc, Q, d),
        pending=scat(qs.pending, admitted),
        wire_defer=qs.wire_defer, wire_defer_ok=qs.wire_defer_ok)
    return new, jnp.sum(admitted), ok & ~admitted


def _drop_answers(qb: QueryBatch, dropped, now, d: int) -> AnswerBatch:
    """Admission-overflow records as ok=False answer rows: the client
    keeps a retriable qid instead of a leaked, forever-outstanding one."""
    C = qb.valid.shape[0]
    return AnswerBatch(
        qid=qb.qid,
        kind=jnp.where(qb.kind == KIND_LINK_TAIL, KIND_LINK, qb.kind),
        ok=jnp.zeros((C,), bool), tick=jnp.full((C,), now, jnp.int32),
        issue=qb.issue, vec=jnp.zeros((C, d), jnp.float32),
        score=jnp.zeros((C,), jnp.float32), valid=dropped)


def _target(qs: QueryState, N: int):
    P_loc, Q = qs.qid.shape
    return (jnp.arange(P_loc)[:, None] * N
            + jnp.clip(qs.slot, 0, N - 1)).reshape(-1)         # [P*Q]


def _empty_answers(d: int) -> AnswerBatch:
    return AnswerBatch(
        qid=jnp.zeros((0,), jnp.int32), kind=jnp.zeros((0,), jnp.int32),
        ok=jnp.zeros((0,), bool), tick=jnp.zeros((0,), jnp.int32),
        issue=jnp.zeros((0,), jnp.int32),
        vec=jnp.zeros((0, d), jnp.float32),
        score=jnp.zeros((0,), jnp.float32), valid=jnp.zeros((0,), bool))


def _plane_work(qs: QueryState, layer_states, router=None, extra_work=None):
    """The shared inputs of BOTH silence gates (start and end of tick):
    per-row clean flags (no red/fwd pending at any layer for that target
    row) and the local pending-work count — the SAME
    `termination.pending_work` aggregation the quiescence gates use, so
    the consistent-snapshot guarantee and flush termination can never
    disagree about what counts as in-flight.

    On a hybrid 2-D mesh each stage holds only ITS layers' states, so
    the per-row dirty flags are OR'd across the stage axis (a row is
    dirty if ANY layer anywhere still has it pending) and the caller's
    `extra_work` carries the inter-stage ring occupancy."""
    P_loc, N = layer_states[0].red_pending.shape
    dirty = jnp.zeros((P_loc, N), bool)
    for ls in layer_states:
        dirty = dirty | ls.red_pending | ls.fwd_pending
    if router is not None and getattr(router, "n_stages", 1) > 1:
        dirty = router.psum_stage(dirty.astype(jnp.int32)) > 0
    return (~dirty.reshape(P_loc * N),
            pending_work(layer_states, qs, extra_work))


def query_admit_stage(qs: QueryState, qb: QueryBatch, layer_states, sink,
                      sink_seen, router, batch_work, extra_work=None):
    """START-of-tick half of the query plane (before the layer ticks).

    1. admit the host's new queries (replicated batch, local filter);
    2. link-score head hop: ready KIND_LINK rows gather h_u from the
       START-of-tick sink and emit a KIND_LINK_TAIL wire record to the
       second endpoint's master part. The returned wire batch rides
       layer 0's round-B exchange (ONE fused all_to_all — ISSUE 5), and
       the delivered records reach `query_answer_stage` the same tick.

    Readiness of consistent heads uses START-silence: no pending window
    state or deferred route/wire rows anywhere (psum'd vote) and an
    empty update batch (`batch_work`) — under which NOTHING can move
    during this tick, so the head's h_u equals its end-of-tick value and
    the link scores a consistent snapshot.

    Returns (new state, wire QueryBatch [P_loc*Q], admission-drop mask,
    n_admitted). Q == 0 short-circuits statically (no wire lane).
    """
    P_loc, Q = qs.qid.shape
    if Q == 0:
        return qs, None, None, jnp.zeros((), jnp.int32)
    part0 = router.part0()
    d = qs.vec.shape[-1]
    N = sink.shape[1]
    sink_flat = sink.reshape(P_loc * N, d)
    seen_flat = sink_seen.reshape(P_loc * N)
    clean_flat, work = _plane_work(qs, layer_states, router, extra_work)
    silent_start = (router.psum_vote(work) == 0) & ~batch_work

    qs, n_adm, drop = admit(qs, qb, part0)

    tgt = _target(qs, N)
    fire_head = (qs.pending & (qs.kind == KIND_LINK)
                 & (~qs.consistent
                    | (clean_flat[tgt] & silent_start).reshape(P_loc, Q)))
    K = qs.wire_defer_ok.shape[0]
    if K:
        # wire-ring headroom gate: a head only fires if the backpressure
        # ring could carry its tail even if NOTHING ships this tick, so
        # the ring structurally cannot overflow and no link query can
        # ever be dropped on the wire (a lost tail would strand its qid).
        # Gated heads stay in the pending table — backpressure propagates
        # to admissions, which answer ok=False retriably when full.
        free = jnp.int32(K) - jnp.sum(qs.wire_defer_ok.astype(jnp.int32))
        fh_flat = fire_head.reshape(-1)
        head_rank = jnp.cumsum(fh_flat.astype(jnp.int32)) - 1
        fire_head = (fh_flat & (head_rank < free)).reshape(P_loc, Q)
    fh = fire_head.reshape(-1)
    wire = QueryBatch(
        qid=qs.qid.reshape(-1), kind=jnp.full((P_loc * Q,), KIND_LINK_TAIL,
                                              jnp.int32),
        part=qs.part2.reshape(-1), slot=qs.slot2.reshape(-1),
        part2=jnp.zeros((P_loc * Q,), jnp.int32),
        slot2=jnp.zeros((P_loc * Q,), jnp.int32),
        consistent=qs.consistent.reshape(-1),
        ok=qs.ok.reshape(-1) & seen_flat[tgt],
        issue=qs.issue.reshape(-1),
        vec=jnp.where(fh[:, None], sink_flat[tgt], 0.0), valid=fh)
    qs = replace(qs, pending=qs.pending & ~fire_head)
    return qs, wire, drop, n_adm


def query_answer_stage(qs: QueryState, wire_d, qb: QueryBatch, drop1,
                       n_adm, layer_states, sink, sink_seen, now,
                       stats_all, router, extra_work=None):
    """END-of-tick half: runs AFTER the sink update so answers read the
    freshest representations.

    1. admit the DELIVERED wire records (link tails — possibly carried
       over from an earlier tick by wire-lane backpressure);
    2. answer: ready KIND_EMBED rows gather the sink row, ready
       KIND_LINK_TAIL rows fire <vec, h_v>; answered slots free. Rows
       dropped by a full pending table answer ok=False instead of
       vanishing (see _drop_answers).

    Readiness: stale_ok rows are always ready; `consistent` rows wait
    for clean target flags AND end-of-tick global silence: no message
    moved this tick (the psum'd stats) and no pending window state,
    deferred route rows, or wire backlog anywhere.

    Returns (new QueryState, AnswerBatch [P_loc*Q + C_adm + |wire_d|],
    QueryStats). Q == 0 short-circuits statically to the exact
    pre-query-plane program.
    """
    P_loc, Q = qs.qid.shape
    d = qs.vec.shape[-1]
    if Q == 0:
        return qs, _empty_answers(d), zero_query_stats()

    part0 = router.part0()
    N = sink.shape[1]
    sink_flat = sink.reshape(P_loc * N, d)
    seen_flat = sink_seen.reshape(P_loc * N)
    clean_flat, timers = _plane_work(qs, layer_states, router, extra_work)
    moved = jnp.zeros((), jnp.int32)
    for s in stats_all:
        moved = moved + s.emitted + s.reduce_msgs + s.broadcast_msgs
    if getattr(router, "n_stages", 1) > 1:
        # 2-D mesh: stats cover this stage's layers only — globalize
        moved = router.psum_stage(moved)
    silent = (moved == 0) & (router.psum_vote(timers) == 0)

    qs, n_adm2, drop2 = admit(qs, wire_d, part0)

    tgt = _target(qs, N)
    fire = (qs.pending & (qs.kind != KIND_LINK)
            & (~qs.consistent
               | (clean_flat[tgt] & silent).reshape(P_loc, Q)))
    ff = fire.reshape(-1)
    h = sink_flat[tgt]
    is_tail = (qs.kind == KIND_LINK_TAIL).reshape(-1)
    score = jnp.sum(qs.vec.reshape(P_loc * Q, d) * h, axis=-1)
    ans = AnswerBatch(
        qid=qs.qid.reshape(-1),
        kind=jnp.where(is_tail, KIND_LINK, qs.kind.reshape(-1)),
        ok=ff & seen_flat[tgt] & jnp.where(is_tail, qs.ok.reshape(-1), True),
        tick=jnp.full((P_loc * Q,), now, jnp.int32),
        issue=qs.issue.reshape(-1),
        vec=jnp.where((ff & ~is_tail)[:, None], h, 0.0),
        score=jnp.where(ff & is_tail, score, 0.0), valid=ff)
    qs = replace(qs, pending=qs.pending & ~fire)

    # overflow-dropped admissions (host batch + wire) answer ok=False
    ans = jax.tree.map(
        lambda *xs: jnp.concatenate(xs),
        ans, _drop_answers(qb, drop1, now, d),
        _drop_answers(wire_d, drop2, now, d))

    psum = router.psum
    del n_adm2                        # tail re-admits are not new client queries
    stats = QueryStats(
        admitted=psum(n_adm),
        answered=psum(jnp.sum(fire)),
        dropped=psum(jnp.sum(drop1) + jnp.sum(drop2)),
        held_ticks=psum(jnp.sum(qs.pending)),
        wire_backlog=psum(jnp.sum(qs.wire_defer_ok.astype(jnp.int32))))
    return qs, ans, stats
