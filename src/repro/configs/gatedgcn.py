"""gatedgcn [gnn]
n_layers=16 d_hidden=70 aggregator=gated. [arXiv:2003.00982; paper]
"""
from __future__ import annotations

from repro.configs.base import ArchSpec
from repro.configs.gnn_common import (GNN_SHAPES, gnn_input_specs,
                                      make_gnn_train_step)
from repro.graph.gatedgcn import GatedGCN


def build(shape_name: str = "full_graph_sm"):
    d = GNN_SHAPES[shape_name].dims
    n_out = d["n_classes"] if d["n_classes"] else 1
    return GatedGCN(d_in=d["d_feat"], d_hidden=70, n_layers=16,
                    n_classes=n_out)


def build_reduced(shape_name: str = "full_graph_sm"):
    d = GNN_SHAPES[shape_name].dims
    n_out = d["n_classes"] if d["n_classes"] else 1
    return GatedGCN(d_in=16, d_hidden=16, n_layers=3, n_classes=n_out)


def _step(model, s):
    shape = GNN_SHAPES[s]
    if shape.dims["n_classes"]:
        return make_gnn_train_step(model, shape, needs_pos=False,
                                   needs_triplets=False)
    import jax
    import jax.numpy as jnp
    from repro.graph.graphs import Graph
    from repro.optim import adam, apply_updates, clip_by_global_norm
    opt = adam()

    def loss_fn(params, batch):
        g = Graph(senders=batch["senders"], receivers=batch["receivers"],
                  x=batch["x"], edge_mask=batch["edge_mask"],
                  node_mask=batch["node_mask"],
                  graph_ids=batch["graph_ids"], n_graphs=shape.dims["n_graphs"])
        e_node = model(params, g)[..., 0]
        e_node = jnp.where(g.node_mask, e_node, 0.0)
        e = jax.ops.segment_sum(e_node, g.graph_ids, g.n_graphs)
        return jnp.mean(jnp.square(e - batch["targets"]))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        upd, opt_state = opt.update(opt_state, grads, params, 1e-3)
        return apply_updates(params, upd), opt_state, loss

    return train_step


SPEC = ArchSpec(
    name="gatedgcn", family="gnn",
    build=build, build_reduced=build_reduced,
    shapes=GNN_SHAPES,
    input_specs=lambda model, s: gnn_input_specs(GNN_SHAPES[s], needs_pos=False,
                                                 needs_triplets=False),
    step=_step,
    batch_style="dict",
    notes="edge-featured MPNN with gated aggregation; LayerNorm replaces "
          "BatchNorm for streaming compatibility (DESIGN §2).")
