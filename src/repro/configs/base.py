"""ArchSpec: the contract between configs, the launcher and the dry-run.

An ArchSpec bundles:
  * build():        full-size model (the published config, verbatim)
  * build_reduced():tiny same-family model for CPU smoke tests
  * shapes:         {shape_name: ShapeSpec} — the assigned input shapes
  * input_specs(shape) -> dict of jax.ShapeDtypeStruct (no allocation)
  * step(model, shape) -> the jittable train_step / serve_step callable

The dry-run lowers step() against input_specs() under the production mesh;
smoke tests run build_reduced() on real (tiny) arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                     # "train" | "prefill" | "decode" | "serve"
    dims: Dict[str, int] = field(default_factory=dict)
    note: str = ""


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                   # "lm" | "gnn" | "recsys" | "d3gnn"
    build: Callable[[], Any]
    build_reduced: Callable[[], Any]
    shapes: Dict[str, ShapeSpec]
    input_specs: Callable[[Any, str], dict]     # (model, shape_name) -> specs
    step: Callable[[Any, str], Callable]        # (model, shape_name) -> fn
    notes: str = ""
    tune_for_mesh: Callable[[Any, Any], Any] = lambda model, mesh: model
    donate_inputs: Callable[[str], tuple] = lambda shape_name: ()
    batch_style: str = "positional"   # "positional" | "dict" (one batch arg)
    optimizer: str = "adam"           # "adam" | "adam8bit" (state-quantized)


def make_optimizer(name: str):
    if name == "adam8bit":
        from repro.optim.quantized import adam8bit
        return adam8bit()
    from repro.optim import adam
    return adam()


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ----------------------------------------------------------- LM helpers
LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            {"seq": 32768, "batch": 128}),
    "long_500k": ShapeSpec(
        "long_500k", "decode", {"seq": 524288, "batch": 1},
        note="decode vs a 512k KV cache is O(S) per token, so it runs for "
             "full-attention archs too (DESIGN §4); a 500k prefill would be "
             "quadratic and is not an assigned shape."),
}


def lm_input_specs(model, shape_name: str) -> dict:
    c = model.cfg
    sh = LM_SHAPES[shape_name]
    B, S = sh.dims["batch"], sh.dims["seq"]
    if sh.kind == "train":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if sh.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    # decode: one new token against an S-token cache
    nG, nB = c.n_groups, len(model.cfg.pattern)
    cache_kv = sds((nG, nB, B, S, c.n_kv, c.head_dim), jnp.dtype(c.dtype))
    return {"tokens": sds((B, 1), jnp.int32),
            "cache_k": cache_kv, "cache_v": cache_kv,
            "cache_len": sds((B,), jnp.int32)}


def lm_tune_for_mesh(model, mesh):
    """Mesh-aware model knobs: shard the residual stream over (data, model)
    so scanned-layer carries are fully distributed (this is the Megatron
    sequence/tensor hybrid — the d axis is gathered per layer on use)."""
    import dataclasses
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    cfg = dataclasses.replace(model.cfg, act_pspec=(dp, None, "model"))
    return type(model)(cfg)


def lm_step(model, shape_name: str, optimizer=None, grad_accum: int = 8,
            opt_name: str = "adam"):
    sh = LM_SHAPES[shape_name]
    if sh.kind == "train":
        from repro.optim import apply_updates, clip_by_global_norm
        opt = optimizer or make_optimizer(opt_name)
        B = sh.dims["batch"]
        k = grad_accum if B % grad_accum == 0 else 1
        m = B // k

        def train_step(params, opt_state, tokens, labels):
            S = tokens.shape[1]
            tok_mb = tokens.reshape(k, m, S)
            lab_mb = labels.reshape(k, m, S)

            def body(carry, xs):
                gsum, lsum = carry
                t, l = xs
                loss, g = jax.value_and_grad(model.loss)(params, t, l)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0),
                                           (tok_mb, lab_mb))
            grads = jax.tree.map(lambda x: x / k, gsum)
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, opt_state = opt.update(opt_state, grads, params, 3e-4)
            return apply_updates(params, upd), opt_state, lsum / k

        return train_step
    if sh.kind == "prefill":
        def prefill_step(params, tokens):
            x, _ = model.hidden_states(params, tokens)
            # next-token logits only; the cache materialization path is
            # exercised by the decode shapes
            logits = (x[:, -1] @ params["lm_head"].astype(x.dtype))
            return logits.astype(jnp.float32)

        return prefill_step

    def decode_step(params, tokens, cache_k, cache_v, cache_len):
        cache = {"k": cache_k, "v": cache_v, "len": cache_len}
        logits, new_cache = model.decode_step(params, cache, tokens)
        return logits, new_cache["k"], new_cache["v"], new_cache["len"]

    return decode_step


def lm_donate(shape_name: str) -> tuple:
    """Input-spec keys donated to outputs (decode caches alias in place)."""
    if LM_SHAPES[shape_name].kind == "decode":
        return ("cache_k", "cache_v")
    return ()
