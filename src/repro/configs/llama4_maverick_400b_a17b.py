"""llama4-maverick-400b-a17b [moe]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

MoE every other layer (interleave step 2, the Maverick layout) + one shared
expert — 24 dense + 24 MoE layers gives the ~400B total / ~17B active
parameter split of the published model. Early-fusion multimodality concerns
the vision frontend only; per the assignment the backbone is modeled and
the modality frontend is out of scope.
"""
from __future__ import annotations

from functools import partial

from repro.configs.base import (ArchSpec, LM_SHAPES, lm_donate,
                                lm_input_specs, lm_step, lm_tune_for_mesh)
from functools import partial as _partial
from repro.nn.moe import MoEConfig
from repro.nn.transformer import TransformerConfig, TransformerLM

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, head_dim=128,
    d_ff=16384,                       # dense-layer FFN
    vocab=202048,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, every=2, n_shared=1,
                  capacity_factor=1.25),
    rope_theta=500000.0)

REDUCED = TransformerConfig(
    name="llama4-maverick-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=512,
    moe=MoEConfig(num_experts=8, top_k=1, d_ff=64, every=2, n_shared=1,
                  capacity_factor=2.0),
    dtype="float32", loss_chunks=2)

SPEC = ArchSpec(
    name="llama4-maverick-400b-a17b", family="lm",
    build=lambda shape_name=None: TransformerLM(CONFIG),
    build_reduced=lambda shape_name=None: TransformerLM(REDUCED),
    shapes=LM_SHAPES,
    input_specs=lm_input_specs,
    step=lm_step,
    tune_for_mesh=lm_tune_for_mesh,
    donate_inputs=lm_donate,
    notes="MoE 128e top-1 every 2nd layer + 1 shared expert; ~400B total.")
