"""d3gnn-sage — the paper's own evaluation model under the streaming engine:
2-layer GraphSAGE, 64-dim output (paper §6), running as the distributed
micro-tick dataflow. Registered as an EXTRA dry-run cell (the 40 assigned
cells are the 10 arch x 4 shape grid; this one proves the paper's engine
itself lowers and compiles on the production mesh).

Scale: 1024 logical parts (= max_parallelism), reddit-scale features
(d_in=602), per-part caps sized for ~1M vertices / ~16M edges globally.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, sds
from repro.core import windowing as win
from repro.core.events import EdgeBatch, FeatBatch, ReplBatch
from repro.core.state import LayerState, TopoState
from repro.core.tick import layer_tick
from repro.graph.sage import GraphSAGE

N_PARTS = 1024
NODE_CAP = 1024          # per-part vertex slots  (~1M vertices w/ replicas)
EDGE_CAP = 16384         # per-part edge slots    (~16M edges)
REPL_CAP = 4096
FEAT_CAP = 16384         # event rows per tick
EDGE_TICK_CAP = 16384
D_IN, D_HID = 602, 64

SHAPES = {
    "stream_tick": ShapeSpec(
        "stream_tick", "serve",
        {"n_parts": N_PARTS, "node_cap": NODE_CAP, "edge_cap": EDGE_CAP,
         "feat_cap": FEAT_CAP, "d_in": D_IN, "d_hid": D_HID}),
}


def build(shape_name=None):
    return GraphSAGE((D_IN, D_HID, D_HID))


def build_reduced(shape_name=None):
    return GraphSAGE((8, 8, 8))


def _topo_specs():
    P, E, R, N = N_PARTS, EDGE_CAP, REPL_CAP, NODE_CAP
    i32, b = jnp.int32, jnp.bool_
    return TopoState(
        e_src_slot=sds((P, E), i32), e_dst_slot=sds((P, E), i32),
        e_dst_mpart=sds((P, E), i32), e_dst_mslot=sds((P, E), i32),
        e_valid=sds((P, E), b),
        r_master_slot=sds((P, R), i32), r_rep_part=sds((P, R), i32),
        r_rep_slot=sds((P, R), i32), r_valid=sds((P, R), b),
        v_exists=sds((P, N), b), is_master=sds((P, N), b),
        m_part=sds((P, N), i32), m_slot=sds((P, N), i32))


def _layer_specs(d):
    P, N = N_PARTS, NODE_CAP
    f32, i32, b = jnp.float32, jnp.int32, jnp.bool_
    return LayerState(
        feat=sds((P, N, d), f32), has_feat=sds((P, N), b),
        x_sent=sds((P, N, d), f32), has_sent=sds((P, N), b),
        agg=sds((P, N, d), f32), agg_cnt=sds((P, N), f32),
        red_pending=sds((P, N), b), red_deadline=sds((P, N), i32),
        fwd_pending=sds((P, N), b), fwd_deadline=sds((P, N), i32),
        cms=sds((4, 2048), f32), last_touch=sds((P, N), i32),
        bc_defer=sds((0, d + 5), f32), bc_defer_ok=sds((0,), b),
        rmi_defer=sds((0, d + 5), f32), rmi_defer_ok=sds((0,), b))


def input_specs(model, shape_name: str) -> dict:
    C, CE = FEAT_CAP, EDGE_TICK_CAP
    i32, b, f32 = jnp.int32, jnp.bool_, jnp.float32
    return {
        "topo": _topo_specs(),
        "state0": _layer_specs(D_IN),
        "state1": _layer_specs(D_HID),
        "inbox": FeatBatch(part=sds((C,), i32), slot=sds((C,), i32),
                           feat=sds((C, D_IN), f32), valid=sds((C,), b)),
        "eb": EdgeBatch(part=sds((CE,), i32), edge_slot=sds((CE,), i32),
                        src_slot=sds((CE,), i32), dst_slot=sds((CE,), i32),
                        dst_master_part=sds((CE,), i32),
                        dst_master_slot=sds((CE,), i32), valid=sds((CE,), b)),
        "rb": ReplBatch(part=sds((CE,), i32), repl_slot=sds((CE,), i32),
                        master_slot=sds((CE,), i32), rep_part=sds((CE,), i32),
                        rep_slot=sds((CE,), i32), valid=sds((CE,), b)),
        "now": sds((), i32),
    }


def step(model, shape_name: str):
    wconf = win.WindowConfig(kind=win.TUMBLING, interval=4)

    def stream_step(params, topo, state0, state1, inbox, eb, rb, now):
        s0, out0, st0, _ = layer_tick(model.layers[0], params["l0"], topo,
                                      state0, inbox, eb, rb, now, wconf,
                                      FEAT_CAP)
        s1, out1, st1, _ = layer_tick(model.layers[1], params["l1"], topo,
                                      state1, out0, eb, rb, now, wconf,
                                      FEAT_CAP)
        return s0, s1, out1

    return stream_step


SPEC = ArchSpec(
    name="d3gnn-sage", family="d3gnn",
    build=build, build_reduced=build_reduced,
    shapes=SHAPES,
    input_specs=input_specs,
    step=step,
    notes="the paper's streaming engine itself, lowered on the mesh.")
