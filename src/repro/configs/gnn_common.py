"""Shared GNN shape definitions + step builders for the four assigned
GNN architectures.

Shapes (assigned):
  full_graph_sm : n_nodes=2,708 n_edges=10,556 d_feat=1,433 (cora-scale,
                  full-batch node classification, 7 classes)
  minibatch_lg  : global graph n_nodes=232,965 n_edges=114,615,892
                  (reddit-scale); the training step consumes a SAMPLED
                  subgraph: batch_nodes=1,024, fanout 15-10 ->
                  node cap 1,024*(1+15+150), edge cap 1,024*(15+150),
                  d_feat=602, 41 classes. graph/sampler.py produces these.
  ogb_products  : n_nodes=2,449,029 n_edges=61,859,140 d_feat=100
                  (full-batch-large), 47 classes
  molecule      : 128 graphs x (30 nodes, 64 edges), 3D positions, energy
                  regression

NequIP/DimeNet need positions: graph shapes without natural coordinates get
a synthesized `pos` input (assignment: geometric models still run every
shape). DimeNet additionally consumes triplet indices capped at
T_max = 4 * n_edges (host-built by graph/triplets.py; DESIGN §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec, sds
from repro.graph.graphs import Graph
from repro.graph.sampler import sample_capacities

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               {"n_nodes": 2708, "n_edges": 10556,
                                "d_feat": 1433, "n_classes": 7,
                                "n_graphs": 1}),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train",
                              {"n_nodes": sample_capacities(1024, (15, 10))[0],
                               "n_edges": sample_capacities(1024, (15, 10))[1],
                               "d_feat": 602, "n_classes": 41,
                               "n_graphs": 1,
                               "global_nodes": 232965,
                               "global_edges": 114615892}),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              {"n_nodes": 2449029, "n_edges": 61859140,
                               "d_feat": 100, "n_classes": 47,
                               "n_graphs": 1}),
    "molecule": ShapeSpec("molecule", "train",
                          {"n_nodes": 128 * 30, "n_edges": 128 * 64,
                           "d_feat": 16, "n_classes": 0,
                           "n_graphs": 128}),
}


def pad512(n: int) -> int:
    """Static capacities are padded to multiples of 512 so arrays shard
    evenly on both production meshes (256 and 512 chips); the edge/node
    masks cover the padding rows (the engine is mask-based throughout)."""
    return -(-n // 512) * 512


def gnn_input_specs(shape: ShapeSpec, needs_pos: bool, needs_triplets: bool,
                    t_factor: int = 4) -> dict:
    d = shape.dims
    N, E = pad512(d["n_nodes"]), pad512(d["n_edges"])
    specs = {
        "senders": sds((E,), jnp.int32),
        "receivers": sds((E,), jnp.int32),
        "x": sds((N, d["d_feat"]), jnp.float32),
        "edge_mask": sds((E,), jnp.bool_),
        "node_mask": sds((N,), jnp.bool_),
    }
    if needs_pos:
        specs["pos"] = sds((N, 3), jnp.float32)
    if d["n_classes"]:
        specs["labels"] = sds((N,), jnp.int32)
        specs["label_mask"] = sds((N,), jnp.bool_)
    else:
        specs["targets"] = sds((d["n_graphs"],), jnp.float32)
        specs["graph_ids"] = sds((N,), jnp.int32)
    if needs_triplets:
        T = pad512(t_factor * E)
        specs["t_kj"] = sds((T,), jnp.int32)
        specs["t_ji"] = sds((T,), jnp.int32)
        specs["t_mask"] = sds((T,), jnp.bool_)
    return specs


def make_gnn_train_step(model, shape: ShapeSpec, needs_pos: bool,
                        needs_triplets: bool, lr: float = 1e-3):
    """Generic full/sampled-batch GNN training step (adam + clip)."""
    from repro.optim import adam, apply_updates, clip_by_global_norm
    opt = adam()
    n_graphs = shape.dims["n_graphs"]
    classes = shape.dims["n_classes"]

    def loss_fn(params, batch):
        g = Graph(senders=batch["senders"], receivers=batch["receivers"],
                  x=batch["x"], edge_mask=batch["edge_mask"],
                  node_mask=batch["node_mask"],
                  pos=batch.get("pos"), graph_ids=batch.get("graph_ids"),
                  n_graphs=n_graphs)
        extra = ((batch["t_kj"], batch["t_ji"], batch["t_mask"])
                 if needs_triplets else ())
        out = model(params, g, *extra)
        if classes:
            logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(logp, batch["labels"][:, None],
                                       axis=-1)[:, 0]
            m = batch["label_mask"] & batch["node_mask"]
            return jnp.sum(jnp.where(m, -gold, 0.0)) / jnp.maximum(
                jnp.sum(m), 1)
        return jnp.mean(jnp.square(out.astype(jnp.float32) - batch["targets"]))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        upd, opt_state = opt.update(opt_state, grads, params, lr)
        return apply_updates(params, upd), opt_state, loss

    return train_step
