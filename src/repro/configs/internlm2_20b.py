"""internlm2-20b [dense]
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
[arXiv:2403.17297; hf]
"""
from __future__ import annotations

from repro.configs.base import (ArchSpec, LM_SHAPES, lm_donate,
                                lm_input_specs, lm_step, lm_tune_for_mesh)
from repro.nn.transformer import TransformerConfig, TransformerLM

CONFIG = TransformerConfig(
    name="internlm2-20b",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=16384, vocab=92544, rope_theta=1000000.0)

REDUCED = TransformerConfig(
    name="internlm2-reduced",
    n_layers=4, d_model=64, n_heads=8, n_kv=2, head_dim=8, d_ff=160,
    vocab=512, dtype="float32", loss_chunks=2)

SPEC = ArchSpec(
    name="internlm2-20b", family="lm",
    build=lambda shape_name=None: TransformerLM(CONFIG),
    build_reduced=lambda shape_name=None: TransformerLM(REDUCED),
    shapes=LM_SHAPES,
    input_specs=lm_input_specs,
    step=lm_step,
    tune_for_mesh=lm_tune_for_mesh,
    donate_inputs=lm_donate,
    notes="dense GQA kv=8.")
