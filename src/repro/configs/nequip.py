"""nequip [gnn]
n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5 equivariance=E(3)
tensor-product. [arXiv:2101.03164; paper]
"""
from __future__ import annotations

from functools import partial

from repro.configs.base import ArchSpec
from repro.configs.gnn_common import (GNN_SHAPES, gnn_input_specs,
                                      make_gnn_train_step)
from repro.graph.nequip import NequIP


def build(shape_name: str = "molecule"):
    d = GNN_SHAPES[shape_name].dims
    return NequIP(d_in=d["d_feat"], mult=32, l_max=2, n_layers=5, n_rbf=8,
                  cutoff=5.0, n_classes=d["n_classes"])


def build_reduced(shape_name: str = "molecule"):
    d = GNN_SHAPES[shape_name].dims
    return NequIP(d_in=16, mult=4, l_max=2, n_layers=2, n_rbf=4,
                  cutoff=5.0, n_classes=d["n_classes"])


SPEC = ArchSpec(
    name="nequip", family="gnn",
    build=build, build_reduced=build_reduced,
    shapes=GNN_SHAPES,
    input_specs=lambda model, s: gnn_input_specs(GNN_SHAPES[s], needs_pos=True,
                                                 needs_triplets=False),
    step=lambda model, s: make_gnn_train_step(model, GNN_SHAPES[s],
                                              needs_pos=True,
                                              needs_triplets=False),
    batch_style="dict",
    notes="irrep tensor-product regime; positions synthesized for the "
          "non-molecular shapes (DESIGN §4).")
