"""two-tower-retrieval [recsys]
embed_dim=256 tower_mlp=1024-512-256 interaction=dot — sampled-softmax
retrieval. [RecSys'19 (YouTube); unverified]

Embedding tables: user 10^8 rows, item 10^7 rows x dim 256 — the "huge
sparse table" regime (taxonomy §B.6). Tables are row-sharded over the whole
mesh; lookups are EmbeddingBag = take + segment-sum (JAX has no native op).

Shapes:
  train_batch    batch=65,536  in-batch sampled softmax (+logQ correction)
  serve_p99      batch=512     online user-tower inference
  serve_bulk     batch=262,144 offline scoring (paired dot)
  retrieval_cand batch=1, n_candidates=1,000,000 — one batched matmul
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, sds
from repro.recsys.two_tower import TwoTower, TwoTowerConfig

# vocabs padded to multiples of 512 so the tables row-shard evenly on both
# production meshes (10^8 / 10^7 rows semantically)
CONFIG = TwoTowerConfig(embed_dim=256, tower_mlp=(1024, 512, 256),
                        user_vocab=100_000_256, item_vocab=10_000_384,
                        user_fields=4, item_fields=2, max_ids_per_field=8)

REDUCED = TwoTowerConfig(embed_dim=32, tower_mlp=(64, 32),
                         user_vocab=1000, item_vocab=1000,
                         user_fields=2, item_fields=2, max_ids_per_field=4)

SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "serve",
                                {"batch": 1, "n_candidates": 1_000_000}),
}


def input_specs(model, shape_name: str) -> dict:
    c = model.cfg
    d = SHAPES[shape_name].dims
    B = d["batch"]
    u = (B, c.user_fields, c.max_ids_per_field)
    i = (B, c.item_fields, c.max_ids_per_field)
    if shape_name == "train_batch":
        return {"user_ids": sds(u, jnp.int32), "item_ids": sds(i, jnp.int32),
                "item_logq": sds((B,), jnp.float32)}
    if shape_name == "serve_p99":
        return {"user_ids": sds(u, jnp.int32)}
    if shape_name == "serve_bulk":
        return {"user_ids": sds(u, jnp.int32), "item_ids": sds(i, jnp.int32)}
    nc = -(-d["n_candidates"] // 512) * 512   # pad for even mesh sharding
    return {"user_ids": sds(u, jnp.int32),
            "cand_ids": sds((nc, c.item_fields, c.max_ids_per_field),
                            jnp.int32)}


def step(model, shape_name: str):
    if shape_name == "train_batch":
        from repro.optim import adam, apply_updates, clip_by_global_norm
        opt = adam()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(
                params, batch["user_ids"], batch["item_ids"],
                batch["item_logq"])
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, opt_state = opt.update(opt_state, grads, params, 1e-3)
            return apply_updates(params, upd), opt_state, loss

        return train_step
    if shape_name == "serve_p99":
        return lambda params, batch: model.user_tower(params, batch["user_ids"])
    if shape_name == "serve_bulk":
        return lambda params, batch: model.score(
            params, batch["user_ids"], batch["item_ids"])
    return lambda params, batch: model.retrieval_scores(
        params, batch["user_ids"], batch["cand_ids"])


SPEC = ArchSpec(
    name="two-tower-retrieval", family="recsys",
    build=lambda shape_name=None: TwoTower(CONFIG),
    build_reduced=lambda shape_name=None: TwoTower(REDUCED),
    shapes=SHAPES,
    input_specs=input_specs,
    step=step,
    batch_style="dict",
    notes="embedding lookup is the hot path; tables row-sharded mesh-wide.")
