"""moonshot-v1-16b-a3b [moe]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Moonlight-style fine-grained MoE: 64 routed experts (top-6) + 2 shared
experts with per-expert d_ff=1408, MoE in every layer.
"""
from __future__ import annotations

from repro.configs.base import (ArchSpec, LM_SHAPES, lm_donate,
                                lm_input_specs, lm_step, lm_tune_for_mesh)
from repro.nn.moe import MoEConfig
from repro.nn.transformer import TransformerConfig, TransformerLM

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=1408, vocab=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, every=1, n_shared=2,
                  capacity_factor=1.25),
    rope_theta=50000.0)

REDUCED = TransformerConfig(
    name="moonshot-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16, d_ff=96,
    vocab=512,
    moe=MoEConfig(num_experts=8, top_k=3, d_ff=48, every=1, n_shared=2,
                  capacity_factor=2.0),
    dtype="float32", loss_chunks=2)

SPEC = ArchSpec(
    name="moonshot-v1-16b-a3b", family="lm",
    build=lambda shape_name=None: TransformerLM(CONFIG),
    build_reduced=lambda shape_name=None: TransformerLM(REDUCED),
    shapes=LM_SHAPES,
    input_specs=lm_input_specs,
    step=lm_step,
    tune_for_mesh=lm_tune_for_mesh,
    donate_inputs=lm_donate,
    notes="kimi/moonlight fine-grained MoE, 64e top-6 + 2 shared.")
