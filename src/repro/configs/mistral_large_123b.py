"""mistral-large-123b [dense]
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
from __future__ import annotations

from repro.configs.base import (ArchSpec, LM_SHAPES, lm_donate,
                                lm_input_specs, lm_step, lm_tune_for_mesh)
from repro.nn.transformer import TransformerConfig, TransformerLM

CONFIG = TransformerConfig(
    name="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, head_dim=128,
    d_ff=28672, vocab=32768, rope_theta=1000000.0)

REDUCED = TransformerConfig(
    name="mistral-large-reduced",
    n_layers=4, d_model=64, n_heads=8, n_kv=2, head_dim=8, d_ff=160,
    vocab=512, dtype="float32", loss_chunks=2)

SPEC = ArchSpec(
    name="mistral-large-123b", family="lm",
    build=lambda shape_name=None: TransformerLM(CONFIG),
    build_reduced=lambda shape_name=None: TransformerLM(REDUCED),
    shapes=LM_SHAPES,
    input_specs=lm_input_specs,
    step=lm_step,
    tune_for_mesh=lm_tune_for_mesh,
    donate_inputs=lm_donate,
    notes="deepest assigned config (88L); dense GQA.")
