"""mistral-nemo-12b [dense]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072 — 128k ctx.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]

head_dim=128 (q-proj 5120 -> 4096), the published Nemo geometry.
"""
from __future__ import annotations

from repro.configs.base import (ArchSpec, LM_SHAPES, lm_donate,
                                lm_input_specs, lm_step, lm_tune_for_mesh)
from repro.nn.transformer import TransformerConfig, TransformerLM

CONFIG = TransformerConfig(
    name="mistral-nemo-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1000000.0)

REDUCED = TransformerConfig(
    name="mistral-nemo-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=160,
    vocab=512, dtype="float32", loss_chunks=2)

SPEC = ArchSpec(
    name="mistral-nemo-12b", family="lm",
    build=lambda shape_name=None: TransformerLM(CONFIG),
    build_reduced=lambda shape_name=None: TransformerLM(REDUCED),
    shapes=LM_SHAPES,
    input_specs=lm_input_specs,
    step=lm_step,
    tune_for_mesh=lm_tune_for_mesh,
    donate_inputs=lm_donate,
    notes="128k-context dense GQA; head_dim 128 != d_model/n_heads.")
