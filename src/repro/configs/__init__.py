"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines SPEC: configs.base.ArchSpec with the exact published
config and its four assigned input shapes. The paper's own evaluation model
(2-layer GraphSAGE-64 under the D3-GNN streaming engine) is registered as
`d3gnn-sage` in addition to the 10 assigned architectures.
"""
from __future__ import annotations

from importlib import import_module

_MODULES = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "nequip": "repro.configs.nequip",
    "dimenet": "repro.configs.dimenet",
    "pna": "repro.configs.pna",
    "gatedgcn": "repro.configs.gatedgcn",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
    "d3gnn-sage": "repro.configs.d3gnn_sage",
}

ARCH_IDS = [k for k in _MODULES if k != "d3gnn-sage"]


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return import_module(_MODULES[arch_id]).SPEC


def all_cells(include_extra: bool = False):
    """Every (arch, shape) cell — 40 assigned (+ the paper's own model)."""
    ids = list(ARCH_IDS) + (["d3gnn-sage"] if include_extra else [])
    out = []
    for a in ids:
        spec = get_arch(a)
        for s in spec.shapes:
            out.append((a, s))
    return out
