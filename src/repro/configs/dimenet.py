"""dimenet [gnn]
n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6.
[arXiv:2003.03123; unverified]
"""
from __future__ import annotations

from repro.configs.base import ArchSpec
from repro.configs.gnn_common import (GNN_SHAPES, gnn_input_specs,
                                      make_gnn_train_step)
from repro.graph.dimenet import DimeNet

# triplet cap = 4 x n_edges (static-shape bound; graph/triplets.py masks)
T_FACTOR = 4


def build(shape_name: str = "molecule"):
    d = GNN_SHAPES[shape_name].dims
    return DimeNet(d_in=d["d_feat"], d_hidden=128, n_blocks=6, n_bilinear=8,
                   n_spherical=7, n_radial=6, n_classes=d["n_classes"])


def build_reduced(shape_name: str = "molecule"):
    d = GNN_SHAPES[shape_name].dims
    return DimeNet(d_in=16, d_hidden=16, n_blocks=2, n_bilinear=4,
                   n_spherical=4, n_radial=4, n_classes=d["n_classes"])


SPEC = ArchSpec(
    name="dimenet", family="gnn",
    build=build, build_reduced=build_reduced,
    shapes=GNN_SHAPES,
    input_specs=lambda model, s: gnn_input_specs(GNN_SHAPES[s], needs_pos=True,
                                                 needs_triplets=True,
                                                 t_factor=T_FACTOR),
    step=lambda model, s: make_gnn_train_step(model, GNN_SHAPES[s],
                                              needs_pos=True,
                                              needs_triplets=True),
    batch_style="dict",
    notes="triplet-gather regime; T_max = 4*E (DESIGN §2: angular basis is "
          "bessel x cos-series — scipy-free, same flops).")
