"""route_pack: sort-by-destination rank packing for the routing plane.

Replaces the O(C * D) one-hot [C, D] membership cumsum + per-field
scatter that `MeshRouter.route` used for bucketing (ISSUE 5 tentpole)
with a plan/place pair:

  route_plan  : ONE stable sort by destination device; per-record rank =
                position - run start (searchsorted over the sorted keys).
                Records beyond the per-destination bucket capacity `cap`
                are flagged as overflow — the router defers them as
                backpressure instead of shipping air.
  route_pack  : place the packed wire rows at their [D * cap] send slots.
                "xla" backend: one scatter of the whole [*, W] row block.
                "pallas" backend: every send slot receives at most ONE
                row, so placement IS a sorted segment-sum — reuses the
                one-hot MXU `segment_sum_kernel` machinery from
                kernels/segment_reduce (interpret=True off-TPU).

Both backends are bit-identical for finite rows (the one-hot matmul
multiplies by exact 0/1 and each output slot sums exactly one row).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.ops import segment_sum_sorted

DEFAULT_BLOCK_E = 128
DEFAULT_BLOCK_V = 128


@partial(jax.jit, static_argnames=("n_dev", "cap"))
def route_plan(dst, ok, n_dev: int, cap: int):
    """Compaction plan for one lane.

    dst [N] int32 destination device per record (any value — rows with
    ok=False OR an out-of-range destination are excluded, matching
    `route_plan_ref`); ok [N] bool live-record mask.

    Returns (order, ship_s, slot_s, left_s):
      order  [N] : stable sort permutation grouping records by destination
                   (sentinel-keyed dead rows sink to the tail) — apply it
                   to the packed rows before placement;
      ship_s [N] : post-permutation mask of records that fit their bucket;
      slot_s [N] : post-permutation [D * cap] send slot (dst * cap + rank),
                   n_dev * cap sentinel for everything not shipped;
      left_s [N] : post-permutation mask of live records that overflowed
                   (the router's defer/backpressure set). FIFO per
                   destination: the stable sort preserves record order
                   within a destination, so earlier records always ship
                   (or defer) before later ones.
    """
    n = dst.shape[0]
    key = jnp.where(ok & (dst >= 0) & (dst < n_dev), dst, n_dev)
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    starts = jnp.searchsorted(key_s, jnp.arange(n_dev + 1)).astype(jnp.int32)
    rank = jnp.arange(n, dtype=jnp.int32) - starts[jnp.minimum(key_s, n_dev)]
    live = key_s < n_dev
    ship_s = live & (rank < cap)
    slot_s = jnp.where(ship_s, key_s * cap + rank, n_dev * cap)
    return order, ship_s, slot_s, live & ~ship_s


@partial(jax.jit, static_argnames=("n_slots", "backend", "block_e",
                                   "block_v", "interpret"))
def route_pack(rows, slots, n_slots: int, backend: str = "xla",
               block_e: int = DEFAULT_BLOCK_E,
               block_v: int = DEFAULT_BLOCK_V,
               interpret: bool | None = None):
    """Place packed wire rows [N, W] at `slots` [N] of a [n_slots, W] send
    buffer (slot == n_slots is the drop sentinel; each live slot receives
    at most one row — guaranteed by route_plan's rank construction).
    """
    if backend == "xla":
        return jnp.zeros((n_slots,) + rows.shape[1:], rows.dtype).at[
            slots].set(rows, mode="drop")
    if backend != "pallas":
        raise ValueError(f"route_pack backend must be 'xla' or 'pallas', "
                         f"got {backend!r}")
    # slots from route_plan are ascending over shipped records but the
    # sentinel rows sit interleaved where buckets overflowed — one more
    # stable sort restores the sorted-segment contract of the kernel.
    order = jnp.argsort(slots, stable=True)
    return segment_sum_sorted(rows[order], slots[order], n_slots,
                              block_e=block_e, block_v=block_v,
                              interpret=interpret)
