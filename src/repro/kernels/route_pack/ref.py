"""Pure-jnp oracles for the route_pack op."""
from __future__ import annotations

import jax.numpy as jnp


def route_plan_ref(dst, ok, n_dev: int, cap: int):
    """O(N * D) reference plan: per-destination membership cumsum ranks
    (the pre-ISSUE-5 bucketing formulation, kept as the oracle)."""
    member = (jnp.where(ok, dst, n_dev)[:, None]
              == jnp.arange(n_dev)[None, :])                    # [N, D]
    pos = jnp.cumsum(member.astype(jnp.int32), axis=0) - 1
    rank = jnp.sum(jnp.where(member, pos, 0), axis=1)
    live = ok & (dst >= 0) & (dst < n_dev)
    ship = live & (rank < cap)
    slot = jnp.where(ship, dst * cap + rank, n_dev * cap)
    return ship, slot, live & ~ship


def route_pack_ref(rows, slots, n_slots: int):
    """Guarded scatter placement (the xla path, spelled out)."""
    return jnp.zeros((n_slots,) + rows.shape[1:], rows.dtype).at[slots].set(
        rows, mode="drop")
