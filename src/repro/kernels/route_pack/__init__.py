from repro.kernels.route_pack.ops import route_pack, route_plan
from repro.kernels.route_pack.ref import route_pack_ref, route_plan_ref

__all__ = ["route_pack", "route_plan", "route_pack_ref", "route_plan_ref"]
