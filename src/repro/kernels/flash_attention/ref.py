"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True):
    """q: [BH, S, D]; k/v: [BH, T, D]. f32 softmax, matches kernel contract."""
    D = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q, k).astype(jnp.float32) / (D ** 0.5)
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bst,btd->bsd", w, v)


def gqa_attention_ref(q, k, v, causal: bool = True):
    """q: [B,S,H,D]; k/v: [B,T,Kh,D] — the nn.attention layout."""
    from repro.nn.attention import causal_mask, mha
    mask = causal_mask(q.shape[1], k.shape[1]) if causal else None
    return mha(q, k, v, mask=mask)
