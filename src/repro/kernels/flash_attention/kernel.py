"""Pallas TPU kernel: block-tiled online-softmax attention (FlashAttention,
arXiv:2205.14135, re-tiled for VMEM/MXU).

Grid: (batch*kv_heads*q_per_kv, n_q_blocks, n_kv_blocks) — the kv loop is
the innermost (sequential) dimension so the running (max, sumexp, acc)
state lives in VMEM scratch across kv steps of one q block.

Per step the kernel computes
    s   = q_blk @ k_blk^T * scale            (MXU, f32 accum)
    m'  = max(m, rowmax(s));  p = exp(s - m')
    acc = acc * exp(m - m') + p @ v_blk       (MXU)
and normalizes by the final sumexp on the last kv step. Causal masking
skips nothing structurally (masked blocks still run — the ops.py wrapper
chooses grid bounds so fully-masked tail blocks are never launched).

VMEM per step: q/k/v blocks (block_q|block_k x d) + acc (block_q x d) f32 +
two (block_q,) vectors — block_q=block_k=256, d<=128 is ~0.8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, s_scr, acc_scr,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # [block_q, d]
    k = k_ref[0]                                     # [block_k, d]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    s_scr[...] = s_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(s_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_kernel(q, k, v, causal: bool = True, block_q: int = 256,
                           block_k: int = 256, interpret: bool = True):
    """q: [BH, S, D]; k/v: [BH, T, D] (kv heads already broadcast).

    Returns [BH, S, D] in q.dtype.
    """
    BH, S, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq, nk = S // block_q, T // block_k
    assert nq * block_q == S and nk * block_k == T, (S, T, block_q, block_k)
    scale = 1.0 / (D ** 0.5)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k, n_kv_blocks=nk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
