"""jit'd wrapper: GQA layout -> kernel layout, head broadcast, dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    """GQA flash attention. q: [B,S,H,D]; k/v: [B,T,Kh,D] -> [B,S,H,D]."""
    if interpret is None:
        interpret = not _is_tpu()
    B, S, H, D = q.shape
    T, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    # fold (B, Kh, G) into one batch axis; kv broadcast over G
    qk = q.reshape(B, S, Kh, G, D).transpose(0, 2, 3, 1, 4).reshape(
        B * Kh * G, S, D)
    kk = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Kh, G, T, D)).reshape(B * Kh * G, T, D)
    vv = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Kh, G, T, D)).reshape(B * Kh * G, T, D)
    out = flash_attention_kernel(qk, kk, vv, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out.reshape(B, Kh, G, S, D).transpose(0, 3, 1, 2, 4).reshape(
        B, S, H, D)
