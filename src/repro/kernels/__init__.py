"""Pallas TPU kernels for the compute hot-spots.

  segment_reduce/  fused per-destination segment-sum over sorted edges —
                   the scatter half of every MPGNN layer and of the paper's
                   windowed evictReduce (GNN hot path). One-hot x message
                   matmul per tile => the reduction runs on the MXU.
  flash_attention/ block-tiled online-softmax attention (LM prefill path).
  embedding_bag/   bag-reduce over gathered table rows (recsys hot path;
                   JAX has no native EmbeddingBag).

Each kernel ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper + layout preprocessing) and ref.py (pure-jnp
oracle). Tests sweep shapes/dtypes in interpret mode against the oracle —
TPU is the compile target, CPU interpret is the correctness harness.
"""
