"""jit'd wrapper: layout preparation + kernel dispatch.

The layout step (sort by destination, pad so edge blocks never straddle
output tiles) runs in XLA; the scatter-reduction runs in the Pallas kernel
on the MXU. On non-TPU backends `interpret=True` executes the same kernel
body for correctness tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import (DEFAULT_BLOCK_E,
                                                 DEFAULT_BLOCK_R,
                                                 DEFAULT_BLOCK_V,
                                                 mean_rows_kernel,
                                                 segment_sum_kernel)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_segments", "block_e", "block_v",
                                   "interpret", "trim"))
def segment_sum_sorted(msgs, seg_ids, n_segments: int,
                       block_e: int = DEFAULT_BLOCK_E,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool | None = None,
                       trim: bool = True):
    """Segment-sum of msgs [E, d] by seg_ids [E] (MUST be sorted ascending;
    id >= n_segments = padding). Returns [n_segments, d].

    trim=False is the opt-out for block-aligned callers that want the raw
    padded [n_segments_pad, d] kernel output (n_segments_pad = n_segments
    rounded up to block_v; the tail rows are zero). It used to be the only
    behaviour, which silently handed every caller an off-by-block tail to
    slice — now the slice happens here.
    """
    if interpret is None:
        interpret = not _is_tpu()
    E, d = msgs.shape
    n_vblk = -(-n_segments // block_v)

    # ---- layout: pad edges so no block spans two output tiles ----------
    vblk_of_edge = jnp.minimum(seg_ids // block_v, n_vblk - 1)
    # within-block capacity: each destination tile's edges padded up to a
    # multiple of block_e by routing them to per-tile padded ranges.
    counts = jnp.zeros((n_vblk,), jnp.int32).at[vblk_of_edge].add(
        jnp.where(seg_ids < n_segments, 1, 0))
    padded_counts = ((counts + block_e - 1) // block_e) * block_e
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(padded_counts)[:-1]])
    # rank of each edge within its tile (seg_ids sorted => stable arange)
    tile_start_edge = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(E, dtype=jnp.int32) - tile_start_edge[vblk_of_edge]
    pos = starts[vblk_of_edge] + rank
    e_cap = E + n_vblk * block_e          # worst-case padded length
    e_cap = ((e_cap + block_e - 1) // block_e) * block_e
    valid = seg_ids < n_segments
    pos = jnp.where(valid, pos, e_cap - 1)  # dump padding at the very end

    msgs_p = jnp.zeros((e_cap, d), msgs.dtype).at[pos].add(
        jnp.where(valid[:, None], msgs, 0.0))
    seg_local = jnp.full((e_cap,), block_v, jnp.int32).at[pos].set(
        jnp.where(valid, seg_ids % block_v, block_v))
    # which output tile each edge block belongs to
    n_eblk = e_cap // block_e
    eblk_starts = jnp.arange(n_eblk, dtype=jnp.int32) * block_e
    cum = jnp.cumsum(padded_counts)
    eblk_to_vblk = jnp.searchsorted(cum, eblk_starts, side="right"
                                    ).astype(jnp.int32)
    eblk_to_vblk = jnp.minimum(eblk_to_vblk, n_vblk - 1)
    first = jnp.concatenate([jnp.ones(1, jnp.int32),
                             (eblk_to_vblk[1:] != eblk_to_vblk[:-1])
                             .astype(jnp.int32)])
    # tiles with zero edges are never visited: fold an explicit zero of
    # those tiles into the result afterwards.
    out = segment_sum_kernel(msgs_p, seg_local, eblk_to_vblk, first,
                             n_vblocks=n_vblk, block_e=block_e,
                             block_v=block_v, interpret=interpret)
    visited = jnp.zeros((n_vblk,), bool).at[eblk_to_vblk].set(True)
    out = out.reshape(n_vblk, block_v, d)
    out = jnp.where(visited[:, None, None], out, 0.0)
    out = out.reshape(n_vblk * block_v, d)
    return out[:n_segments] if trim else out


def gather_segment_sum(x, senders, receivers, n_nodes: int, edge_mask=None,
                       block_e: int = DEFAULT_BLOCK_E,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool | None = None):
    """Fused-graph entry point: sorts edges by destination, gathers source
    rows, reduces with the Pallas kernel. Drop-in for
    graph.segment.segment_sum(x[senders], receivers, n_nodes, mask)."""
    E = senders.shape[0]
    seg = jnp.where(edge_mask, receivers, n_nodes) if edge_mask is not None \
        else receivers
    order = jnp.argsort(seg)
    msgs = x[senders[order]]
    return segment_sum_sorted(msgs, seg[order], n_nodes, block_e=block_e,
                              block_v=block_v, interpret=interpret)


# ==================== streaming-tick delivery variants (ISSUE 3 tentpole)

@partial(jax.jit, static_argnames=("n_rows", "mode", "block_e", "block_v",
                                   "interpret"))
def segment_deliver(idx, vec, cnt, n_rows: int, mode: str = "add",
                    block_e: int = DEFAULT_BLOCK_E,
                    block_v: int = DEFAULT_BLOCK_V,
                    interpret: bool | None = None):
    """Fixed-capacity message delivery as ONE sorted segment reduction.

    idx [C] int32 destination rows — rows outside [0, n_rows) are the
    drop sentinel (invalid/padding records, `state.local_index` style);
    vec [C, d] float payload; cnt [C] float scalar count deltas.

    Returns (vec_out [n_rows, d], cnt_out [n_rows], touched [n_rows]):
      mode="add" : per-row sums of vec and cnt (aggregator RMI apply);
      mode="set" : the LAST valid writer's vec/cnt per row (feature
                   delivery; matches XLA scatter-set update order).
    touched[r] is True iff any valid record addressed row r — the
    changed/dirty flag the tick needs, accumulated in the same kernel
    pass (the count column of the packed payload).

    Layout plane (XLA): mask + stable sort by destination, pack
    [vec | cnt | touch] into one [C, d+2] payload. Compute plane
    (Pallas): one `segment_sum_kernel` pass over the packed payload.
    """
    if interpret is None:
        interpret = not _is_tpu()
    C, d = vec.shape
    idx = idx.astype(jnp.int32)
    valid = (idx >= 0) & (idx < n_rows)
    seg = jnp.where(valid, idx, n_rows)
    order = jnp.argsort(seg, stable=True)     # stable: record order per row
    seg_s = seg[order]
    vec_s, cnt_s, val_s = vec[order], cnt[order], valid[order]
    if mode == "set":
        # last-writer-wins: only the final record of each destination run
        # carries payload into the sum (stable sort preserves write order)
        is_last = jnp.concatenate([seg_s[1:] != seg_s[:-1],
                                   jnp.ones((1,), bool)])
        live = val_s & is_last
    elif mode == "add":
        live = val_s
    else:
        raise ValueError(f"segment_deliver mode must be 'add' or 'set', "
                         f"got {mode!r}")
    payload = jnp.concatenate(
        [jnp.where(live[:, None], vec_s, 0.0),
         jnp.where(live, cnt_s, 0.0)[:, None],
         live.astype(vec.dtype)[:, None]], axis=1)
    out = segment_sum_sorted(payload, seg_s, n_rows, block_e=block_e,
                             block_v=block_v, interpret=interpret)
    return out[:, :d], out[:, d], out[:, d + 1] > 0


@partial(jax.jit, static_argnames=("block_r", "interpret"))
def mean_rows(sums, cnts, block_r: int = DEFAULT_BLOCK_R,
              interpret: bool | None = None):
    """Aggregator read at selected rows: sums/cnts with cnt <= 0 rows
    reading ZERO (empty-neighborhood contract of aggregators.mean_read —
    a remove-emptied row must not read its stale sigma residual).

    Pads K up to a block_r multiple (padding counts are 1 so the padded
    rows divide cleanly) and runs the VPU `mean_rows_kernel`."""
    if interpret is None:
        interpret = not _is_tpu()
    K, d = sums.shape
    k_pad = max(block_r, -(-K // block_r) * block_r)
    sums_p = jnp.zeros((k_pad, d), sums.dtype).at[:K].set(sums)
    cnts_p = jnp.ones((k_pad, 1), sums.dtype).at[:K, 0].set(cnts)
    out = mean_rows_kernel(sums_p, cnts_p, block_r=block_r,
                           interpret=interpret)
    return out[:K]


@partial(jax.jit, static_argnames=("block_e", "block_v", "block_r",
                                   "interpret"))
def rmi_apply_read(agg, cnt, idx, vec, dcnt, read_idx,
                   block_e: int = DEFAULT_BLOCK_E,
                   block_v: int = DEFAULT_BLOCK_V,
                   block_r: int = DEFAULT_BLOCK_R,
                   interpret: bool | None = None):
    """Fused RMI-apply + mean read in ONE call (paper §4.2.1 primitive).

    Applies a tick's aggregator RMI records (idx, vec, dcnt) onto the
    (agg [R, d], cnt [R]) synopsis with one `segment_deliver` pass, then
    reads the MEAN synopsis at `read_idx` [K] through `mean_rows` — the
    full [R, d] mean table is never materialized, only the K picked rows.

    The streaming tick itself calls the two halves separately
    (PallasDelivery.deliver_add in apply_rmis, .agg_read_rows in
    forward_psi) because the read rows are only chosen AFTER the dirty
    flags exist; this single-call form is for callers that know their
    read rows up front, and is the tested contract
    (`rmi_apply_read_ref`) both halves are pinned to.

    Returns (agg', cnt', dirty [R] bool, reads [K, d]).
    """
    d_vec, d_cnt, dirty = segment_deliver(
        idx, vec, dcnt, agg.shape[0], mode="add", block_e=block_e,
        block_v=block_v, interpret=interpret)
    agg2, cnt2 = agg + d_vec, cnt + d_cnt
    reads = mean_rows(agg2[read_idx], cnt2[read_idx], block_r=block_r,
                      interpret=interpret)
    return agg2, cnt2, dirty, reads
