"""jit'd wrapper: layout preparation + kernel dispatch.

The layout step (sort by destination, pad so edge blocks never straddle
output tiles) runs in XLA; the scatter-reduction runs in the Pallas kernel
on the MXU. On non-TPU backends `interpret=True` executes the same kernel
body for correctness tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import (DEFAULT_BLOCK_E,
                                                 DEFAULT_BLOCK_V,
                                                 segment_sum_kernel)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_segments", "block_e", "block_v",
                                   "interpret"))
def segment_sum_sorted(msgs, seg_ids, n_segments: int,
                       block_e: int = DEFAULT_BLOCK_E,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool | None = None):
    """Segment-sum of msgs [E, d] by seg_ids [E] (MUST be sorted ascending;
    id >= n_segments = padding). Returns [n_segments_pad, d] — caller slices
    to n_segments.
    """
    if interpret is None:
        interpret = not _is_tpu()
    E, d = msgs.shape
    n_vblk = -(-n_segments // block_v)

    # ---- layout: pad edges so no block spans two output tiles ----------
    vblk_of_edge = jnp.minimum(seg_ids // block_v, n_vblk - 1)
    # within-block capacity: each destination tile's edges padded up to a
    # multiple of block_e by routing them to per-tile padded ranges.
    counts = jnp.zeros((n_vblk,), jnp.int32).at[vblk_of_edge].add(
        jnp.where(seg_ids < n_segments, 1, 0))
    padded_counts = ((counts + block_e - 1) // block_e) * block_e
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(padded_counts)[:-1]])
    # rank of each edge within its tile (seg_ids sorted => stable arange)
    tile_start_edge = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(E, dtype=jnp.int32) - tile_start_edge[vblk_of_edge]
    pos = starts[vblk_of_edge] + rank
    e_cap = E + n_vblk * block_e          # worst-case padded length
    e_cap = ((e_cap + block_e - 1) // block_e) * block_e
    valid = seg_ids < n_segments
    pos = jnp.where(valid, pos, e_cap - 1)  # dump padding at the very end

    msgs_p = jnp.zeros((e_cap, d), msgs.dtype).at[pos].add(
        jnp.where(valid[:, None], msgs, 0.0))
    seg_local = jnp.full((e_cap,), block_v, jnp.int32).at[pos].set(
        jnp.where(valid, seg_ids % block_v, block_v))
    # which output tile each edge block belongs to
    n_eblk = e_cap // block_e
    eblk_starts = jnp.arange(n_eblk, dtype=jnp.int32) * block_e
    cum = jnp.cumsum(padded_counts)
    eblk_to_vblk = jnp.searchsorted(cum, eblk_starts, side="right"
                                    ).astype(jnp.int32)
    eblk_to_vblk = jnp.minimum(eblk_to_vblk, n_vblk - 1)
    first = jnp.concatenate([jnp.ones(1, jnp.int32),
                             (eblk_to_vblk[1:] != eblk_to_vblk[:-1])
                             .astype(jnp.int32)])
    # tiles with zero edges are never visited: fold an explicit zero of
    # those tiles into the result afterwards.
    out = segment_sum_kernel(msgs_p, seg_local, eblk_to_vblk, first,
                             n_vblocks=n_vblk, block_e=block_e,
                             block_v=block_v, interpret=interpret)
    visited = jnp.zeros((n_vblk,), bool).at[eblk_to_vblk].set(True)
    out = out.reshape(n_vblk, block_v, d)
    out = jnp.where(visited[:, None, None], out, 0.0)
    return out.reshape(n_vblk * block_v, d)


def gather_segment_sum(x, senders, receivers, n_nodes: int, edge_mask=None,
                       block_e: int = DEFAULT_BLOCK_E,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool | None = None):
    """Fused-graph entry point: sorts edges by destination, gathers source
    rows, reduces with the Pallas kernel. Drop-in for
    graph.segment.segment_sum(x[senders], receivers, n_nodes, mask)."""
    E = senders.shape[0]
    seg = jnp.where(edge_mask, receivers, n_nodes) if edge_mask is not None \
        else receivers
    order = jnp.argsort(seg)
    msgs = x[senders[order]]
    out = segment_sum_sorted(msgs, seg[order], n_nodes, block_e=block_e,
                             block_v=block_v, interpret=interpret)
    return out[:n_nodes]
