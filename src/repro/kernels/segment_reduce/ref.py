"""Pure-jnp oracle for the segment-reduce kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_segment_sum_ref(x, senders, receivers, n_nodes, edge_mask=None):
    """out[v] = sum_{e: receivers[e]=v} x[senders[e]]  (masked)."""
    msgs = x[senders]
    if edge_mask is not None:
        msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    return jax.ops.segment_sum(msgs, receivers, n_nodes)


def segment_sum_sorted_ref(msgs, seg_ids, n_segments):
    """Plain sorted segment-sum (the layout ops.py feeds the kernel)."""
    return jax.ops.segment_sum(msgs, seg_ids, n_segments)
