"""Pure-jnp oracle for the segment-reduce kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_segment_sum_ref(x, senders, receivers, n_nodes, edge_mask=None):
    """out[v] = sum_{e: receivers[e]=v} x[senders[e]]  (masked)."""
    msgs = x[senders]
    if edge_mask is not None:
        msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    return jax.ops.segment_sum(msgs, receivers, n_nodes)


def segment_sum_sorted_ref(msgs, seg_ids, n_segments):
    """Plain sorted segment-sum (the layout ops.py feeds the kernel)."""
    return jax.ops.segment_sum(msgs, seg_ids, n_segments)


def segment_deliver_ref(idx, vec, cnt, n_rows, mode="add"):
    """Oracle for ops.segment_deliver: plain guarded scatters.

    mode="set" resolves duplicates to the highest record position (the
    last writer) via an unambiguous scatter-max over positions."""
    valid = (idx >= 0) & (idx < n_rows)
    safe = jnp.where(valid, idx, 0)
    if mode == "add":
        vec_out = jnp.zeros((n_rows, vec.shape[1]), vec.dtype).at[safe].add(
            jnp.where(valid[:, None], vec, 0.0))
        cnt_out = jnp.zeros((n_rows,), cnt.dtype).at[safe].add(cnt * valid)
    else:
        pos = jnp.arange(idx.shape[0])
        last = jnp.full((n_rows,), -1).at[safe].max(
            jnp.where(valid, pos, -1))
        win = last >= 0
        take = jnp.maximum(last, 0)
        vec_out = jnp.where(win[:, None], vec[take], 0.0)
        cnt_out = jnp.where(win, cnt[take], 0.0)
    touched = jnp.zeros((n_rows,), bool).at[safe].max(valid)
    return vec_out, cnt_out, touched


def rmi_apply_read_ref(agg, cnt, idx, vec, dcnt, read_idx):
    """Oracle for ops.rmi_apply_read: unfused apply, full mean table."""
    d_vec, d_cnt, dirty = segment_deliver_ref(idx, vec, dcnt, agg.shape[0],
                                              mode="add")
    agg2, cnt2 = agg + d_vec, cnt + d_cnt
    # empty (cnt <= 0) neighborhoods read zeros, not the stale residual
    mean = jnp.where(cnt2[:, None] > 0,
                     agg2 / jnp.maximum(cnt2, 1.0)[:, None], 0.0)
    return agg2, cnt2, dirty, mean[read_idx]
