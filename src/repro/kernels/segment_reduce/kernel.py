"""Pallas TPU kernel: segment-sum of sorted messages via one-hot MXU matmul.

Layout contract (prepared by ops.py):
  * messages [E_pad, d] sorted by destination, padded so that no BLOCK_E
    edge block spans two BLOCK_V output blocks;
  * seg_local [E_pad] — destination index *within* its output block
    (BLOCK_V sentinel = padding row, contributes nothing);
  * eblk_to_vblk [n_eblk] (scalar-prefetch) — which output tile each edge
    block accumulates into (non-decreasing);
  * first_visit [n_eblk] (scalar-prefetch) — 1 where this edge block is the
    first to touch its output tile (zero-initialize then).

Grid is 1-D over edge blocks; the output BlockSpec's index_map reads the
scalar-prefetched eblk_to_vblk, so consecutive grid steps can revisit the
same output tile and accumulate in VMEM (the standard TPU reduction
pattern). The inner op is onehot^T @ msgs — an (BLOCK_V x BLOCK_E) x
(BLOCK_E x d) matmul on the MXU with f32 accumulation.

VMEM budget per step: BLOCK_E*d (msgs) + BLOCK_V*d (out tile) + BLOCK_E
(ids) floats. Defaults BLOCK_E=512, BLOCK_V=256, d<=512 stay well under
16 MB VMEM with MXU-aligned (multiple-of-128) matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_E = 512
DEFAULT_BLOCK_V = 256


def _kernel(eblk_to_vblk, first_visit,      # scalar prefetch
            seg_ref, msg_ref, out_ref, *, block_v: int):
    i = pl.program_id(0)

    @pl.when(first_visit[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                                  # [BLOCK_E]
    msgs = msg_ref[...]                                 # [BLOCK_E, d]
    # one-hot [BLOCK_E, BLOCK_V]; padding rows (seg == block_v) select none
    rows = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_v), 1)
    onehot = (rows == seg[:, None]).astype(msgs.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, msgs, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


DEFAULT_BLOCK_R = 128


@functools.partial(jax.jit, static_argnames=("n_vblocks", "block_e",
                                             "block_v", "interpret"))
def segment_sum_kernel(msgs, seg_local, eblk_to_vblk, first_visit,
                       n_vblocks: int, block_e: int = DEFAULT_BLOCK_E,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool = True):
    """msgs [E_pad, d] (sorted/padded), returns [n_vblocks*block_v, d]."""
    e_pad, d = msgs.shape
    n_eblk = e_pad // block_e
    assert n_eblk * block_e == e_pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_eblk,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, ev, fv: (i,)),
            pl.BlockSpec((block_e, d), lambda i, ev, fv: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, d), lambda i, ev, fv: (ev[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_vblocks * block_v, d), msgs.dtype),
        interpret=interpret,
    )(eblk_to_vblk, first_visit, seg_local, msgs)


def _mean_rows_kernel(sum_ref, cnt_ref, out_ref):
    # counts <= 0 (neighborhood emptied by remove/replace RMIs) read zero,
    # not the stale sigma/1 residual — same contract as
    # core/aggregators.mean_read and ref.rmi_apply_read_ref
    cnt = cnt_ref[...]
    out_ref[...] = jnp.where(cnt > 0,
                             sum_ref[...] / jnp.maximum(cnt, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def mean_rows_kernel(sums, cnts, block_r: int = DEFAULT_BLOCK_R,
                     interpret: bool = True):
    """Row-wise synopsis read: sums [K_pad, d] / max(cnts [K_pad, 1], 1).

    The VPU half of the fused RMI-apply + read: the caller gathers the
    picked aggregator rows and this kernel divides them by their counts,
    so the full [P*N, d] mean table is never materialized. K_pad must be
    a multiple of block_r (ops.py pads; padded counts are 1). The [*, 1]
    count block is lane-sub-tile: fine in interpret mode, padded to the
    (8, 128) f32 tile by Mosaic on real TPUs.
    """
    k_pad, d = sums.shape
    assert k_pad % block_r == 0 and cnts.shape == (k_pad, 1)
    return pl.pallas_call(
        _mean_rows_kernel,
        grid=(k_pad // block_r,),
        in_specs=[pl.BlockSpec((block_r, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_r, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, d), sums.dtype),
        interpret=interpret,
    )(sums, cnts)
