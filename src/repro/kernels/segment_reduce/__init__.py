from repro.kernels.segment_reduce.ops import (  # noqa: F401
    gather_segment_sum, mean_rows, rmi_apply_read, segment_deliver,
    segment_sum_sorted)
