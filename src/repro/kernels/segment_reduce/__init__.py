from repro.kernels.segment_reduce.ops import segment_sum_sorted, gather_segment_sum  # noqa: F401
