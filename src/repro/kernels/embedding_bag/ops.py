"""jit'd wrapper: gather + weight/mask prep + kernel dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import (DEFAULT_BLOCK_B,
                                                embedding_bag_kernel)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("mode", "block_b", "interpret"))
def embedding_bag(table, ids, mode: str = "mean",
                  block_b: int = DEFAULT_BLOCK_B,
                  interpret: bool | None = None):
    """table: [V, d]; ids: [B, W] int32, -1 = padding. Returns [B, d]."""
    if interpret is None:
        interpret = not _is_tpu()
    B, W = ids.shape
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    rows = jnp.take(table, safe.reshape(-1), axis=0)       # [B*W, d]
    if mode == "sum":
        w = valid.astype(table.dtype)
    elif mode == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        w = (valid / cnt).astype(table.dtype)
    else:
        raise ValueError(mode)
    return embedding_bag_kernel(rows, w.reshape(-1), width=W,
                                block_b=block_b, interpret=interpret)
