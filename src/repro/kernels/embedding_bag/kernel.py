"""Pallas TPU kernel: bag-reduce (sum/mean) of gathered embedding rows.

EmbeddingBag = ragged gather over a [V, d] table + per-bag reduce. The
gather half is XLA's native strength on TPU (dynamic-gather HBM streams);
the fusion win is the reduce half: instead of materializing [B, W, d]
gathered rows and reducing in a second pass, the kernel consumes gathered
rows tile-by-tile and reduces them into [B, d] bags in VMEM via a one-hot
MXU matmul (B rows per tile x W slots).

Layout contract (ops.py): rows arrive as [B*W, d] where bag b owns rows
[b*W, (b+1)*W); a weights vector [B*W] carries the padding mask (0 for
padded ids) and 1/count for mean mode — so sum and mean are one kernel.

Grid: (n_bag_blocks,), each step consuming (BLOCK_B * W, d) rows and
writing a (BLOCK_B, d) output tile. VMEM: BLOCK_B*W*d + BLOCK_B*d floats;
BLOCK_B=64, W=8, d=256 ≈ 0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 64


def _kernel(rows_ref, w_ref, out_ref, *, width: int, block_b: int):
    rows = rows_ref[...]                          # [block_b*W, d]
    w = w_ref[...]                                # [block_b*W]
    # selector [block_b*W, block_b]: row r belongs to bag r // W
    bag_of = jax.lax.broadcasted_iota(jnp.int32, (block_b * width, block_b), 0
                                      ) // width
    bag_id = jax.lax.broadcasted_iota(jnp.int32, (block_b * width, block_b), 1)
    sel = (bag_of == bag_id).astype(rows.dtype) * w[:, None].astype(rows.dtype)
    out_ref[...] = jax.lax.dot_general(
        sel, rows, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("width", "block_b", "interpret"))
def embedding_bag_kernel(rows, weights, width: int,
                         block_b: int = DEFAULT_BLOCK_B,
                         interpret: bool = True):
    """rows: [B*W, d] gathered table rows; weights: [B*W] per-row weight.
    Returns [B, d] reduced bags."""
    BW, d = rows.shape
    B = BW // width
    assert B * width == BW
    block_b = min(block_b, B)
    nb = B // block_b
    assert nb * block_b == B, (B, block_b)

    kern = functools.partial(_kernel, width=width, block_b=block_b)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b * width, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b * width,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), rows.dtype),
        interpret=interpret,
    )(rows, weights)
