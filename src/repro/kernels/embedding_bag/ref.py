"""Pure-jnp oracle for the embedding-bag kernel (and the torch
nn.EmbeddingBag semantics it mirrors)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.recsys.embedding_bag import embedding_bag_lookup


def embedding_bag_ref(table, ids, mode: str = "mean"):
    """ids: [B, W] with -1 padding -> [B, d]."""
    return embedding_bag_lookup(table, ids, mode)
