"""Consistent-cut checkpointing (paper §3.2 / §5.1).

Flink uses Chandy-Lamport barrier snapshots that must capture in-flight
iteration-queue events. In the micro-tick engine a tick boundary IS a
consistent cut: all channels are empty between ticks, and what the paper
stores as "in-queue messages" lives in the window-pending state
(red_pending/fwd_pending + deadlines) — so checkpointing the operator
states between ticks captures exactly the same information.

Format: one compressed msgpack blob per checkpoint with raw ndarray
buffers (no pickle — restore-safe), plus host-side partitioner tables.
Compression is zstd when the `zstandard` package is available, else
stdlib zlib; a one-byte codec tag prefixes every blob so either build
restores checkpoints written by the other. Writes go to <step>.tmp then
atomic-rename, so a crash mid-write never corrupts the latest
checkpoint. Async mode hands serialization to a background thread (the
paper's non-blocking snapshots).
"""
from __future__ import annotations

import json
import threading
import warnings
import zlib
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                    # optional: zstd when installed
    import zstandard
except ImportError:                     # clean env: stdlib fallback
    zstandard = None

# codec tags (format header): every blob starts with one of these bytes.
# \x01/\x02 are the legacy CRC-less formats (restore-only); since ISSUE 10
# writes use \x03/\x04 = tag + CRC32(compressed payload, 4 bytes LE) +
# payload, so a truncated or bit-flipped .ckpt fails loudly at the header
# instead of surfacing a deep zlib/msgpack error.
_CODEC_ZSTD = b"\x01"
_CODEC_ZLIB = b"\x02"
_CODEC_ZSTD_CRC = b"\x03"
_CODEC_ZLIB_CRC = b"\x04"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint blob failed its integrity check (CRC mismatch,
    truncation, or undecodable payload). `CheckpointManager.restore`
    raises it annotated with step + path; step=None restores fall back to
    the previous kept generation with a warning."""


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        tag = _CODEC_ZSTD_CRC
        body = zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        tag = _CODEC_ZLIB_CRC
        body = zlib.compress(raw, 6)
    return tag + (zlib.crc32(body) & 0xFFFFFFFF).to_bytes(4, "little") + body


def _decompress(blob: bytes) -> bytes:
    tag = blob[:1]
    if tag in (_CODEC_ZSTD_CRC, _CODEC_ZLIB_CRC):
        if len(blob) < 5:
            raise CheckpointCorruptError(
                "truncated checkpoint: blob ends inside the CRC header")
        want = int.from_bytes(blob[1:5], "little")
        body = blob[5:]
        got = zlib.crc32(body) & 0xFFFFFFFF
        if got != want:
            raise CheckpointCorruptError(
                f"payload CRC mismatch (stored {want:#010x}, computed "
                f"{got:#010x}) — the blob is truncated or bit-flipped")
        if tag == _CODEC_ZSTD_CRC:
            if zstandard is None:
                raise RuntimeError("checkpoint is zstd-compressed but the "
                                   "'zstandard' package is not installed")
            return zstandard.ZstdDecompressor().decompress(body)
        return zlib.decompress(body)
    if tag == _CODEC_ZSTD:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "'zstandard' package is not installed")
        return zstandard.ZstdDecompressor().decompress(blob[1:])
    if tag == _CODEC_ZLIB:
        return zlib.decompress(blob[1:])
    if blob[:4] == b"\x28\xb5\x2f\xfd":
        # legacy checkpoint from before the codec tag: a bare zstd frame
        if zstandard is None:
            raise RuntimeError("legacy zstd checkpoint needs the "
                               "'zstandard' package to restore")
        return zstandard.ZstdDecompressor().decompress(blob)
    raise CheckpointCorruptError(f"unknown checkpoint codec tag {tag!r}")


def _pack_tree(tree) -> bytes:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype), "shape": list(np.asarray(l).shape),
             "data": np.ascontiguousarray(np.asarray(l)).tobytes()}
            for l in leaves
        ],
    }
    return _compress(msgpack.packb(payload, use_bin_type=True))


def _unpack_leaves(blob: bytes):
    payload = msgpack.unpackb(_decompress(blob), raw=False)
    # .copy(): frombuffer views are read-only; host tables are mutated live
    return [np.frombuffer(l["data"], dtype=np.dtype(l["dtype"])).reshape(
        l["shape"]).copy() for l in payload["leaves"]]


@dataclass
class CheckpointInfo:
    step: int
    path: Path


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: list[threading.Thread] = []

    # ------------------------------------------------------------ generic
    def save(self, step: int, tree, meta: dict | None = None,
             aux: dict | None = None):
        """Checkpoint any pytree (params, optimizer state, engine states).

        `aux` is a flat {name: ndarray} dict of variable-shape host tables
        restored as-is (no template check)."""
        tree = jax.tree.map(np.asarray, tree)   # device -> host snapshot NOW
        aux = None if aux is None else {k: np.asarray(v)
                                        for k, v in aux.items()}

        def _write():
            blob = _pack_tree(tree)
            tmp = self.dir / f"{step:010d}.ckpt.tmp"
            final = self.dir / f"{step:010d}.ckpt"
            tmp.write_bytes(blob)
            if aux is not None:
                names = sorted(aux)
                (self.dir / f"{step:010d}.aux").write_bytes(
                    _pack_tree([aux[k] for k in names]))
                (self.dir / f"{step:010d}.auxnames.json").write_text(
                    json.dumps(names))
            if meta is not None:
                (self.dir / f"{step:010d}.meta.json").write_text(
                    json.dumps(meta))
            tmp.rename(final)
            self._gc()

        if self.async_write:
            t = threading.Thread(target=_write, daemon=True)
            t.start()
            self._pending.append(t)
        else:
            _write()

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _load_leaves(self, info: CheckpointInfo):
        """Decode one blob; any integrity failure surfaces as a
        CheckpointCorruptError carrying step + path."""
        try:
            return _unpack_leaves(info.path.read_bytes())
        except CheckpointCorruptError as e:
            raise CheckpointCorruptError(
                f"corrupt checkpoint at step {info.step} "
                f"({info.path}): {e}") from e
        except Exception as e:   # zlib.error / msgpack / struct depths
            raise CheckpointCorruptError(
                f"corrupt checkpoint at step {info.step} ({info.path}): "
                f"{type(e).__name__}: {e}") from e

    def checkpoints(self) -> list[CheckpointInfo]:
        return [CheckpointInfo(int(p.stem.split(".")[0]), p)
                for p in sorted(self.dir.glob("*.ckpt"))]

    def restore(self, template, step: int | None = None):
        """Restore into the structure of `template` (shape/dtype checked).

        step=None restores the newest checkpoint; if its blob fails the
        integrity check the restore FALLS BACK to the previous kept
        generation (newest -> oldest) with a warning — a torn write never
        strands recovery while an older consistent cut exists. An
        explicit step raises CheckpointCorruptError instead."""
        infos = ([CheckpointInfo(step, self.dir / f"{step:010d}.ckpt")]
                 if step is not None else list(reversed(self.checkpoints())))
        if not infos:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        errors: list[CheckpointCorruptError] = []
        for info in infos:
            try:
                leaves = self._load_leaves(info)
            except CheckpointCorruptError as e:
                if step is not None:
                    raise
                errors.append(e)
                warnings.warn(f"{e} — falling back to the previous kept "
                              "generation")
                continue
            t_leaves, treedef = jax.tree.flatten(template)
            assert len(leaves) == len(t_leaves), \
                f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}"
            out = []
            for got, want in zip(leaves, t_leaves):
                w = np.asarray(want)
                assert tuple(got.shape) == tuple(w.shape), (got.shape, w.shape)
                out.append(jnp.asarray(got.astype(w.dtype)))
            return jax.tree.unflatten(treedef, out), info.step
        raise errors[0]

    def restore_aux(self, step: int | None = None) -> dict:
        info = self.latest() if step is None else CheckpointInfo(
            step, self.dir / f"{step:010d}.ckpt")
        names = json.loads(
            (self.dir / f"{info.step:010d}.auxnames.json").read_text())
        leaves = _unpack_leaves(
            (self.dir / f"{info.step:010d}.aux").read_bytes())
        return dict(zip(names, leaves))

    def latest(self) -> CheckpointInfo | None:
        ckpts = sorted(self.dir.glob("*.ckpt"))
        if not ckpts:
            return None
        p = ckpts[-1]
        return CheckpointInfo(int(p.stem.split(".")[0]), p)

    def _gc(self):
        ckpts = sorted(self.dir.glob("*.ckpt"))
        for p in ckpts[: -self.keep]:
            p.unlink(missing_ok=True)
            meta = p.with_suffix("").with_suffix(".meta.json")
            meta.unlink(missing_ok=True)

    # ----------------------------------------------------------- pipeline
    def save_pipeline(self, step: int, pipe):
        """Full engine snapshot: device state + host partitioner tables +
        metrics. Window-pending state (the in-flight events) is inside
        LayerState and held point queries live in the QueryState table,
        so this IS the Chandy-Lamport-equivalent cut — a restored carry
        answers pending `consistent` queries identically."""
        t = pipe.part.t
        aux = {
            "degree": t.degree, "replicas": t.replicas, "load": t.load,
            "master": t.master, "master_slot": t.master_slot,
            "next_vslot": t.next_vslot, "next_eslot": t.next_eslot,
            "repl_counters": pipe.part._repl_counters,
            "slot_keys": np.asarray([[p, v] for (p, v) in t.slot_of],
                                    np.int64).reshape(-1, 2),
            "slot_vals": np.asarray(list(t.slot_of.values()), np.int64),
            "now": np.asarray(pipe.now),
        }
        tree = {"topo": pipe.topo, "layers": pipe.states, "sink": pipe.sink,
                "sink_seen": pipe.sink_seen, "queries": pipe.queries,
                "params": pipe.params,
                # hybrid-parallel pipelines DO have a non-empty channel at
                # the tick cut: the inter-stage ring's in-flight rows ride
                # the snapshot (None on a 1-D mesh — zero leaves)
                "stage_ring": getattr(pipe, "stage_ring", None),
                # training-plane state (labels/dirty window, live params,
                # optimizer + error-feedback residuals) is part of the
                # consistent cut; None when cfg.train_cap == 0
                "train": getattr(pipe, "train_state", None)}
        self.save(step, tree, meta={"now": pipe.now}, aux=aux)

    def restore_pipeline(self, pipe, step: int | None = None) -> int:
        template = {"topo": pipe.topo, "layers": pipe.states,
                    "sink": pipe.sink, "sink_seen": pipe.sink_seen,
                    "queries": pipe.queries, "params": pipe.params,
                    "stage_ring": getattr(pipe, "stage_ring", None),
                    "train": getattr(pipe, "train_state", None)}
        tree, got_step = self.restore(template, step)
        pipe.topo = tree["topo"]
        pipe.states = tree["layers"]
        pipe.sink = tree["sink"]
        pipe.sink_seen = tree["sink_seen"]
        pipe.queries = tree["queries"]
        pipe.params = tree["params"]
        if tree.get("stage_ring") is not None:
            pipe.stage_ring = tree["stage_ring"]
        if tree.get("train") is not None:
            pipe.train_state = tree["train"]
            if hasattr(pipe, "_sync_params_from_train"):
                pipe._sync_params_from_train()
        h = self.restore_aux(got_step)
        t = pipe.part.t
        t.degree = np.asarray(h["degree"])
        t.replicas = np.asarray(h["replicas"])
        t.load = np.asarray(h["load"])
        t.master = np.asarray(h["master"])
        t.master_slot = np.asarray(h["master_slot"])
        t.next_vslot = np.asarray(h["next_vslot"])
        t.next_eslot = np.asarray(h["next_eslot"])
        pipe.part._repl_counters = np.asarray(h["repl_counters"])
        keys = np.asarray(h["slot_keys"]).reshape(-1, 2)
        vals = np.asarray(h["slot_vals"])
        t.slot_of = {(int(p), int(v)): int(s)
                     for (p, v), s in zip(keys, vals)}
        pipe.now = int(np.asarray(h["now"]))
        return got_step
