"""Elastic re-scaling of physical sub-operators (paper §4.4.2).

Logical parts are fixed at max_parallelism; the physical placement of a
logical part under `parallelism` is Algorithm 5. A re-scale (node failure,
scale-up) therefore never re-partitions the graph — Keyed State moves with
its logical part to the new physical owner; Alg. 5's fixed mapping makes
recovery deterministic.

In the mesh runtime, "physical sub-operator" = mesh shard: re-scaling is a
re-sharding of the [P_logical, ...] state arrays onto a different number of
data-axis shards. On one host this is a pure relayout (the arrays are
already keyed by logical part); the function below verifies the invariants
and produces the shard assignment + per-shard state views used by the
launcher and the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.explosion import physical_part


@dataclass
class RescalePlan:
    old_parallelism: int
    new_parallelism: int
    max_parallelism: int
    moves: list          # (logical_part, old_phys, new_phys)

    @property
    def moved_fraction(self) -> float:
        return len(self.moves) / self.max_parallelism


def rescale_parts(old_parallelism: int, new_parallelism: int,
                  max_parallelism: int) -> RescalePlan:
    logical = np.arange(max_parallelism)
    old = physical_part(logical, old_parallelism, max_parallelism)
    new = physical_part(logical, new_parallelism, max_parallelism)
    moves = [(int(l), int(o), int(n))
             for l, o, n in zip(logical, old, new) if o != n]
    return RescalePlan(old_parallelism, new_parallelism, max_parallelism,
                       moves)


def shard_views(state_leading_parts: int, parallelism: int,
                max_parallelism: int):
    """Which logical parts each physical sub-operator owns."""
    assert state_leading_parts == max_parallelism
    phys = physical_part(np.arange(max_parallelism), parallelism,
                         max_parallelism)
    return [np.nonzero(phys == p)[0] for p in range(parallelism)]


def simulate_failure_and_recover(pipe, ckpt_mgr, step: int,
                                 new_parallelism: int):
    """Fail-stop drill: restore the latest checkpoint into a fresh pipeline
    and re-map logical parts onto `new_parallelism` sub-operators. Returns
    (restored_step, RescalePlan). The engine state arrays are keyed by
    logical part, so no graph data is touched — exactly the paper's claim.
    """
    restored = ckpt_mgr.restore_pipeline(pipe, step)
    plan = rescale_parts(pipe.cfg.base_parallelism, new_parallelism,
                         pipe.cfg.n_parts)
    pipe.cfg.base_parallelism = new_parallelism
    return restored, plan
