"""Elastic re-scaling of physical sub-operators (paper §4.4.2).

Logical parts are fixed at max_parallelism; the physical placement of a
logical part under `parallelism` is Algorithm 5. A re-scale (node failure,
scale-up) therefore never re-partitions the graph — Keyed State moves with
its logical part to the new physical owner; Alg. 5's fixed mapping makes
recovery deterministic.

In the mesh runtime, "physical sub-operator" = mesh shard: re-scaling is a
re-sharding of the [P_logical, ...] state arrays onto a different number of
data-axis shards. Since ISSUE 10 this is LIVE: `D3Pipeline.reshard(mesh)`
relays the whole carry — layer tables, defer rings, the inter-stage ring,
QueryState, TrainState — onto the new mesh with `jax.device_put` (no host
round-trip per array) using the helpers below to re-block the three packed
row buffers whose LAYOUT (not content) is device-count dependent:

  * defer rings are [D*K, W] row-compacted FIFOs whose rows are
    DESTINATION-addressed (the router recomputes dst = part // p_loc at
    exchange time), so under a new D they only need compacting into the
    new global capacity (`repack_defer_ring`);
  * the inter-stage ring's [D*C, W] slabs hold rows already routed to
    their owning data shard — delivery drops rows outside the local part
    block — so rows must be re-blocked by part ownership under the new
    p_loc (`repack_stage_slab`).

`simulate_failure_and_recover` is now a thin wrapper over
checkpoint-restore + `reshard`; it returns the NEW validated
PipelineConfig instead of mutating the caller's config in place.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from repro.core.explosion import physical_part


@dataclass
class RescalePlan:
    old_parallelism: int
    new_parallelism: int
    max_parallelism: int
    moves: list          # (logical_part, old_phys, new_phys)

    @property
    def moved_fraction(self) -> float:
        return len(self.moves) / self.max_parallelism


def rescale_parts(old_parallelism: int, new_parallelism: int,
                  max_parallelism: int) -> RescalePlan:
    logical = np.arange(max_parallelism)
    old = physical_part(logical, old_parallelism, max_parallelism)
    new = physical_part(logical, new_parallelism, max_parallelism)
    moves = [(int(l), int(o), int(n))
             for l, o, n in zip(logical, old, new) if o != n]
    return RescalePlan(old_parallelism, new_parallelism, max_parallelism,
                       moves)


def shard_views(state_leading_parts: int, parallelism: int,
                max_parallelism: int):
    """Which logical parts each physical sub-operator owns."""
    assert state_leading_parts == max_parallelism
    phys = physical_part(np.arange(max_parallelism), parallelism,
                         max_parallelism)
    return [np.nonzero(phys == p)[0] for p in range(parallelism)]


# ------------------------------------------------- packed-row re-blocking
def repack_defer_ring(rows, ok, new_rows: int):
    """Re-capacity a [K, W] defer ring to [new_rows, W].

    Valid rows compact to the front with a STABLE sort (FIFO order — and
    therefore delivery order after the reshard — is preserved), then the
    buffer is padded or truncated to the new global capacity. Returns
    (rows', ok', n_lost) where n_lost counts valid rows that did not fit
    (the caller raises — a reshard must never silently drop in-flight
    work)."""
    order = jnp.argsort(~ok, stable=True)
    rows_s, ok_s = rows[order], ok[order]
    k, w = rows_s.shape
    if new_rows >= k:
        pad = new_rows - k
        return (jnp.concatenate(
                    [rows_s, jnp.zeros((pad, w), rows_s.dtype)]),
                jnp.concatenate([ok_s, jnp.zeros((pad,), bool)]),
                jnp.zeros((), jnp.int32))
    lost = jnp.sum(ok_s[new_rows:].astype(jnp.int32))
    return rows_s[:new_rows], ok_s[:new_rows], lost


def repack_stage_slab(rows, part_col: int, valid_col: int,
                      p_loc_new: int, d_new: int, cap_new: int):
    """Re-block one inter-stage ring slab [K, W] -> [d_new * cap_new, W].

    Ring rows are consumed through the drop-sentinel delivery index, which
    silently ignores rows sitting outside their owner's part block — so
    after a reshard every valid row must live in the block of the data
    shard that owns its part under the NEW p_loc. Row order within a block
    is irrelevant (ring rows deliver to unique (part, slot) targets).
    Returns (slab', n_lost) with n_lost the valid rows that overflowed a
    block (cannot happen for capacities derived from the same config —
    kept as a loud invariant)."""
    valid = rows[:, valid_col] > 0.5
    part = rows[:, part_col].astype(jnp.int32)
    dst = jnp.where(valid, part // jnp.int32(p_loc_new), d_new)
    order = jnp.argsort(dst, stable=True)
    rows_s, dst_s = rows[order], dst[order]
    # rank of each row within its destination run of the sorted array
    starts = jnp.searchsorted(dst_s, jnp.arange(d_new + 1))
    rank = jnp.arange(dst_s.shape[0]) - starts[jnp.clip(dst_s, 0, d_new)]
    in_cap = (dst_s < d_new) & (rank < cap_new)
    slot = jnp.where(in_cap, dst_s * cap_new + rank, d_new * cap_new)
    out = jnp.zeros((d_new * cap_new + 1, rows.shape[1]), rows.dtype)
    out = out.at[slot].set(jnp.where(in_cap[:, None], rows_s, 0.0))
    lost = jnp.sum(((dst_s < d_new) & ~in_cap).astype(jnp.int32))
    return out[:-1], lost


def simulate_failure_and_recover(pipe, ckpt_mgr, step: int,
                                 new_parallelism: int, new_mesh=None):
    """Fail-stop drill: restore the checkpoint into `pipe`, then LIVE
    reshard the recovered carry onto the survivor mesh. Returns
    (restored_step, RescalePlan, new_cfg).

    The engine state arrays are keyed by logical part, so no graph data
    is touched — exactly the paper's claim. `new_mesh=None` on a meshed
    pipeline builds a `make_stream_mesh(new_parallelism * S, stage=S)`
    survivor grid; on a local pipeline it re-validates the config at the
    new parallelism without moving anything. The caller's config object
    is never mutated — the new validated `PipelineConfig` is installed on
    the pipeline and returned."""
    restored = ckpt_mgr.restore_pipeline(pipe, step)
    plan = rescale_parts(pipe.cfg.base_parallelism, new_parallelism,
                         pipe.cfg.n_parts)
    if new_mesh is None and pipe.mesh is not None:
        from repro.launch.mesh import make_stream_mesh
        new_mesh = make_stream_mesh(new_parallelism * pipe.n_stages,
                                    stage=pipe.n_stages)
    new_cfg = replace(pipe.cfg, base_parallelism=new_parallelism)
    pipe.reshard(new_mesh, cfg=new_cfg)
    return restored, plan, pipe.cfg
