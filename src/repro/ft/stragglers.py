"""Straggler mitigation for the synchronous tick loop.

Live-wired since ISSUE 9: when the telemetry plane is on
(`PipelineConfig.telemetry=True`) both pipeline drivers feed
`StragglerMitigator.observe_tick` every launch — the per-tick wall
time (super-tick wall / T on the scan driver) plus the per-shard busy
proxies folded from `TickStats.busy` — via
`D3Pipeline._trace_ticks`; `D3Pipeline.parts_per_shard()` supplies
the work-steal planner's part map. Before that the class was only
exercised by unit tests.

On a real pod a straggling host slows every lock-step collective. The
standard mitigations this module provides:

  * tick-deadline detection: an EWMA of tick wall-times flags ticks (and,
    with per-shard busy proxies, the shards) that exceed k x the EWMA;
  * work-stealing re-map: persistent stragglers get logical parts moved to
    the fastest shards via an Alg. 5-compatible override table (the same
    keyed-state movement as elastic rescale — no graph re-partitioning);
  * backup-task semantics for the host-side partitioner chunks (speculative
    re-execution after a timeout) — the classic MapReduce trick, applicable
    because chunk ingestion is idempotent (slots are allocated once; a
    replayed chunk hits the slot_of table and produces identical rows).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerMitigator:
    n_shards: int
    ewma_alpha: float = 0.2
    threshold: float = 2.0            # x EWMA flags a straggler
    patience: int = 3                 # consecutive flags before re-map
    _ewma: float = 0.0
    _flags: np.ndarray = field(default=None)
    overrides: dict = field(default_factory=dict)   # logical part -> shard
    ticks_observed: int = 0           # observe_tick feed counter — lets
                                      # tests/telemetry assert the drivers
                                      # actually wire the mitigator in

    def __post_init__(self):
        if self._flags is None:
            self._flags = np.zeros(self.n_shards, np.int64)

    def observe_tick(self, wall_s: float, busy_per_shard: np.ndarray):
        """Feed one tick; returns list of shards flagged this tick.

        Flagged (slow) ticks do NOT update the EWMA baseline — otherwise a
        persistent straggler would poison its own detection threshold."""
        self.ticks_observed += 1
        busy_per_shard = np.asarray(busy_per_shard)
        flagged = []
        if self._ewma and wall_s > self.threshold * self._ewma \
                and busy_per_shard.sum() > 0:
            # attribute the slowdown to the busiest shard(s)
            worst = int(np.argmax(busy_per_shard))
            self._flags[worst] += 1
            flagged.append(worst)
        else:
            self._flags[:] = np.maximum(self._flags - 1, 0)
            self._ewma = (wall_s if self._ewma == 0.0 else
                          (1 - self.ewma_alpha) * self._ewma
                          + self.ewma_alpha * wall_s)
        return flagged

    def persistent_stragglers(self) -> list[int]:
        return [int(s) for s in np.nonzero(self._flags >= self.patience)[0]]

    def plan_work_steal(self, parts_per_shard: list[np.ndarray],
                        busy_per_shard: np.ndarray) -> dict:
        """Move half the straggler's logical parts to the least-busy shard.

        Returns {logical_part: new_shard} merged into self.overrides; the
        engine applies it as a routing override on top of Alg. 5 (keyed
        state moves with the part, same as rescale)."""
        stealers = np.argsort(busy_per_shard)
        for s in self.persistent_stragglers():
            victim_parts = parts_per_shard[s]
            give = victim_parts[: max(1, len(victim_parts) // 2)]
            target = int(stealers[0]) if int(stealers[0]) != s else int(
                stealers[1]) if len(stealers) > 1 else s
            for lp in give:
                self.overrides[int(lp)] = target
            self._flags[s] = 0
        return dict(self.overrides)


def speculative_chunks(chunk_ids: list[int], started_s: dict,
                       now_s: float, timeout_s: float) -> list[int]:
    """Backup-task planner for partitioner chunks: re-issue chunks that
    have been running longer than `timeout_s` (idempotent re-execution)."""
    return [c for c in chunk_ids
            if c in started_s and now_s - started_s[c] > timeout_s]
