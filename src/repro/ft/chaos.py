"""Chaos plane (ISSUE 10): deterministic fault injection for the tick.

Nothing in a recovery path counts until something can *cause* the
failure: this module injects the four faults the engine claims to
survive, each as a seeded, wall-clock-free program (a fixed event
stream + a tick-indexed fault schedule) so the full recovery matrix
runs in CI rather than by hand:

  * **fail-stop shard loss** (`scenario_failstop`): mid-stream, the
    pipeline "loses" data shards — recovery is checkpoint-restore +
    `D3Pipeline.reshard` onto the survivor mesh
    (`launch.mesh.survivor_mesh`), replaying the chunks since the last
    consistent cut (chunk ingestion is idempotent: the restored
    partitioner tables make the replay bit-identical). Held
    `consistent` queries ride the checkpointed QueryState and answer
    after recovery; the sink at quiescence is bit-equal to the
    uninterrupted run's.
  * **checkpoint-write truncation** (`scenario_truncated_checkpoint`):
    the newest .ckpt is torn mid-blob; restore must fail loudly
    (`CheckpointCorruptError` with step + path) and fall back to the
    previous kept generation.
  * **fail-slow shard** (`scenario_slow_shard`): a deterministic
    synthetic wall-time schedule drives `ft/stragglers.py` exactly the
    way the telemetry plane does live; once the flag turns persistent,
    `D3Pipeline.mitigate_stragglers()` consumes it end-to-end — a live
    reshard onto the surviving shards re-maps `parts_per_shard()` so
    the slow shard owns nothing.
  * **admission storm** (`scenario_admission_storm`): a query burst far
    beyond the per-tick admission budget; the ServeSession degrades
    observably (shed + bounded retry counters) instead of stalling or
    silently dropping.

Every scenario returns a plain report dict asserted by
`tests/test_chaos.py`; `SCENARIOS` is the CI matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.ft.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh, survivor_mesh
from repro.serve.session import ServeSession


@dataclass
class ChaosConfig:
    """Deterministic chaos schedule: everything is keyed to the seeded
    event stream and chunk indices — no wall clock anywhere, so every
    scenario replays bit-identically."""
    seed: int = 0
    n_vertices: int = 48
    n_events: int = 288
    d_in: int = 8
    n_hubs: int = 3
    hub_fraction: float = 0.3        # steady-state hub traffic share
    spike_fraction: float = 0.75     # hub share during the traffic spike
    spike_from: float = 0.5          # spike starts at this stream fraction
    tick_edges: int = 16             # events per chunk (one tick each)
    n_parts: int = 4
    node_cap: int = 64
    query_cap: int = 8
    driver: str = "tick"             # "tick" | "super"
    # fault schedule (chunk-indexed)
    fail_at_chunk: int = 10          # fail-stop strikes BEFORE this chunk
                                     # (NOT on a cut: chunks since the
                                     # last checkpoint must replay)
    lose_shards: tuple = (1, 3)      # data-shard indices lost
    checkpoint_every: int = 3        # consistent cut cadence (chunks)
    slow_shard: int = 1              # fail-slow target
    slow_factor: float = 8.0         # injected wall multiple when slow
    storm_queries: int = 96          # admission-storm burst size
    reserved: int = 4                # vertex ids the stream NEVER emits —
                                     # late-materializing endpoints for
                                     # the retry path
    route_cap: int | None = None     # None keeps runs bit-equal across D


def hub_heavy_stream(cfg: ChaosConfig):
    """Seeded hub-heavy event stream with a mid-stream traffic spike:
    returns (edges [n,2] int64, feats {vid: [d_in] f32}, hubs). The top
    `cfg.reserved` vertex ids never appear — scenarios introduce them
    late to exercise endpoint-not-yet-materialized answers."""
    rng = np.random.default_rng(cfg.seed)
    active = cfg.n_vertices - cfg.reserved
    hubs = rng.choice(active, size=cfg.n_hubs, replace=False)
    n = cfg.n_events
    frac = np.where(np.arange(n) < cfg.spike_from * n,
                    cfg.hub_fraction, cfg.spike_fraction)
    src = rng.integers(0, active, n)
    dst = np.where(rng.random(n) < frac,
                   hubs[rng.integers(0, len(hubs), n)],
                   rng.integers(0, active, n))
    edges = np.stack([src, dst], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    feats = {v: rng.normal(size=cfg.d_in).astype(np.float32)
             for v in range(cfg.n_vertices)}
    return edges, feats, hubs


def _chunks(cfg: ChaosConfig, edges):
    return [edges[i:i + cfg.tick_edges]
            for i in range(0, len(edges), cfg.tick_edges)]


def _feat_rows(chunk, feats):
    return [(int(v), feats[int(v)]) for e in chunk for v in set(map(int, e))]


def build_pipeline(cfg: ChaosConfig, mesh=None, n_stages: int = 1,
                   telemetry: bool = False) -> D3Pipeline:
    model = GraphSAGE((cfg.d_in, cfg.d_in, cfg.d_in))
    params = model.init(jax.random.key(cfg.seed))
    pcfg = PipelineConfig(
        n_parts=cfg.n_parts, node_cap=cfg.node_cap, edge_cap=256,
        repl_cap=256, feat_cap=256, edge_tick_cap=2 * cfg.tick_edges,
        max_nodes=cfg.n_vertices, query_cap=cfg.query_cap,
        n_stages=n_stages, route_cap=cfg.route_cap, telemetry=telemetry,
        window=win.WindowConfig(kind=win.SESSION, interval=3))
    return D3Pipeline(model, params, pcfg, mesh=mesh)


def _advance(session: ServeSession, chunk, feats):
    rows = _feat_rows(chunk, feats) if len(chunk) else None
    ed = chunk if len(chunk) else None
    if session.driver == "tick":
        session.advance(ed, rows)
    else:
        session.advance_super([ed] if ed is not None else None,
                              [rows] if rows is not None else None, T=1)


# ------------------------------------------------------------- scenarios
def scenario_failstop(cfg: ChaosConfig, ckpt_dir, d_old: int = 4,
                      d_new: int = 2, n_stages: int = 1) -> dict:
    """Hub-heavy spike + fail-stop shard loss mid-stream.

    Oracle first: the SAME stream, queries, and driver, uninterrupted on
    the d_old grid. Then the chaos run: consistent-cut checkpoints every
    `checkpoint_every` chunks; before chunk `fail_at_chunk` the shards in
    `lose_shards` fail-stop — the session degrades, the last checkpoint
    restores, the carry reshards onto the survivor mesh, the chunks since
    the cut REPLAY, and the stream resumes. Returns both runs' sinks,
    answers, and drop counters for the test to compare bit-exactly."""
    edges, feats, hubs = hub_heavy_stream(cfg)
    chunks = _chunks(cfg, edges)
    fail_at = min(cfg.fail_at_chunk, len(chunks) - 1)
    # consistent queries submitted right before the cut preceding the
    # failure: held on device, checkpointed, restored, answered after
    # recovery
    cut = (fail_at // cfg.checkpoint_every) * cfg.checkpoint_every
    q_vids = [int(h) for h in hubs]

    def _run(mesh_fn, fail: bool):
        pipe = build_pipeline(cfg, mesh_fn(), n_stages=n_stages)
        session = ServeSession(pipe, driver=cfg.driver, max_retries=2)
        mgr = (CheckpointManager(Path(ckpt_dir) / "chaos", keep=3)
               if fail else None)
        qids = None
        restored_step = None
        for i, chunk in enumerate(chunks):
            if i == cut - 1 and cut > 0:
                qids = session.submit_embed(q_vids, consistent=True)
            if fail and i == fail_at:
                # ---- fail-stop: shards in lose_shards are gone
                session.degrade("failstop drill")
                restored_step, _, _ = _recover(pipe, mgr, d_new)
                for j in range(restored_step, i):   # replay since cut
                    _advance(session, chunks[j], feats)
                session.restore_normal()
            _advance(session, chunk, feats)
            if fail and (i + 1) % cfg.checkpoint_every == 0 and i < fail_at:
                mgr.save_pipeline(i + 1, pipe)
        session.flush()
        return (np.asarray(jax.device_get(pipe.sink)), pipe.metrics,
                session, qids, restored_step)

    def _recover(pipe, mgr, d_new):
        from repro.ft.elastic import rescale_parts
        surv = survivor_mesh(pipe.mesh, cfg.lose_shards, n_data=d_new)
        restored = mgr.restore_pipeline(pipe)
        plan = rescale_parts(d_old, d_new, cfg.n_parts)
        new_cfg = pipe.reshard(surv)
        return restored, plan, new_cfg

    mesh_old = lambda: make_stream_mesh(n_stages * d_old, stage=n_stages)
    o_sink, o_met, o_sess, o_qids, _ = _run(mesh_old, fail=False)
    c_sink, c_met, c_sess, c_qids, restored_step = _run(mesh_old, fail=True)
    o_ans = {q: o_sess.answers[q] for q in (o_qids or [])
             if q in o_sess.answers}
    c_ans = {q: c_sess.answers[q] for q in (c_qids or [])
             if q in c_sess.answers}
    return {
        "oracle_sink": o_sink, "chaos_sink": c_sink,
        "oracle_answers": o_ans, "chaos_answers": c_ans,
        "restored_step": restored_step,
        "dropped": int(c_met.dropped),
        "route_dropped": int(c_met.route_dropped),
        "oracle_dropped": int(o_met.dropped),
        "stats": c_sess.latency_stats(),
        "n_chunks": len(chunks), "cut": cut, "fail_at": fail_at,
    }


def scenario_truncated_checkpoint(cfg: ChaosConfig, ckpt_dir) -> dict:
    """Tear the newest checkpoint blob mid-write; restore must fail
    loudly and fall back to the previous kept generation."""
    edges, feats, _ = hub_heavy_stream(cfg)
    chunks = _chunks(cfg, edges)[:4]
    pipe = build_pipeline(cfg)
    session = ServeSession(pipe, driver=cfg.driver)
    mgr = CheckpointManager(Path(ckpt_dir) / "torn", keep=3)
    for i, chunk in enumerate(chunks):
        _advance(session, chunk, feats)
        mgr.save_pipeline(i + 1, pipe)
    good = mgr.latest()
    blob = good.path.read_bytes()
    good.path.write_bytes(blob[: max(8, len(blob) // 2)])   # torn write
    explicit_error = None
    try:
        mgr.restore_pipeline(pipe, step=good.step)
    except CheckpointCorruptError as e:
        explicit_error = str(e)
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        restored_step = mgr.restore_pipeline(pipe)
    return {
        "torn_step": good.step,
        "explicit_error": explicit_error,
        "restored_step": restored_step,
        "fallback_warned": any("falling back" in str(w.message)
                               for w in caught),
    }


def scenario_slow_shard(cfg: ChaosConfig, d_old: int = 4,
                        n_stages: int = 1) -> dict:
    """Deterministic fail-slow: a synthetic wall-time schedule feeds the
    StragglerMitigator exactly as the live telemetry plane does (tick
    wall + per-shard busy); once the slow shard's flag is persistent,
    `mitigate_stragglers()` executes the re-map — a live reshard onto
    the survivors, with `parts_per_shard()` re-mapped end-to-end."""
    edges, feats, _ = hub_heavy_stream(cfg)
    chunks = _chunks(cfg, edges)
    mesh = make_stream_mesh(n_stages * d_old, stage=n_stages)
    pipe = build_pipeline(cfg, mesh, n_stages=n_stages, telemetry=True)
    before = [p.copy() for p in pipe.parts_per_shard()]
    base_wall = 1.0
    plan = None
    mitigated_at = None
    for i, chunk in enumerate(chunks):
        rows = _feat_rows(chunk, feats)
        if cfg.driver == "tick":
            pipe.tick(chunk, rows)
        else:
            pipe.run_super_tick([chunk], [rows])
        if plan is None:
            # deterministic injected walls: the slow shard stretches the
            # lock-step tick by slow_factor and shows the highest busy.
            # The LIVE telemetry feed also observes every tick (real ms
            # walls never flag, but non-flagged ticks DECAY flags by 1),
            # so the injection repeats past patience + decay per chunk.
            busy = np.ones(max(pipe._n_data, 1))
            busy[cfg.slow_shard] = 2.0
            if i < 2:
                pipe.straggler.observe_tick(base_wall, busy)
            else:
                slow = base_wall * cfg.slow_factor
                for _ in range(pipe.straggler.patience + 2):
                    pipe.straggler.observe_tick(slow, busy)
            got = pipe.mitigate_stragglers()
            if got is not None:
                plan, mitigated_at = got, i
    pipe.flush(max_ticks=256)
    return {
        "plan": plan, "mitigated_at_chunk": mitigated_at,
        "parts_before": before,
        "parts_after": [p.copy() for p in pipe.parts_per_shard()],
        "n_data_after": pipe._n_data,
        "dropped": int(pipe.metrics.dropped),
        "route_dropped": int(pipe.metrics.route_dropped),
        "sink": np.asarray(jax.device_get(pipe.sink)),
        "ticks_observed": pipe.straggler.ticks_observed,
    }


def scenario_admission_storm(cfg: ChaosConfig) -> dict:
    """Query burst far beyond the per-tick admission budget: the session
    sheds beyond `shed_threshold` and bound-retries the retriable
    ok=False answers (queries naming vertices the stream has not
    materialized yet succeed on a later attempt) — every counter lands
    in latency_stats(), nothing is silent."""
    edges, feats, _ = hub_heavy_stream(cfg)
    chunks = _chunks(cfg, edges)
    pipe = build_pipeline(cfg)
    session = ServeSession(pipe, driver=cfg.driver, max_retries=4,
                           retry_backoff_ticks=1, shed_threshold=64)
    rng = np.random.default_rng(cfg.seed + 1)
    active = cfg.n_vertices - cfg.reserved
    # endpoints the stream has NOT materialized yet: their first answer
    # is a retriable ok=False; a backoff retry lands after the vertices
    # exist and succeeds
    late = list(range(active, cfg.n_vertices))
    storm_qids = []
    for i, chunk in enumerate(chunks):
        if i == 2:   # the storm: one burst >> admissions * ticks left
            vids = rng.integers(0, active, cfg.storm_queries)
            storm_qids = session.submit_embed(vids)
        _advance(session, chunk, feats)
    late_qids = session.submit_embed(late)
    _advance(session, np.zeros((0, 2), np.int64), feats)  # -> ok=False
    late_edges = np.asarray([[late[k], late[(k + 1) % len(late)]]
                             for k in range(len(late))], np.int64)
    _advance(session, late_edges, feats)   # NOW they materialize
    session.flush()   # window emits; the late embeddings reach the sink
    # release the backoff retries with empty ticks until they answer
    for _ in range(16):
        _advance(session, np.zeros((0, 2), np.int64), feats)
        if all(q in session.answers for q in late_qids):
            break
    session.flush()
    stats = session.latency_stats()
    resolved = sum(1 for q in storm_qids if q in session.answers)
    late_ok = {q: session.answers[q].ok for q in late_qids
               if q in session.answers}
    return {
        "stats": stats, "n_storm": len(storm_qids),
        "storm_resolved": resolved,
        "late_ok": late_ok,
        "outstanding": session.outstanding,
        "dropped": int(pipe.metrics.dropped),
        "route_dropped": int(pipe.metrics.route_dropped),
    }


SCENARIOS = {
    "failstop": scenario_failstop,
    "truncated_checkpoint": scenario_truncated_checkpoint,
    "slow_shard": scenario_slow_shard,
    "admission_storm": scenario_admission_storm,
}
