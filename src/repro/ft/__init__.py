"""Fault tolerance: consistent-cut checkpointing (incl. in-flight iteration
state), Alg. 5 elastic rescale, straggler mitigation."""
from repro.ft.checkpoint import CheckpointManager  # noqa: F401
from repro.ft.elastic import rescale_parts  # noqa: F401
from repro.ft.stragglers import StragglerMitigator  # noqa: F401
