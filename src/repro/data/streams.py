"""Synthetic streams mirroring the paper's datasets (temporal edge lists +
node features), and an LM token pipeline for the train drivers.

The paper streams temporal edge-list files (sx-superuser, reddit-hyperlink,
stackoverflow, ogb-products, wikikg90Mv2) as per-edge addition events
ordered by timestamp, with node features as a feature stream. These
generators produce the same event discipline at arbitrary scale:
hub-skewed (power-law) topology, timestamped edges, features delivered with
a vertex's first appearance (or early/late by `feature_lag`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.graph.graphs import powerlaw_edges


@dataclass
class TemporalStream:
    edges: np.ndarray           # [E, 2] ordered by timestamp
    timestamps: np.ndarray      # [E]
    feats: dict                 # vid -> feature vector
    n_nodes: int


def temporal_stream(seed: int = 0, n_nodes: int = 1000, n_edges: int = 10000,
                    d_feat: int = 16, alpha: float = 1.3,
                    burstiness: float = 0.0) -> TemporalStream:
    """Power-law temporal graph stream. `burstiness` > 0 concentrates
    timestamps (the paper's seasonality/hot-region workload shifts)."""
    rng = np.random.default_rng(seed)
    edges = powerlaw_edges(rng, n_nodes, n_edges, alpha)
    gaps = rng.exponential(1.0, n_edges)
    if burstiness > 0:
        bursts = rng.random(n_edges) < burstiness
        gaps = np.where(bursts, gaps * 0.01, gaps)
    ts = np.cumsum(gaps)
    feats = {v: rng.normal(size=d_feat).astype(np.float32)
             for v in range(n_nodes)}
    return TemporalStream(edges=edges, timestamps=ts, feats=feats,
                          n_nodes=n_nodes)


def edge_stream(stream: TemporalStream, tick_edges: int) -> Iterator[np.ndarray]:
    for lo in range(0, len(stream.edges), tick_edges):
        yield stream.edges[lo: lo + tick_edges]


def feature_stream(stream: TemporalStream, tick_edges: int,
                   feature_lag: int = 0) -> Iterator[list]:
    """Feature events aligned with a vertex's first appearance, optionally
    delayed by `feature_lag` ticks (exercises msgReady gating)."""
    seen: set = set()
    pending: list = []
    for i, lo in enumerate(range(0, len(stream.edges), tick_edges)):
        chunk = stream.edges[lo: lo + tick_edges]
        new = []
        for v in np.unique(chunk):
            v = int(v)
            if v not in seen:
                seen.add(v)
                new.append((v, stream.feats[v]))
        pending.append(new)
        if i >= feature_lag:
            yield pending.pop(0)
        else:
            yield []
    while pending:
        yield pending.pop(0)


def token_batches(seed: int, vocab: int, batch: int, seq: int,
                  n_batches: int) -> Iterator[tuple]:
    """Synthetic LM (tokens, labels) batches with a Zipfian marginal —
    exercises the vocab-sharded embedding/head paths realistically."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    for _ in range(n_batches):
        toks = rng.choice(vocab, size=(batch, seq), p=p).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        yield toks, labels
