"""Data pipeline: synthetic stand-ins for the paper's streams + LM tokens."""
from repro.data.streams import (edge_stream, feature_stream,  # noqa: F401
                                temporal_stream, token_batches)
