"""§Perf hillclimb experiments: optimized step variants per target cell,
measured with the same lower+compile+analyze loop as the baseline dry-run.
"""
