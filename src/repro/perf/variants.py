"""Optimized step variants for the three hillclimb cells (§Perf).

Each builder returns {"step", "args" (ShapeDtypeStructs), "in_shardings",
"donate_argnums", "baseline"}; repro.perf.run lowers/compiles/analyzes it
on the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import sds
from repro.launch.mesh import data_axes


# =====================================================================
# Cell A: pna x ogb_products — most collective-bound GNN, most
# representative of the paper (vertex-cut locality IS the contribution).
# =====================================================================
def _pna_locality(mesh, r_cap_per_pair: int, local_update: bool = False,
                  compute_dtype=None):
    from repro.dist.gnn_locality import make_locality_train_step
    from repro.graph.pna import PNA
    from repro.optim import adam

    axes = tuple(mesh.axis_names)          # all axes = one shard grid
    S = int(mesh.size)
    N = 2449408                            # padded ogb_products nodes
    E = 61859328                           # padded edges
    d_feat, ncls = 100, 47
    n_loc = N // S
    e_cap = -(-int(E // S * 1.3) // 512) * 512
    model = PNA(d_feat, d_hidden=75, n_layers=4, n_classes=ncls,
                avg_log_deg=3.2)
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(adam().init, params)
    step = make_locality_train_step(model, ncls, axes, mesh,
                                    local_update=local_update,
                                    compute_dtype=compute_dtype)

    batch = {
        "x": sds((S, n_loc, d_feat)),
        "labels": sds((S, n_loc), jnp.int32),
        "label_mask": sds((S, n_loc), jnp.bool_),
        "senders": sds((S, e_cap), jnp.int32),
        "receivers": sds((S, e_cap), jnp.int32),
        "edge_mask": sds((S, e_cap), jnp.bool_),
        "send_idx": sds((S, S, r_cap_per_pair), jnp.int32),
        "send_mask": sds((S, S, r_cap_per_pair), jnp.bool_),
    }
    repl = jax.tree.map(lambda l: NamedSharding(mesh, P()), params)
    repl_o = jax.tree.map(lambda l: NamedSharding(mesh, P()), opt_state)
    bsh = {k: NamedSharding(mesh, P(axes)) for k in batch}
    return {"step": step, "args": (params, opt_state, batch),
            "in_shardings": (repl, repl_o, bsh),
            "baseline": "pna__ogb_products"}


def pna_ogb_locality(mesh):
    """Iteration 2: vertex-cut halo exchange, HDRF-budget replicas
    (r_cap=512 rows per shard pair ~= replication factor ~7 on the
    power-law co-purchase graph)."""
    return _pna_locality(mesh, r_cap_per_pair=512)


def pna_ogb_locality_local(mesh):
    """Iteration 3: + update-MLP restricted to owned rows (halo rows only
    feed messages) — removes the 14x post-MLP overcompute of iteration 2."""
    return _pna_locality(mesh, r_cap_per_pair=512, local_update=True)


def pna_ogb_locality_bf16(mesh):
    """Iteration 4: + bf16 features/messages (f32 loss & params) — the
    memory term is message-traffic-dominated, so halving message bytes
    should halve it."""
    return _pna_locality(mesh, r_cap_per_pair=512, local_update=True,
                         compute_dtype=jnp.bfloat16)


def pna_ogb_locality_tight(mesh):
    """Iteration 5: halo budget down to r_cap=128/pair (total halo 3.4x
    owned rows ~= HDRF replication factor ~4) — the all_to_all transpose
    materializes per-peer slices of the WHOLE recv buffer, so wire AND
    memory cost scale with S*r_cap."""
    return _pna_locality(mesh, r_cap_per_pair=128, local_update=True,
                         compute_dtype=jnp.bfloat16)


def pna_ogb_locality_fat(mesh):
    """Ablation: 4x fatter halo budget (r_cap=2048) — tests sensitivity of
    the collective term to partition quality."""
    return _pna_locality(mesh, r_cap_per_pair=2048)


# =====================================================================
# Cell B: mistral-large x decode_32k — memory-bound serving; hypotheses:
# (1) bf16 serving weights (params were f32 -> 2x read traffic),
# (2) scatter cache update instead of full-cache where-rewrite.
# =====================================================================
def mistral_decode_bf16(mesh):
    from repro.configs import get_arch
    from repro.dist.sharding import (FAMILY_INPUT_RULES, FAMILY_PARAM_RULES,
                                     spec_tree)
    from repro.nn.module import tree_cast
    spec = get_arch("mistral-large-123b")
    model = spec.build("decode_32k")
    model = spec.tune_for_mesh(model, mesh)
    step = spec.step(model, "decode_32k")
    in_specs = spec.input_specs(model, "decode_32k")
    params = jax.eval_shape(model.init, jax.random.key(0))
    # serving weights in bf16 (the paper-faithful baseline keeps the f32
    # training master copies; serving replicas are cast)
    params = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 else l, params)
    params_sh = spec_tree(params, FAMILY_PARAM_RULES["lm"], mesh)
    input_sh = FAMILY_INPUT_RULES["lm"](in_specs, mesh, "decode")
    keys = list(in_specs)
    return {"step": step,
            "args": (params, *[in_specs[k] for k in keys]),
            "in_shardings": (params_sh, *[input_sh[k] for k in keys]),
            "donate_argnums": (2, 3),
            "baseline": "mistral-large-123b__decode_32k"}


# =====================================================================
# Cell C: moonshot x train_4k — most collective-bound LM (fine-grained
# MoE, top-6 of 64 experts every layer). Hypotheses:
# (1) fewer grad-accum steps => fewer FSDP weight re-gathers,
# (2) int8-compressed DP gradient all-reduce.
# =====================================================================
def moonshot_train_accum2(mesh):
    from repro.configs import get_arch
    from repro.configs.base import lm_step
    from repro.dist.sharding import (FAMILY_INPUT_RULES, FAMILY_PARAM_RULES,
                                     spec_tree)
    from repro.optim import adam
    spec = get_arch("moonshot-v1-16b-a3b")
    model = spec.build("train_4k")
    model = spec.tune_for_mesh(model, mesh)
    step = lm_step(model, "train_4k", grad_accum=2)
    in_specs = spec.input_specs(model, "train_4k")
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(adam().init, params)
    params_sh = spec_tree(params, FAMILY_PARAM_RULES["lm"], mesh)
    opt_sh = spec_tree(opt_state, FAMILY_PARAM_RULES["lm"], mesh)
    input_sh = FAMILY_INPUT_RULES["lm"](in_specs, mesh, "train")
    keys = list(in_specs)
    return {"step": step,
            "args": (params, opt_state, *[in_specs[k] for k in keys]),
            "in_shardings": (params_sh, opt_sh,
                             *[input_sh[k] for k in keys]),
            "donate_argnums": (0, 1),
            "baseline": "moonshot-v1-16b-a3b__train_4k"}


def moonshot_train_accum1(mesh):
    from repro.configs import get_arch
    from repro.configs.base import lm_step
    from repro.dist.sharding import (FAMILY_INPUT_RULES, FAMILY_PARAM_RULES,
                                     spec_tree)
    from repro.optim import adam
    spec = get_arch("moonshot-v1-16b-a3b")
    model = spec.build("train_4k")
    model = spec.tune_for_mesh(model, mesh)
    step = lm_step(model, "train_4k", grad_accum=1)
    in_specs = spec.input_specs(model, "train_4k")
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(adam().init, params)
    params_sh = spec_tree(params, FAMILY_PARAM_RULES["lm"], mesh)
    opt_sh = spec_tree(opt_state, FAMILY_PARAM_RULES["lm"], mesh)
    input_sh = FAMILY_INPUT_RULES["lm"](in_specs, mesh, "train")
    keys = list(in_specs)
    return {"step": step,
            "args": (params, opt_state, *[in_specs[k] for k in keys]),
            "in_shardings": (params_sh, opt_sh,
                             *[input_sh[k] for k in keys]),
            "donate_argnums": (0, 1),
            "baseline": "moonshot-v1-16b-a3b__train_4k"}


def moonshot_train_ep(mesh):
    """Cell C iteration 2: explicit all_to_all expert parallelism (the
    collective breakdown showed 7.2 TB of GSPMD all-gathers and ZERO
    all-to-alls — the partitioner never emits the dispatch pattern)."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import lm_step
    from repro.dist.sharding import (FAMILY_INPUT_RULES, FAMILY_PARAM_RULES,
                                     spec_tree)
    from repro.launch.mesh import data_axes
    from repro.optim import adam
    spec = get_arch("moonshot-v1-16b-a3b")
    model = spec.build("train_4k")
    model = spec.tune_for_mesh(model, mesh)
    cfg = model.cfg
    moe = dataclasses.replace(cfg.moe, ep_axis=("model",),
                              dp_axes=data_axes(mesh))
    model = type(model)(dataclasses.replace(cfg, moe=moe))
    step = lm_step(model, "train_4k", grad_accum=8)
    in_specs = spec.input_specs(model, "train_4k")
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(adam().init, params)
    params_sh = spec_tree(params, FAMILY_PARAM_RULES["lm"], mesh)
    opt_sh = spec_tree(opt_state, FAMILY_PARAM_RULES["lm"], mesh)
    input_sh = FAMILY_INPUT_RULES["lm"](in_specs, mesh, "train")
    keys = list(in_specs)
    return {"step": step,
            "args": (params, opt_state, *[in_specs[k] for k in keys]),
            "in_shardings": (params_sh, opt_sh,
                             *[input_sh[k] for k in keys]),
            "donate_argnums": (0, 1),
            "baseline": "moonshot-v1-16b-a3b__train_4k"}
