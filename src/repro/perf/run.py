import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-variant runner: lower+compile an optimized step variant and record
its roofline terms next to the baseline.

    PYTHONPATH=src python -m repro.perf.run --variant pna_ogb_locality
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_compiled

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "perf"


def run_variant(name: str, multi_pod: bool = False, save: bool = True):
    from repro.perf import variants
    build = getattr(variants, name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = build(mesh)
    t0 = time.perf_counter()
    with mesh, jax.set_mesh(mesh):
        jitted = jax.jit(spec["step"], in_shardings=spec.get("in_shardings"),
                         donate_argnums=spec.get("donate_argnums", ()))
        lowered = jitted.lower(*spec["args"])
        compiled = lowered.compile()
    result = {"variant": name,
              "mesh": "multi" if multi_pod else "single",
              "n_devices": int(mesh.size),
              "compile_s": round(time.perf_counter() - t0, 2),
              "baseline": spec.get("baseline", "")}
    result.update(analyze_compiled(compiled, mesh))
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out = RESULTS_DIR / f"{name}__{result['mesh']}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for m in meshes:
        r = run_variant(args.variant, multi_pod=m)
        print(f"[ok] {args.variant} x {r['mesh']}: "
              f"compile={r['compile_s']}s peak={r.get('peak_memory_gb')}GB "
              f"flops={r.get('hlo_gflops')}G mem={r.get('hlo_bytes_gb')}GB "
              f"coll={r.get('collective_gb')}GB "
              f"t=({r.get('t_compute_s')},{r.get('t_memory_s')},"
              f"{r.get('t_collective_s')}) bound={r.get('bottleneck')}")


if __name__ == "__main__":
    main()
