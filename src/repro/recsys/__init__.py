"""RecSys substrate: sparse embedding tables + two-tower retrieval.

JAX has no native EmbeddingBag and no CSR sparse — the EmbeddingBag here is
built from jnp.take + segment_sum (as the assignment requires); the Pallas
fused version lives in kernels/embedding_bag.
"""
from repro.recsys.embedding_bag import EmbeddingBag  # noqa: F401
from repro.recsys.two_tower import TwoTower, TwoTowerConfig  # noqa: F401
