"""Two-tower retrieval (YouTube/RecSys'19): sampled-softmax over in-batch
negatives with logQ correction.

Assigned config: embed_dim=256, tower MLP 1024-512-256, dot interaction.

Shapes:
  train_batch   : batch=65,536 in-batch sampled-softmax training step
  serve_p99     : batch=512 online user-tower inference
  serve_bulk    : batch=262,144 offline item scoring
  retrieval_cand: 1 query x 1,000,000 candidates — batched dot (no loop)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn.layers import MLP
from repro.nn.module import Module
from repro.recsys.embedding_bag import EmbeddingBag


@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    user_vocab: int = 10_000_000
    item_vocab: int = 10_000_000
    user_fields: int = 4            # multi-hot feature fields per user
    item_fields: int = 2
    max_ids_per_field: int = 8      # padded multi-hot width
    temperature: float = 0.05


@dataclass(frozen=True)
class TwoTower(Module):
    cfg: TwoTowerConfig

    def __post_init__(self):
        c = self.cfg
        object.__setattr__(self, "user_emb", EmbeddingBag(c.user_vocab, c.embed_dim))
        object.__setattr__(self, "item_emb", EmbeddingBag(c.item_vocab, c.embed_dim))
        u_in = c.embed_dim * c.user_fields
        i_in = c.embed_dim * c.item_fields
        object.__setattr__(self, "user_mlp",
                           MLP((u_in,) + tuple(c.tower_mlp), act=jax.nn.relu))
        object.__setattr__(self, "item_mlp",
                           MLP((i_in,) + tuple(c.tower_mlp), act=jax.nn.relu))

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"user_emb": self.user_emb.init(k1),
                "item_emb": self.item_emb.init(k2),
                "user_mlp": self.user_mlp.init(k3),
                "item_mlp": self.item_mlp.init(k4)}

    def user_tower(self, params, user_ids):
        """user_ids: [B, fields, max_ids] -> normalized [B, d]."""
        c = self.cfg
        e = embedding_fields(self.user_emb, params["user_emb"], user_ids)
        h = self.user_mlp(params["user_mlp"], e)
        return l2_normalize(h)

    def item_tower(self, params, item_ids):
        e = embedding_fields(self.item_emb, params["item_emb"], item_ids)
        h = self.item_mlp(params["item_mlp"], e)
        return l2_normalize(h)

    def score(self, params, user_ids, item_ids):
        """Dot-product scores [B] for paired users/items."""
        u = self.user_tower(params, user_ids)
        v = self.item_tower(params, item_ids)
        return jnp.sum(u * v, axis=-1) / self.cfg.temperature

    def retrieval_scores(self, params, user_ids, cand_item_ids):
        """One (or few) queries vs many candidates: [Bq, Nc] batched dot."""
        u = self.user_tower(params, user_ids)                  # [Bq, d]
        v = self.item_tower(params, cand_item_ids)             # [Nc, d]
        return (u @ v.T) / self.cfg.temperature

    def loss(self, params, user_ids, item_ids, item_logq=None):
        """In-batch sampled softmax with logQ correction.

        user_ids: [B, uf, w]; item_ids: [B, if, w]; item_logq: [B] sampling
        log-probabilities of items (frequency correction), optional.
        """
        u = self.user_tower(params, user_ids)                  # [B, d]
        v = self.item_tower(params, item_ids)                  # [B, d]
        logits = (u @ v.T).astype(jnp.float32) / self.cfg.temperature
        if item_logq is not None:
            logits = logits - item_logq[None, :]
        labels = jnp.arange(u.shape[0])
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def embedding_fields(bag: EmbeddingBag, params, ids):
    """ids: [B, fields, max_ids] -> concat of per-field bags [B, fields*d]."""
    B, F, W = ids.shape
    e = bag(params, ids.reshape(B * F, W))
    return e.reshape(B, F * bag.dim)


def l2_normalize(x, eps=1e-6):
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1, keepdims=True)
    return (x / jnp.maximum(n, eps).astype(x.dtype))
