"""EmbeddingBag: ragged multi-hot gather + segment reduce.

Input is a padded [B, max_ids] id matrix with -1 padding (equivalent to the
offsets form; the data pipeline produces this layout). Modes: sum / mean.

The lookup is the recsys hot path (taxonomy §B.6): jnp.take over a
[vocab, dim] table then per-row reduce. Row-sharded tables route lookups
with all_to_all in repro/dist/embedding_sharding.py; the fused TPU kernel is
kernels/embedding_bag.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.module import Module


@dataclass(frozen=True)
class EmbeddingBag(Module):
    vocab: int
    dim: int
    mode: str = "mean"          # "sum" | "mean"
    init_std: float = 0.01

    def init(self, key):
        return {"table": init.normal(self.init_std)(key, (self.vocab, self.dim))}

    def __call__(self, params, ids):
        """ids: [B, max_ids] int32, -1 = padding. Returns [B, dim]."""
        return embedding_bag_lookup(params["table"], ids, self.mode)


def embedding_bag_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                         mode: str = "mean") -> jnp.ndarray:
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    emb = jnp.take(table, safe.reshape(-1), axis=0)
    emb = emb.reshape(ids.shape + (table.shape[1],))
    emb = jnp.where(valid[..., None], emb, 0.0)
    s = jnp.sum(emb, axis=-2)
    if mode == "sum":
        return s
    n = jnp.sum(valid, axis=-1, keepdims=True).astype(s.dtype)
    return s / jnp.maximum(n, 1.0)


def embedding_bag_segment(table: jnp.ndarray, flat_ids: jnp.ndarray,
                          segment_ids: jnp.ndarray, n_bags: int,
                          mode: str = "mean") -> jnp.ndarray:
    """Offsets-form EmbeddingBag: flat id list + bag segment ids
    (torch nn.EmbeddingBag semantics; used by the kernel oracle)."""
    emb = jnp.take(table, flat_ids, axis=0)
    s = jax.ops.segment_sum(emb, segment_ids, n_bags)
    if mode == "sum":
        return s
    n = jax.ops.segment_sum(jnp.ones_like(flat_ids, table.dtype),
                            segment_ids, n_bags)
    return s / jnp.maximum(n, 1.0)[:, None]
