"""8-bit-state Adam (blockwise-quantized m/v, à la Dettmers' 8-bit Adam).

At 400B params on 256 chips, f32 Adam state is 12.5 GB/device — over the
v5e 16 GB budget on its own. Storing m and v as int8 with per-block f32
scales cuts optimizer state 4x at <1% update error (validated in tests
against f32 Adam on convergence).

Layout matters for sharding: the int8 codes keep the PARAM's shape (blocks
run along the last dim), so the quantized state shards exactly like the
parameter and dequantization is shard-local — a flattened [nblocks, BLOCK]
layout forces a global reshard of the dequantized f32 tensor on every step
(measured: +750 GB/device transients on the 400B config).

m: symmetric int8; v stored in sqrt-space (halves the dynamic range the
int8 grid must cover — keeps m/sqrt(v) stable late in training).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _f32

BLOCK = 256


def _block_len(last_dim: int) -> int:
    """256 when it divides the last dim, else one block per row."""
    return BLOCK if last_dim % BLOCK == 0 else last_dim


def quantize_blockwise(x: jnp.ndarray):
    """x [..., L] -> (int8 codes [..., L], scales [..., L/block])."""
    L = x.shape[-1] if x.ndim else 1
    xb = x.reshape(x.shape[:-1] + (-1,)) if x.ndim else x.reshape(1)
    blk = _block_len(xb.shape[-1])
    blocks = xb.reshape(xb.shape[:-1] + (xb.shape[-1] // blk, blk))
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0]


def dequantize_blockwise(q: jnp.ndarray, scale: jnp.ndarray):
    blk = _block_len(q.shape[-1] if q.ndim else 1)
    qb = q.reshape(q.shape[:-1] + (q.shape[-1] // blk, blk))
    out = qb.astype(jnp.float32) * scale[..., None]
    return out.reshape(q.shape)


class QState(NamedTuple):
    q: jnp.ndarray          # int8, same shape as the parameter
    scale: jnp.ndarray      # f32 [..., last/block]


def adam8bit(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        def z(p):
            blk = _block_len(p.shape[-1] if p.ndim else 1)
            sshape = (p.shape[:-1] + (max(1, (p.shape[-1] if p.ndim else 1)
                                          // blk),)) if p.ndim else (1,)
            return {"m": QState(jnp.zeros(p.shape, jnp.int8),
                                jnp.full(sshape, 1e-12)),
                    "v": QState(jnp.zeros(p.shape, jnp.int8),
                                jnp.full(sshape, 1e-12))}

        return {"per_param": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(state, grads, params, lr):
        g = _f32(grads)
        t = state["t"] + 1
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(s, gi, pi):
            m = dequantize_blockwise(s["m"].q, s["m"].scale)
            u = dequantize_blockwise(s["v"].q, s["v"].scale)
            v = u * u
            m = b1 * m + (1 - b1) * gi
            v = b2 * v + (1 - b2) * gi * gi
            step = (-lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)).astype(pi.dtype)
            mq, ms = quantize_blockwise(m)
            vq, vs = quantize_blockwise(jnp.sqrt(v))
            return step, {"m": QState(mq, ms), "v": QState(vq, vs)}

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(g)
        flat_s = tdef.flatten_up_to(state["per_param"])
        outs = [upd(s, gi, pi) for s, gi, pi in zip(flat_s, flat_g, flat_p)]
        steps = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_s = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return steps, {"per_param": new_s, "t": t}

    return Optimizer(init, update)
