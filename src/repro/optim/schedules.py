"""Learning-rate schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return f


def warmup_cosine(lr: float, warmup: int, steps: int, final_frac: float = 0.1):
    def f(step):
        warm = lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(steps - warmup, 1), 0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return f
