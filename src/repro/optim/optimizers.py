"""Optimizers as (init, update) pairs over param pytrees (optax-style API,
built from scratch — optax is not available in this environment).

update(opt_state, grads, params, lr) -> (updates, new_state); caller applies
`params + updates` via apply_updates. Optimizer state is kept in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(state, grads, params, lr):
        g = _f32(grads)
        if momentum == 0.0:
            return jax.tree.map(lambda gi: -lr * gi, g), state
        mu = jax.tree.map(lambda m, gi: momentum * m + gi, state["mu"], g)
        if nesterov:
            upd = jax.tree.map(lambda m, gi: -lr * (momentum * m + gi), mu, g)
        else:
            upd = jax.tree.map(lambda m: -lr * m, mu)
        return upd, {"mu": mu}

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(state, grads, params, lr):
        g = _f32(grads)
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi, state["m"], g)
        v = jax.tree.map(lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, state["v"], g)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mi, vi, pi):
            step = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * pi.astype(jnp.float32)
            return (-lr * step).astype(pi.dtype)

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adamax(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "u": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(state, grads, params, lr):
        g = _f32(grads)
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi, state["m"], g)
        u = jax.tree.map(lambda ui, gi: jnp.maximum(b2 * ui, jnp.abs(gi)), state["u"], g)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda mi, ui, pi: (-lr * (mi / bc1) / (ui + eps)).astype(pi.dtype),
            m, u, params)
        return upd, {"m": m, "u": u, "t": t}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads), gn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
