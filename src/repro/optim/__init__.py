from repro.optim.optimizers import adam, adamax, sgd, clip_by_global_norm, apply_updates  # noqa: F401
from repro.optim.schedules import cosine_decay, warmup_cosine, constant  # noqa: F401
