"""MODEL_FLOPS: the useful-work estimate per (arch x shape) cell.

LM     : train 6*N*D (N = params, active-params for MoE; D = tokens),
         prefill 2*N*D, decode 2*N_active*B + cache-read term
         4*B*S*L*Kh*Dh (one new token vs an S-token cache).
GNN    : closed-form message/update flops per model family x 3 for
         fwd+bwd (train shapes).
recsys : tower GEMMs + interaction x 3 for train, x 1 for serving.

The §Roofline ratio MODEL_FLOPS / HLO_FLOPs(global) measures how much of
the compiled compute is useful (catches remat/redundancy waste — remat'd
train steps legitimately sit near ~0.7, pure serving near 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.gnn_common import GNN_SHAPES, pad512
from repro.nn.module import param_count


def _lm_params(model, active: bool = False) -> int:
    import math
    cfg = model.cfg
    params = jax.eval_shape(model.init, jax.random.key(0))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(params))
    if not active or cfg.moe is None:
        return total
    # active params: replace the routed-expert contribution by top_k experts
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff
    n_moe_layers = cfg.n_layers // m.every
    total_experts = n_moe_layers * m.num_experts * per_expert
    active_experts = n_moe_layers * m.top_k * per_expert
    return total - total_experts + active_experts


def lm_model_flops(model, shape) -> float:
    cfg = model.cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    if shape.kind == "train":
        return 6.0 * _lm_params(model, active=True) * B * S
    if shape.kind == "prefill":
        return 2.0 * _lm_params(model, active=True) * B * S
    # decode: one token
    cache_read = 4.0 * B * S * cfg.n_layers * cfg.n_kv * cfg.head_dim
    return 2.0 * _lm_params(model, active=True) * B + cache_read


def gnn_model_flops(arch: str, model, shape) -> float:
    d = shape.dims
    N, E = pad512(d["n_nodes"]), pad512(d["n_edges"])
    if arch == "pna":
        dh = model.d_hidden
        din = model.d_in
        fwd = 0.0
        dims = [din] + [dh] * model.n_layers
        for i in range(model.n_layers):
            fwd += 2.0 * E * (2 * dims[i]) * dims[i]          # pre MLP
            fwd += 2.0 * N * (12 * dims[i] + dims[i]) * dims[i + 1]  # post
        return 3.0 * fwd
    if arch == "gatedgcn":
        dh = model.d_hidden
        fwd = 2.0 * N * model.d_in * dh                       # embed
        fwd += model.n_layers * (2.0 * 3 * E * dh * dh        # A/B/C on edges
                                 + 2.0 * 2 * N * dh * dh)     # U/V on nodes
        return 3.0 * fwd
    if arch == "nequip":
        mult = model.mult
        n_paths = 15                                           # l_max=2
        per_edge = n_paths * (2.0 * mult * 3 * 3 * 5           # CG contract
                              + 2.0 * 64 * n_paths * mult / n_paths)
        radial = 2.0 * E * (model.n_rbf * 64 + 64 * n_paths * mult)
        self_mix = 2.0 * N * 3 * 2 * mult * mult * 3
        return 3.0 * model.n_layers * (E * per_edge + radial + self_mix)
    if arch == "dimenet":
        dh = model.d_hidden
        T = pad512(4 * E)
        per_block = (2.0 * T * model.n_bilinear * dh * dh      # bilinear
                     + 2.0 * E * dh * dh * 3)                  # msg/out MLPs
        embed = 2.0 * E * (2 * dh + model.n_radial) * dh
        return 3.0 * (model.n_blocks * per_block + embed)
    raise KeyError(arch)


def recsys_model_flops(model, shape) -> float:
    c = model.cfg
    d = shape.dims
    B = d["batch"]

    def tower(fields):
        dims = [c.embed_dim * fields] + list(c.tower_mlp)
        return sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))

    if shape.name == "train_batch":
        fwd = B * (tower(c.user_fields) + tower(c.item_fields))
        fwd += 2.0 * B * B * c.tower_mlp[-1]        # in-batch logits
        return 3.0 * fwd
    if shape.name == "serve_p99":
        return B * tower(c.user_fields)
    if shape.name == "serve_bulk":
        return B * (tower(c.user_fields) + tower(c.item_fields)
                    + 2.0 * c.tower_mlp[-1])
    nc = -(-d["n_candidates"] // 512) * 512
    return (d["batch"] * tower(c.user_fields) + nc * tower(c.item_fields)
            + 2.0 * d["batch"] * nc * c.tower_mlp[-1])


def model_flops(arch: str, shape_name: str) -> float:
    spec = get_arch(arch)
    model = spec.build(shape_name)
    shape = spec.shapes[shape_name]
    if spec.family == "lm":
        return lm_model_flops(model, shape)
    if spec.family == "gnn":
        return gnn_model_flops(arch, model, shape)
    if spec.family == "recsys":
        return recsys_model_flops(model, shape)
    return float("nan")
