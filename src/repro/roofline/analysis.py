"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string like 'f32[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-op-kind output bytes of collective ops in optimized HLO.

    Uses the op's RESULT shape (bytes that cross the fabric at least once
    for AG/AR; a standard, reproducible proxy). Shapes are per-PARTITION in
    SPMD-partitioned HLO, i.e. already per-device.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %ag = bf16[4,1024]{1,0} all-gather(%x), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
                     r"([a-z\-]+)", s)
        if not m:
            continue
        opname = m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-"):
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def analyze_compiled(compiled, mesh) -> dict:
    """memory_analysis + cost_analysis + collective parse -> result dict."""
    n_dev = int(mesh.size)
    result = {}
    try:
        ma = compiled.memory_analysis()
        alias = getattr(ma, "alias_size_in_bytes", 0)
        per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - alias)
        result["bytes_per_device_gb"] = round(per_dev / 2**30, 3)
        result["peak_memory_gb"] = round(ma.peak_memory_in_bytes / 2**30, 3)
        result["argument_gb"] = round(ma.argument_size_in_bytes / 2**30, 3)
        result["temp_gb"] = round(ma.temp_size_in_bytes / 2**30, 3)
        result["output_gb"] = round(ma.output_size_in_bytes / 2**30, 3)
        result["alias_gb"] = round(alias / 2**30, 3)
    except Exception as e:  # noqa: BLE001
        result["memory_analysis_error"] = repr(e)
    try:
        # raw XLA cost_analysis counts while bodies ONCE — kept for
        # reference only; the roofline uses the trip-corrected analyzer.
        ca = compiled.cost_analysis()
        result["xla_raw_gflops"] = round(float(ca.get("flops", 0.0)) / 1e9, 3)
        result["xla_raw_bytes_gb"] = round(
            float(ca.get("bytes accessed", 0.0)) / 2**30, 3)
    except Exception as e:  # noqa: BLE001
        result["cost_analysis_error"] = repr(e)
    try:
        from repro.roofline.hlo_analyzer import analyze_hlo
        hlo = compiled.as_text()
        a = analyze_hlo(hlo)
        result["hlo_gflops"] = round(a["flops"] / 1e9, 3)
        result["hlo_bytes_gb"] = round(a["bytes"] / 2**30, 3)
        result["collective_gb"] = round(a["collective_bytes"] / 2**30, 3)
        result["collective_counts"] = {k: int(v) for k, v in
                                       a["collective_counts"].items()}
        result["collective_bytes_by_kind"] = {
            k: int(v) for k, v in a["collective_bytes_by_kind"].items()}
        result["_flops"] = a["flops"]
        result["_bytes"] = a["bytes"]
        result["_collective_bytes"] = a["collective_bytes"]
    except Exception as e:  # noqa: BLE001
        result["hlo_parse_error"] = repr(e)
    if "_flops" in result:
        result.update(roofline_terms(
            result["_flops"], result.get("_bytes", 0.0),
            result.get("_collective_bytes", 0.0), n_dev))
    for k in ("_flops", "_bytes", "_collective_bytes"):
        result.pop(k, None)
    return result


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, n_devices: int,
                   per_device_cost: bool = True) -> dict:
    """Three terms in seconds + the dominant bottleneck.

    cost_analysis of SPMD-partitioned HLO reports PER-PARTITION numbers;
    collective bytes parsed from partitioned HLO are per-device as well, so
    divide only by per-chip rates (not by n_devices again).
    """
    if per_device_cost:
        t_comp = hlo_flops / PEAK_FLOPS
        t_mem = hlo_bytes / HBM_BW
        t_coll = collective_bytes / ICI_BW
    else:
        t_comp = hlo_flops / (n_devices * PEAK_FLOPS)
        t_mem = hlo_bytes / (n_devices * HBM_BW)
        t_coll = collective_bytes / (n_devices * ICI_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    return {"t_compute_s": round(t_comp, 6), "t_memory_s": round(t_mem, 6),
            "t_collective_s": round(t_coll, 6), "bottleneck": dom}
