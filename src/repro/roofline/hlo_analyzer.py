"""Optimized-HLO analyzer: flops / HBM-traffic / collective bytes with
while-loop trip multiplicities.

XLA's compiled.cost_analysis() counts every computation ONCE — a scanned
transformer (88 layers x 8 microbatches) under-reports by orders of
magnitude, and loop-carried collectives (MoE all-to-alls in the layer scan)
vanish from the naive HLO grep. This analyzer:

  * splits the optimized HLO text into computations,
  * per computation tallies
      - dot flops (2 * prod(out_shape) * contracted_size),
      - memory traffic proxy: operand+result bytes of top-level ops
        (fusions count their boundaries only — internals are on-chip),
      - collective bytes by kind (result shape),
  * builds the call graph (call / fusion / while / conditional custom
    calls), extracts while trip counts from the condition computation's
    compare-against-constant pattern,
  * walks from ENTRY multiplying by enclosing trip counts.

Validated in tests against hand-computed scan programs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\s]+?))\s+"
    r"([a-z][a-z0-9\-]*)\(")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation|"
    r"branch_computations)=\{?%?([\w.\-, %]+)\}?")
# computation header: "[ENTRY] %name (args...) -> ret {"; args may nest
# parens (tuple types) so just anchor on name + trailing "{" and rely on the
# no-"=" guard at the call site to exclude op lines.
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_dims(shape_str: str):
    """All (dtype, dims list) found in a shape string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, d))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_operands(s: str):
    """Split an HLO operand list on TOP-LEVEL commas only — shape dims
    (f32[1024,64]) and layouts ({1,0}) contain commas of their own. Stops
    at the call's closing paren."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]}":
            depth -= 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


@dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict = field(default_factory=lambda: {k: 0 for k in
                                                       _COLLECTIVES})
    # (callee, kind) edges; kind "while" gets the trip multiplier
    calls: list = field(default_factory=list)
    max_const: int = 1          # largest small int constant (trip heuristic)
    symbols: dict = field(default_factory=dict)   # op name -> shape string
    # in-place update accounting: if this computation's ROOT is a
    # dynamic-update-slice, a caller fusion only moves ~2x the update slice
    # (read+write), not the whole buffer (XLA aliases the operand).
    root_dus_bytes: int | None = None
    fusion_sites: list = field(default_factory=list)  # (callee, result_bytes)


_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\))|[\w\[\],]+)")


def _dot_flops(line: str, out_shape: str, symbols: dict) -> float:
    """2 * prod(out) * contracted. Optimized HLO omits shapes at use sites,
    so the lhs shape is resolved through the computation's symbol table."""
    out_elems = 1
    shapes = _shape_dims(out_shape)
    if shapes:
        for x in shapes[0][1]:
            out_elems *= x
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    args = line[line.index("dot(") + 4:]
    operands = _split_operands(args)
    lhs = operands[0] if operands else ""
    lhs_name = lhs.strip().split()[-1].lstrip("%") if lhs.strip() else ""
    lhs_shapes = _shape_dims(lhs)
    if not lhs_shapes and lhs_name in symbols:
        lhs_shapes = _shape_dims(symbols[lhs_name])
    contracted = 1
    if m and lhs_shapes:
        dims = lhs_shapes[0][1]
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                contracted *= dims[int(idx)]
    elif lhs_shapes and lhs_shapes[0][1]:
        contracted = lhs_shapes[0][1][-1]
    return 2.0 * out_elems * contracted


def parse_hlo(text: str) -> dict:
    """-> {comp_name: CompStats}, plus '_entry' key with the entry name."""
    comps: dict[str, CompStats] = {}
    entry = None
    cur = None
    cur_name = None
    for raw in text.splitlines():
        # strip /*index=N*/-style comments (their '=' breaks the header
        # vs op-line discrimination)
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "=" not in line.split("{")[0]:
            cur_name = hdr.group(2)
            cur = CompStats()
            comps[cur_name] = cur
            if hdr.group(1):
                entry = cur_name
            # parameter shapes into the symbol table
            arglist = line[line.index("("):]
            for pm in _PARAM_RE.finditer(arglist):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            # plain constant lines for trip heuristic
            cm = re.search(r"constant\((\d+)\)", line)
            if cm:
                cur.max_const = max(cur.max_const, int(cm.group(1)))
            continue
        opname, shape_str, opcode = m.groups()
        cur.symbols[opname] = shape_str
        is_root = line.lstrip().startswith("ROOT")
        if opcode == "dynamic-update-slice":
            # in-place update: traffic ~= 2x the update operand, not the
            # whole buffer
            ops_str = line[line.index("dynamic-update-slice(") + 21:]
            parts = _split_operands(ops_str)
            # operand text is "f32[1,64]{1,0} %name" (shaped use site) or
            # just "%name"; prefer the inline shape, else the symbol table
            upd_bytes = 0
            if len(parts) > 1:
                upd_bytes = _shape_bytes(parts[1])
                if upd_bytes == 0:
                    upd_name = parts[1].strip().split()[-1].lstrip("%")
                    upd_bytes = _shape_bytes(cur.symbols.get(upd_name, ""))
            if upd_bytes == 0:
                upd_bytes = _shape_bytes(shape_str) // 16
            cur.bytes += 2 * upd_bytes
            # remember update size keyed by buffer size: fusions rooted in
            # this DUS (possibly through bitcast/convert) are in-place
            cur.dus_by_size = getattr(cur, "dus_by_size", {})
            cur.dus_by_size[_shape_bytes(shape_str)] = 2 * upd_bytes
            if is_root:
                cur.root_dus_bytes = 2 * upd_bytes
        elif opcode == "dot":
            cur.flops += _dot_flops(line, shape_str, cur.symbols)
            cur.bytes += _shape_bytes(shape_str)
        elif opcode == "fusion":
            mfc = re.search(r"calls=%?([\w.\-]+)", line)
            cur.fusion_sites.append((mfc.group(1) if mfc else None,
                                     _shape_bytes(shape_str)))
        elif opcode in ("custom-call", "copy", "scatter", "gather",
                        "dynamic-slice", "reduce",
                        "sort", "concatenate", "slice", "select-and-scatter",
                        "pad", "transpose"):
            # HBM-traffic proxy: result bytes of ops that materialize on
            # TPU. Pure elementwise ops (add/mul/convert/broadcast/...) are
            # fusion fodder there and are deliberately NOT counted even
            # when the CPU backend leaves them top-level — the roofline
            # targets the TPU memory system, not the CPU lowering.
            cur.bytes += _shape_bytes(shape_str)
        hit_coll = False
        for kind in _COLLECTIVES:
            if opcode == kind or opcode.startswith(kind + "-"):
                b = _shape_bytes(shape_str)
                cur.coll[kind] += b
                cur.coll_counts[kind] += 1
                cur.bytes += b
                hit_coll = True
                break
        cm = re.search(r"constant\((\d+)\)", line)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))
        attr = _CALL_ATTR_RE.findall(line)
        if attr:
            kind = ("while" if opcode == "while"
                    else "fusion" if opcode == "fusion" else "call")
            names = []
            for a in attr:
                names.extend(x.strip().lstrip("%") for x in a.split(","))
            if opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                mt = _TRIP_RE.search(line)
                trip = int(mt.group(1)) if mt else None
                if mb:
                    cur.calls.append((mb.group(1), "while",
                                      (mc.group(1) if mc else None, trip)))
            else:
                for nm in names:
                    if nm:
                        cur.calls.append((nm, kind, None))
    comps["_entry"] = entry
    return comps


def analyze_hlo(text: str) -> dict:
    comps = parse_hlo(text)
    entry = comps.pop("_entry")
    # resolve fusion result bytes now that all callees are parsed:
    # DUS-rooted fusions move ~2x the update slice, everything else moves
    # its full result
    for c in comps.values():
        for callee, rbytes in c.fusion_sites:
            dus = None
            if callee in comps:
                cc2 = comps[callee]
                dus = cc2.root_dus_bytes
                if dus is None:
                    sizes = getattr(cc2, "dus_by_size", {})
                    # tolerate dtype converts around the DUS (CPU lowering
                    # inserts bf16<->f32 roundtrips TPU would not)
                    for cand in (rbytes, 2 * rbytes, rbytes // 2):
                        if cand in sizes:
                            dus = sizes[cand]
                            break
            c.bytes += dus if dus is not None else rbytes
    memo = {}

    def total(name: str, depth=0):
        if name not in comps or depth > 64:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}, {
                k: 0 for k in _COLLECTIVES}
        if name in memo:
            return memo[name]
        c = comps[name]
        fl, by = c.flops, c.bytes
        co = dict(c.coll)
        cc = dict(c.coll_counts)
        for callee, kind, cond in c.calls:
            cf, cb, cco, ccc = total(callee, depth + 1)
            mult = 1
            if kind == "while":
                cond_name, trip = cond
                if trip is not None:             # backend_config trip count
                    mult = trip
                elif cond_name in comps:         # fallback: cond constant
                    mult = comps[cond_name].max_const
                mult = max(mult, 1)
            fl += mult * cf
            # fusion internals are on-chip: their flops/collectives count,
            # their intermediate bytes do not (the caller already counted
            # the fusion's boundary)
            if kind != "fusion":
                by += mult * cb
            for k in _COLLECTIVES:
                co[k] += mult * cco[k]
                cc[k] += mult * ccc[k]
        memo[name] = (fl, by, co, cc)
        return memo[name]

    fl, by, co, cc = total(entry)
    return {"flops": fl, "bytes": by,
            "collective_bytes_by_kind": co,
            "collective_counts": cc,
            "collective_bytes": sum(co.values())}
