"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import all_cells
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

IMPROVE_HINTS = {
    "compute": "reduce redundant flops (causal-block skipping, remat policy)",
    "memory": "fuse reads / larger tiles; decode: quantize or pack the KV "
              "cache, batch more requests per step",
    "collective": "locality-aware sharding (vertex-cut edge buckets), "
                  "int8-compressed DP all-reduce, all_to_all EP dispatch",
}


def load(arch, shape, mesh):
    p = RESULTS_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_fraction(r, model_fl):
    """Useful-compute time / dominant-term time (per device)."""
    n = r["n_devices"]
    t_useful = model_fl / n / PEAK_FLOPS
    t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    return t_useful / t_dom if t_dom > 0 else float("nan")


def build_rows(mesh: str, include_extra: bool = True):
    from repro.roofline.model_flops import model_flops
    rows = []
    for arch, shape in all_cells(include_extra=include_extra):
        r = load(arch, shape, mesh)
        if r is None:
            continue
        try:
            mf = model_flops(arch, shape)
        except Exception:  # d3gnn-sage etc.
            mf = float("nan")
        n = r["n_devices"]
        hlo_global = r["hlo_gflops"] * 1e9 * n
        ratio = mf / hlo_global if hlo_global and mf == mf else float("nan")
        frac = roofline_fraction(r, mf) if mf == mf else float("nan")
        rows.append({
            "arch": arch, "shape": shape, **r,
            "model_gflops_global": mf / 1e9 if mf == mf else None,
            "useful_ratio": ratio, "roofline_fraction": frac,
        })
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "peak GB/dev | MODEL/HLO flops | roofline frac | next lever |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        ratio = (f"{r['useful_ratio']:.2f}" if r["useful_ratio"] == r[
            "useful_ratio"] else "—")
        frac = (f"{r['roofline_fraction']:.2f}"
                if r["roofline_fraction"] == r["roofline_fraction"] else "—")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['bottleneck']} | {r.get('peak_memory_gb', '?')} | {ratio} | "
            f"{frac} | {IMPROVE_HINTS[r['bottleneck']]} |")
    return "\n".join(out)


def dryrun_table(rows):
    hdr = ("| arch | shape | mesh | compile (s) | peak GB/dev | HLO GFLOP/dev "
           "| HLO GB/dev | coll GB/dev | AG/AR/RS/A2A/CP |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        c = r.get("collective_counts", {})
        counts = "/".join(str(c.get(k, 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r.get('peak_memory_gb', '?')} | {r['hlo_gflops']} | "
            f"{r.get('hlo_bytes_gb', '?')} | {r.get('collective_gb', '?')} | "
            f"{counts} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    rows = build_rows(args.mesh)
    print(f"### Roofline ({args.mesh}-pod, per device)\n")
    print(markdown_table(rows))
    print()
    both = build_rows("single") + build_rows("multi")
    print("### Dry-run (all cells x both meshes)\n")
    print(dryrun_table(both))


if __name__ == "__main__":
    main()
