"""Serving launcher: the streaming-GNN online pipeline (the paper's kind)
or LM batched decode, selected by --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch d3gnn-sage --edges 2000
    PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
        --reduced --tokens 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch


def serve_lm(args):
    spec = get_arch(args.arch)
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    B = 4
    cache = model.init_cache(B, args.tokens + 8)
    tok = jnp.asarray(np.random.default_rng(0).integers(
        0, model.cfg.vocab, (B, 1)), jnp.int32)
    decode = jax.jit(model.decode_step)
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {B} seqs in {dt:.2f}s "
          f"({B * args.tokens / dt:.1f} tok/s)")


def serve_stream(args):
    from repro.core import windowing as win
    from repro.core.pipeline import D3Pipeline, PipelineConfig
    from repro.graph.graphs import powerlaw_edges
    from repro.graph.sage import GraphSAGE
    rng = np.random.default_rng(0)
    n_nodes = 400
    edges = powerlaw_edges(rng, n_nodes, args.edges)
    feats = {v: rng.normal(size=16).astype(np.float32)
             for v in range(n_nodes)}
    model = GraphSAGE((16, 64, 64))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=256, edge_cap=4096,
                         repl_cap=1024, feat_cap=2048, edge_tick_cap=512,
                         max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.SESSION, interval=4))
    pipe = D3Pipeline(model, params, cfg)
    t0 = time.perf_counter()
    if args.driver == "super":
        # device-resident driver: T micro-ticks per lax.scan launch, one
        # host sync per super-tick (the serving default for throughput)
        pipe.run_stream_super(edges, feats, tick_edges=args.tick_edges,
                              super_ticks=args.super_ticks)
        pipe.flush_super(max_ticks=64, T=4)
    else:
        pipe.run_stream(edges, feats, tick_edges=args.tick_edges)
        pipe.flush()
    dt = time.perf_counter() - t0
    print(f"streamed {args.edges} edges in {dt:.2f}s "
          f"[{args.driver} driver, {args.edges / dt:.0f} ev/s]; "
          f"materialized {len(pipe.embeddings())} embeddings; "
          f"{pipe.metrics.reduce_msgs} RMIs, "
          f"{pipe.metrics.cross_part_msgs} cross-part msgs")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="d3gnn-sage")
    ap.add_argument("--edges", type=int, default=2000)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--driver", choices=["super", "tick"], default="super",
                    help="super: lax.scan super-tick driver (default); "
                         "tick: per-tick reference driver")
    ap.add_argument("--tick-edges", type=int, default=256)
    ap.add_argument("--super-ticks", type=int, default=16,
                    help="micro-ticks per device launch (super driver)")
    args = ap.parse_args()
    if args.arch == "d3gnn-sage":
        serve_stream(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
