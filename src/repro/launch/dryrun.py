import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                      .lower(*arg_specs, **input_specs(arch, shape))
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes(HLO parse)

proves the distribution config is coherent: sharding mismatches, compile
OOMs and unsupported collectives all fail here. Results are cached as JSON
(results/dryrun/<arch>__<shape>__<mesh>.json) and feed EXPERIMENTS.md
§Dry-run and §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch nequip --shape molecule
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import all_cells, get_arch
from repro.dist.sharding import (FAMILY_INPUT_RULES, FAMILY_PARAM_RULES,
                                 spec_tree)
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_compiled

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _needs_opt(shape_kind: str) -> bool:
    return shape_kind == "train"


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             save: bool = True, donate: bool = True) -> dict:
    spec = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    model = spec.build(shape_name)
    model = spec.tune_for_mesh(model, mesh)
    shape = spec.shapes[shape_name]
    step = spec.step(model, shape_name)
    in_specs = spec.input_specs(model, shape_name)

    # parameter / optimizer-state shape trees without allocation
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    param_rule = FAMILY_PARAM_RULES[spec.family]
    params_sh = spec_tree(params_shapes, param_rule, mesh)
    input_sh = FAMILY_INPUT_RULES[spec.family](in_specs, mesh, shape.kind)

    args, in_shardings = [params_shapes], [params_sh]
    donate_argnums: tuple = ()
    if _needs_opt(shape.kind) and spec.family != "d3gnn":
        from repro.configs.base import make_optimizer
        opt = make_optimizer(getattr(spec, "optimizer", "adam"))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_sh = spec_tree(opt_shapes, param_rule, mesh)
        args.append(opt_shapes)
        in_shardings.append(opt_sh)
        donate_argnums = (0, 1) if donate else ()
    if donate:
        keys = list(in_specs)
        base = len(args)
        extra = tuple(base + keys.index(k) for k in spec.donate_inputs(shape_name))
        donate_argnums = donate_argnums + extra

    if spec.batch_style == "dict":
        all_args = args + [in_specs]
        all_shardings = tuple(in_shardings) + (input_sh,)
    else:
        all_args = args + [in_specs[k] for k in in_specs]
        all_shardings = tuple(in_shardings) + tuple(
            input_sh[k] for k in in_specs)

    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(step, in_shardings=all_shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*all_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "n_devices": int(mesh.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    result.update(analyze_compiled(compiled, mesh))
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out = RESULTS_DIR / f"{arch_id}__{shape_name}__{mesh_name}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-extra", action="store_true",
                    help="also run the d3gnn-sage streaming cell")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells(include_extra=args.include_extra)
    else:
        assert args.arch, "--arch required unless --all"
        spec = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        cells = [(args.arch, s) for s in shapes]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch_id, shape_name in cells:
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            tag = f"{arch_id} x {shape_name} x {mesh_name}"
            out = RESULTS_DIR / f"{arch_id}__{shape_name}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                print(f"[skip] {tag}")
                continue
            try:
                r = run_cell(arch_id, shape_name, multi)
                print(f"[ok] {tag}: compile={r['compile_s']}s "
                      f"peak/dev={r.get('peak_memory_gb', '?')}GB "
                      f"flops={r.get('hlo_gflops', '?')}G "
                      f"coll={r.get('collective_gb', '?')}GB "
                      f"bound={r.get('bottleneck', '?')}")
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall dry-run cells compiled.")


if __name__ == "__main__":
    main()
