"""Training launcher: ``--arch <id> --shape <shape>`` end-to-end.

On real hardware this runs the full config against the production mesh; on
CPU (this container) ``--reduced`` runs the same code path with the
reduced config and synthetic data — the per-arch smoke path.

    PYTHONPATH=src python -m repro.launch.train --arch gatedgcn \
        --shape full_graph_sm --steps 5 --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.ft.checkpoint import CheckpointManager
from repro.optim import adam


def synth_batch(spec, model, shape_name: str, reduced: bool, rng):
    """Synthetic inputs matching input_specs (reduced sizes on CPU)."""
    specs = spec.input_specs(model, shape_name)
    scale = 64 if reduced else 1

    def mk(k, s):
        shp = tuple(max(1, d // scale) if i == 0 else d
                    for i, d in enumerate(s.shape))
        if "mask" in k:
            return jnp.ones(shp, s.dtype)
        if s.dtype == jnp.int32:
            hi = 100
            return jnp.asarray(rng.integers(0, hi, shp), s.dtype)
        if s.dtype == jnp.bool_:
            return jnp.ones(shp, s.dtype)
        return jnp.asarray(rng.normal(size=shp), s.dtype)

    return {k: mk(k, s) for k, s in specs.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    shape = spec.shapes[args.shape]
    assert shape.kind == "train", f"{args.shape} is a {shape.kind} shape"
    model = (spec.build_reduced(args.shape) if args.reduced
             else spec.build(args.shape))
    params = model.init(jax.random.key(0))
    opt_state = adam().init(params)
    step = spec.step(model, args.shape)
    rng = np.random.default_rng(0)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    for i in range(args.steps):
        t0 = time.perf_counter()
        if spec.family == "lm":
            # reduced LM batches (token ids within reduced vocab)
            B, S = (2, 64) if args.reduced else (
                shape.dims["batch"], shape.dims["seq"])
            toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, S)),
                               jnp.int32)
            labels = jnp.roll(toks, -1, 1)
            loss, grads = jax.value_and_grad(model.loss)(params, toks, labels)
            from repro.optim import apply_updates, clip_by_global_norm
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, opt_state_new = adam().update(opt_state, grads, params, 3e-4)
            params = apply_updates(params, upd)
            opt_state = opt_state_new
        else:
            batch = synth_batch(spec, model, args.shape, args.reduced, rng)
            params, opt_state, loss = step(params, opt_state, batch)
        dt = time.perf_counter() - t0
        print(f"step {i}: loss={float(loss):.4f} ({dt:.2f}s)")
        if mgr:
            mgr.save(i, {"params": params, "opt": opt_state})
    print("train driver done")


if __name__ == "__main__":
    main()
