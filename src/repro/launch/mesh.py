"""Production mesh definition (dry-run target: TPU v5e pods).

single-pod: (16, 16)    axes ("data", "model")          = 256 chips
multi-pod : (2, 16, 16) axes ("pod", "data", "model")   = 512 chips

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
smoke tests must keep seeing one real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_stream_mesh(n_devices: int | None = None, stage: int = 1):
    """Mesh for the streaming engine: 1-D ("data",) or 2-D ("stage", "data").

    `D3Pipeline(mesh=make_stream_mesh())` shards the part axis of the
    tick over the "data" axis (MeshRouter). Defaults to all visible
    devices; to force a multi-device CPU mesh for tests set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before first jax
    use (see the "Distributed execution" README section).

    stage > 1 (hybrid parallelism, ISSUE 7) reshapes the same devices
    into a ("stage", "data") grid of `stage` pipeline stages x
    n_devices // stage data shards: the L GNN layers are placed
    round-robin on the stage axis and micro-ticks flow through them as a
    circular pipeline (set PipelineConfig.n_stages to match). stage=1
    (default) returns the exact 1-D mesh of every prior release — the
    pipelined code path is never entered.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        raise ValueError(f"requested {n} devices, only {len(devs)} visible "
                         "(forgot --xla_force_host_platform_device_count?)")
    stage = int(stage)
    if stage <= 1:
        return Mesh(np.asarray(devs[:n]), ("data",))
    if n % stage:
        raise ValueError(
            f"requested {n} devices over stage={stage} pipeline stages: "
            "the device count must be a multiple of the stage count "
            f"(each stage gets {n} / {stage} data shards)")
    return Mesh(np.asarray(devs[:n]).reshape(stage, n // stage),
                ("stage", "data"))


def survivor_mesh(mesh, lost_data_shards, n_data: int | None = None):
    """Mesh after fail-stop loss of `lost_data_shards` (data-axis column
    indices of `mesh`): keeps the stage extent, drops the lost data
    columns, and optionally trims to the first `n_data` surviving columns
    (block sharding needs n_parts % n_data == 0, so recovery may keep
    fewer shards than survived). The lost devices own nothing afterwards —
    `D3Pipeline.reshard(survivor_mesh(...))` relays all state onto the
    survivors."""
    import numpy as np
    from jax.sharding import Mesh

    devs = np.asarray(mesh.devices)
    stage_grid = devs.ndim == 2
    if not stage_grid:
        devs = devs[None, :]
    lost = {int(s) for s in lost_data_shards}
    keep = [i for i in range(devs.shape[1]) if i not in lost]
    if n_data is not None:
        keep = keep[: int(n_data)]
    if not keep:
        raise ValueError("no surviving data shards after "
                         f"losing {sorted(lost)}")
    grid = devs[:, keep]
    if not stage_grid:
        return Mesh(grid[0], ("data",))
    return Mesh(grid, ("stage", "data"))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: ("pod","data") on multi-pod else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
