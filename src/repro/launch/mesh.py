"""Production mesh definition (dry-run target: TPU v5e pods).

single-pod: (16, 16)    axes ("data", "model")          = 256 chips
multi-pod : (2, 16, 16) axes ("pod", "data", "model")   = 512 chips

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
smoke tests must keep seeing one real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_stream_mesh(n_devices: int | None = None):
    """1-D ("data",) mesh for the streaming engine's part axis.

    `D3Pipeline(mesh=make_stream_mesh())` shards the part axis of the
    tick over it (MeshRouter). Defaults to all visible devices; to force a
    multi-device CPU mesh for tests set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before first jax
    use (see the "Distributed execution" README section).
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        raise ValueError(f"requested {n} devices, only {len(devs)} visible "
                         "(forgot --xla_force_host_platform_device_count?)")
    return Mesh(np.asarray(devs[:n]), ("data",))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: ("pod","data") on multi-pod else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
