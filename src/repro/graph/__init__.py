"""Graph-learning substrate.

JAX has no sparse message-passing primitives beyond BCOO, so the
message-passing core here is built on ``jax.ops.segment_sum`` /
``segment_max`` over edge-index arrays (senders/receivers) — this IS part of
the system, not a stub. All models consume the same `Graph` struct:

  graphs.py    Graph container (edge index + masks + features + positions)
  segment.py   masked segment reduce ops (sum/mean/max/min/std/softmax)
  mp.py        generic MPGNN layer (phi / rho / psi), the paper's Section 3.3
  sage.py      GraphSAGE + GCN (the paper's evaluation models)
  pna.py       Principal Neighbourhood Aggregation (assigned arch)
  gatedgcn.py  GatedGCN (assigned arch)
  so3.py       real spherical harmonics + real Clebsch-Gordan coupling
  nequip.py    E(3)-equivariant interatomic potential (assigned arch)
  dimenet.py   directional message passing w/ triplet gather (assigned arch)
  sampler.py   fanout neighbor sampler (minibatch_lg shape)
  triplets.py  triplet index construction for DimeNet
"""
from repro.graph.graphs import Graph  # noqa: F401
