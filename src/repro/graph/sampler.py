"""Fanout neighbor sampler (GraphSAGE-style) for the `minibatch_lg` shape.

Host-side numpy over a CSR of in-edges: for each seed, sample up to
fanout[0] in-neighbors; for each of those, fanout[1]; etc. Returns a padded
subgraph with remapped local node ids (static shapes for jit).

The sampled-subgraph capacities for a fanout (f1, f2, ...) and B seeds:
    layer0 nodes: B, layer1: B*f1, layer2: B*f1*f2, ...
    edges: B*f1 + B*f1*f2 + ...
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.graphs import Graph


@dataclass
class CSRGraph:
    """In-edge CSR: for node v, senders of its in-edges are
    indices[indptr[v]:indptr[v+1]]."""
    indptr: np.ndarray
    indices: np.ndarray
    n_nodes: int

    @staticmethod
    def from_edges(senders: np.ndarray, receivers: np.ndarray, n_nodes: int):
        order = np.argsort(receivers, kind="stable")
        sorted_send = senders[order]
        counts = np.bincount(receivers, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=sorted_send, n_nodes=n_nodes)


def sample_capacities(batch_nodes: int, fanout: tuple[int, ...]):
    node_caps = [batch_nodes]
    edge_cap = 0
    for f in fanout:
        edge_cap += node_caps[-1] * f
        node_caps.append(node_caps[-1] * f)
    return sum(node_caps), edge_cap


def sample_subgraph(rng: np.random.Generator, csr: CSRGraph,
                    seeds: np.ndarray, fanout: tuple[int, ...],
                    features: np.ndarray | None = None):
    """Multi-hop fanout sample. Returns (Graph, local_seed_ids)."""
    max_nodes, max_edges = sample_capacities(len(seeds), fanout)
    local_of = {}                       # global id -> local id
    nodes = []                          # global ids by local id

    def local(gid: int) -> int:
        lid = local_of.get(gid)
        if lid is None:
            lid = len(nodes)
            local_of[gid] = lid
            nodes.append(gid)
        return lid

    senders, receivers = [], []
    frontier = [local(int(s)) for s in seeds]
    frontier_g = [int(s) for s in seeds]
    for f in fanout:
        nxt_l, nxt_g = [], []
        for lv, gv in zip(frontier, frontier_g):
            lo, hi = csr.indptr[gv], csr.indptr[gv + 1]
            deg = hi - lo
            if deg == 0:
                continue
            k = min(f, int(deg))
            picks = rng.choice(deg, size=k, replace=False) + lo
            for p in picks:
                gu = int(csr.indices[p])
                lu = local(gu)
                senders.append(lu)
                receivers.append(lv)
                nxt_l.append(lu)
                nxt_g.append(gu)
        frontier, frontier_g = nxt_l, nxt_g

    N, E = len(nodes), len(senders)
    s = np.zeros(max_edges, np.int32)
    r = np.zeros(max_edges, np.int32)
    emask = np.zeros(max_edges, bool)
    s[:E] = senders
    r[:E] = receivers
    emask[:E] = True
    nmask = np.zeros(max_nodes, bool)
    nmask[:N] = True
    gids = np.array(nodes + [0] * (max_nodes - N), np.int64)
    if features is not None:
        x = np.zeros((max_nodes, features.shape[1]), features.dtype)
        x[:N] = features[gids[:N]]
    else:
        x = np.zeros((max_nodes, 1), np.float32)
    g = Graph(senders=s, receivers=r, x=x, edge_mask=emask, node_mask=nmask)
    return g, np.arange(len(seeds), dtype=np.int32), gids
