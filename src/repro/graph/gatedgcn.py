"""GatedGCN — arXiv:1711.07553 / benchmarking-gnns (arXiv:2003.00982).

Assigned config: n_layers=16, d_hidden=70, gated aggregator.

    e_ij' = e_ij + ReLU(Norm(A x_i + B x_j + C e_ij))
    eta   = sigma(e_ij') / (sum_j sigma(e_ij') + eps)
    x_i'  = x_i + ReLU(Norm(U x_i + sum_j eta_ij * (V x_j)))

We use LayerNorm rather than BatchNorm: the streaming engine processes
events in micro-ticks where batch statistics are ill-defined (DESIGN §2);
LayerNorm is the standard drop-in for streaming/inference-first use.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.nn.layers import LayerNorm, Linear
from repro.nn.module import Module


@dataclass(frozen=True)
class GatedGCNLayer(Module):
    dim: int

    def __post_init__(self):
        d = self.dim
        for name in ("A", "B", "C", "U", "V"):
            object.__setattr__(self, name, Linear(d, d))
        object.__setattr__(self, "norm_e", LayerNorm(d))
        object.__setattr__(self, "norm_x", LayerNorm(d))

    def init(self, key):
        ks = jax.random.split(key, 7)
        return {"A": self.A.init(ks[0]), "B": self.B.init(ks[1]),
                "C": self.C.init(ks[2]), "U": self.U.init(ks[3]),
                "V": self.V.init(ks[4]), "norm_e": self.norm_e.init(ks[5]),
                "norm_x": self.norm_x.init(ks[6])}

    def __call__(self, params, g: Graph, x, e):
        """x: [N,d], e: [E,d] -> (x', e')."""
        xi, xj = x[g.receivers], x[g.senders]
        e_hat = (self.A(params["A"], xi) + self.B(params["B"], xj)
                 + self.C(params["C"], e))
        e_new = e + jax.nn.relu(self.norm_e(params["norm_e"], e_hat))
        gate = jax.nn.sigmoid(e_new)
        vj = self.V(params["V"], xj) * gate
        num = segment.segment_sum(vj, g.receivers, g.n_nodes, g.edge_mask)
        den = segment.segment_sum(gate, g.receivers, g.n_nodes, g.edge_mask)
        agg = num / (den + 1e-6)
        h = self.U(params["U"], x) + agg
        x_new = x + jax.nn.relu(self.norm_x(params["norm_x"], h))
        return x_new, e_new


@dataclass(frozen=True)
class GatedGCN(Module):
    d_in: int
    d_hidden: int = 70
    n_layers: int = 16
    n_classes: int = 0
    d_edge_in: int = 0              # 0 = no input edge features

    def __post_init__(self):
        object.__setattr__(self, "embed_x", Linear(self.d_in, self.d_hidden))
        object.__setattr__(self, "embed_e",
                           Linear(max(self.d_edge_in, 1), self.d_hidden))
        layers = tuple(GatedGCNLayer(self.d_hidden) for _ in range(self.n_layers))
        object.__setattr__(self, "layers", layers)
        if self.n_classes:
            object.__setattr__(self, "head", Linear(self.d_hidden, self.n_classes))

    def init(self, key):
        keys = jax.random.split(key, self.n_layers + 3)
        p = {"embed_x": self.embed_x.init(keys[0]),
             "embed_e": self.embed_e.init(keys[1])}
        for i, l in enumerate(self.layers):
            p[f"l{i}"] = l.init(keys[2 + i])
        if self.n_classes:
            p["head"] = self.head.init(keys[-1])
        return p

    def __call__(self, params, g: Graph, x=None):
        x = g.x if x is None else x
        x = self.embed_x(params["embed_x"], x)
        if g.edge_attr is not None:
            e = self.embed_e(params["embed_e"], g.edge_attr)
        else:
            e = self.embed_e(params["embed_e"],
                             jnp.ones((g.n_edges, 1), x.dtype))
        for i, l in enumerate(self.layers):
            x, e = l(params[f"l{i}"], g, x, e)
        if self.n_classes:
            return self.head(params["head"], x)
        return x

    def loss(self, params, g: Graph, labels, label_mask):
        logits = self(params, g).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        ce = jnp.where(label_mask, -gold, 0.0)
        return jnp.sum(ce) / jnp.maximum(jnp.sum(label_mask), 1)
