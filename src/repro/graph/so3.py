"""SO(3) machinery for equivariant GNNs (NequIP), l_max <= 2.

e3nn is not available in this environment, so this is built from scratch:
  * complex Clebsch-Gordan coefficients via the Racah formula (numpy, exact
    for the tiny l involved),
  * real-basis change U_l (standard real spherical harmonic convention),
  * real coupling tensors W[l1,l2,l3] := U3 . CG . (U1* x U2*), phase-fixed
    to be real,
  * real spherical harmonics computed FROM the complex ones through U_l, so
    the basis convention is consistent with the coupling tensors by
    construction.

Conventions verified in tests: l=1 real basis is ordered (y, z, x), so
D^1(R) = P R P^T with P the (x,y,z)->(y,z,x) permutation; full-model energy
invariance under random rotations exercises every l<=2 coupling path.
"""
from __future__ import annotations

from functools import lru_cache
from math import factorial, sqrt

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- complex CG
def _cg_complex(l1: int, l2: int, l3: int, m1: int, m2: int, m3: int) -> float:
    """<l1 m1 l2 m2 | l3 m3> via the Racah formula (exact floats, small l)."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return 0.0
    if abs(m1) > l1 or abs(m2) > l2 or abs(m3) > l3:
        return 0.0
    f = factorial
    pre = sqrt(
        (2 * l3 + 1)
        * f(l3 + l1 - l2) * f(l3 - l1 + l2) * f(l1 + l2 - l3)
        / f(l1 + l2 + l3 + 1)
    )
    pre *= sqrt(f(l3 + m3) * f(l3 - m3)
                * f(l1 - m1) * f(l1 + m1) * f(l2 - m2) * f(l2 + m2))
    s = 0.0
    for k in range(0, l1 + l2 + l3 + 1):
        denoms = [l1 + l2 - l3 - k, l1 - m1 - k, l2 + m2 - k,
                  l3 - l2 + m1 + k, l3 - l1 - m2 + k]
        if any(d < 0 for d in denoms):
            continue
        s += (-1.0) ** k / (
            f(k) * f(denoms[0]) * f(denoms[1]) * f(denoms[2])
            * f(denoms[3]) * f(denoms[4]))
    return pre * s


@lru_cache(maxsize=None)
def cg_matrix_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """[2l1+1, 2l2+1, 2l3+1] complex-basis CG, m from -l..l."""
    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    for i, m1 in enumerate(range(-l1, l1 + 1)):
        for j, m2 in enumerate(range(-l2, l2 + 1)):
            for k, m3 in enumerate(range(-l3, l3 + 1)):
                out[i, j, k] = _cg_complex(l1, l2, l3, m1, m2, m3)
    return out


# ------------------------------------------------------- real-basis change
@lru_cache(maxsize=None)
def real_basis_change(l: int) -> np.ndarray:
    """U_l with y_real = U_l @ y_complex; rows ordered m=-l..l (real),
    cols m=-l..l (complex, Condon-Shortley)."""
    n = 2 * l + 1
    U = np.zeros((n, n), dtype=np.complex128)
    for m in range(-l, l + 1):
        r = m + l
        if m == 0:
            U[r, l] = 1.0
        elif m > 0:
            U[r, -m + l] = 1 / sqrt(2)
            U[r, m + l] = ((-1) ** m) / sqrt(2)
        else:  # m < 0
            am = -m
            U[r, -am + l] = 1j / sqrt(2)
            U[r, am + l] = -1j * ((-1) ** am) / sqrt(2)
    return U


@lru_cache(maxsize=None)
def coupling_tensor(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling W[i,j,k]: w_k = sum_ij W[i,j,k] u_i v_j.

    Phase-fixed to a real tensor (the complex result is e^{i phi} * real;
    the global phase is absorbed by learnable path weights)."""
    C = cg_matrix_complex(l1, l2, l3).astype(np.complex128)
    U1, U2, U3 = (real_basis_change(x) for x in (l1, l2, l3))
    W = np.einsum("ia,jb,abc,kc->ijk", np.conj(U1), np.conj(U2), C, U3)
    re, im = np.real(W), np.imag(W)
    if np.abs(im).max() > np.abs(re).max():
        assert np.abs(re).max() < 1e-10, (l1, l2, l3, np.abs(re).max())
        return np.ascontiguousarray(im)
    assert np.abs(im).max() < 1e-10, (l1, l2, l3, np.abs(im).max())
    return np.ascontiguousarray(re)


# --------------------------------------------------- real spherical harmonics
def real_sph_harm(vec: jnp.ndarray, l_max: int = 2, eps: float = 1e-9):
    """Real spherical harmonics of unit(vec) for l=0..l_max.

    vec: [..., 3]. Returns dict {l: [..., 2l+1]} matching real_basis_change
    conventions (derived from complex Y_lm through U_l, evaluated here in
    closed form). Normalized so that ||Y_l||^2 integrates to 1 on S^2.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    x, y, z = x / r, y / r, z / r
    out = {0: jnp.full(vec.shape[:-1] + (1,), 0.5 * sqrt(1 / np.pi), vec.dtype)}
    if l_max >= 1:
        c1 = sqrt(3 / (4 * np.pi))
        out[1] = jnp.stack([c1 * y, c1 * z, c1 * x], axis=-1)
    if l_max >= 2:
        c2 = 0.5 * sqrt(15 / np.pi)
        out[2] = jnp.stack([
            c2 * x * y,                                     # m=-2
            c2 * y * z,                                     # m=-1
            0.25 * sqrt(5 / np.pi) * (3 * z * z - 1),       # m=0
            c2 * x * z,                                     # m=1
            0.5 * c2 * (x * x - y * y),                     # m=2
        ], axis=-1)
    if l_max >= 3:
        raise NotImplementedError("l_max <= 2 (assigned NequIP config)")
    return out


def check_l1_conventions() -> float:
    """Max deviation between analytic real Y_1 and U_1-transformed complex Y_1
    on random directions (used by tests)."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(64, 3))
    v /= np.linalg.norm(v, axis=-1, keepdims=True)
    x, y, z = v[:, 0], v[:, 1], v[:, 2]
    c = 0.5 * sqrt(3 / (2 * np.pi))
    Yc = np.stack([c * (x - 1j * y), 0.5 * sqrt(3 / np.pi) * z,
                   -c * (x + 1j * y)], axis=-1)   # m=-1,0,1 complex
    U1 = real_basis_change(1)
    Yr_from_complex = np.real(Yc @ U1.T)
    Yr = np.asarray(real_sph_harm(jnp.asarray(v), 1)[1])
    return float(np.abs(Yr - Yr_from_complex).max())
