"""Graph container: struct-of-arrays, static shapes, mask-padded.

Directed edges run sender -> receiver; messages flow along edges and
aggregate at receivers (the paper's N_in(v) convention). Batched small
graphs (the `molecule` shape) are disjoint unions with a `graph_ids` vector.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Graph:
    senders: jnp.ndarray            # [E] int32
    receivers: jnp.ndarray          # [E] int32
    x: jnp.ndarray                  # [N, d] node features
    edge_mask: Optional[jnp.ndarray] = None   # [E] bool (None = all valid)
    node_mask: Optional[jnp.ndarray] = None   # [N] bool
    edge_attr: Optional[jnp.ndarray] = None   # [E, de]
    pos: Optional[jnp.ndarray] = None         # [N, 3]
    graph_ids: Optional[jnp.ndarray] = None   # [N] int32 (batched small graphs)
    n_graphs: int = 1               # static

    @property
    def n_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]

    def replace(self, **kw) -> "Graph":
        return replace(self, **kw)


jax.tree_util.register_dataclass(
    Graph,
    data_fields=["senders", "receivers", "x", "edge_mask", "node_mask",
                 "edge_attr", "pos", "graph_ids"],
    meta_fields=["n_graphs"],
)


def in_degree(g: Graph) -> jnp.ndarray:
    ones = jnp.ones((g.n_edges,), jnp.float32)
    if g.edge_mask is not None:
        ones = jnp.where(g.edge_mask, ones, 0.0)
    return jax.ops.segment_sum(ones, g.receivers, g.n_nodes)


def erdos_graph(key, n_nodes: int, n_edges: int, d_feat: int,
                with_pos: bool = False, n_classes: int = 0):
    """Synthetic random graph (numpy host-side ok, returned as jnp)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    senders = jax.random.randint(k1, (n_edges,), 0, n_nodes)
    receivers = jax.random.randint(k2, (n_edges,), 0, n_nodes)
    x = jax.random.normal(k3, (n_nodes, d_feat))
    pos = 3.0 * jax.random.normal(k4, (n_nodes, 3)) if with_pos else None
    return Graph(senders=senders.astype(jnp.int32),
                 receivers=receivers.astype(jnp.int32), x=x, pos=pos)


def powerlaw_edges(rng: np.random.Generator, n_nodes: int, n_edges: int,
                   alpha: float = 1.5) -> np.ndarray:
    """Preferential-attachment-flavoured edge stream [E,2] (hub-skewed),
    matching the paper's power-law workload discussion."""
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** (-alpha)
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w)
    dst = rng.choice(n_nodes, size=n_edges, p=w)
    # avoid self loops by bumping dst
    dst = np.where(dst == src, (dst + 1) % n_nodes, dst)
    return np.stack([src, dst], axis=1).astype(np.int32)


def batch_molecules(key, n_graphs: int, nodes_per: int, edges_per: int,
                    d_feat: int) -> Graph:
    """Disjoint union of `n_graphs` random molecule-sized graphs with 3D pos."""
    keys = jax.random.split(key, 4)
    N, E = n_graphs * nodes_per, n_graphs * edges_per
    offs_n = jnp.repeat(jnp.arange(n_graphs) * nodes_per, edges_per)
    senders = jax.random.randint(keys[0], (E,), 0, nodes_per) + offs_n
    receivers = jax.random.randint(keys[1], (E,), 0, nodes_per) + offs_n
    x = jax.random.normal(keys[2], (N, d_feat))
    pos = 2.0 * jax.random.normal(keys[3], (N, 3))
    gids = jnp.repeat(jnp.arange(n_graphs), nodes_per)
    return Graph(senders=senders.astype(jnp.int32),
                 receivers=receivers.astype(jnp.int32),
                 x=x, pos=pos, graph_ids=gids.astype(jnp.int32),
                 n_graphs=n_graphs)
