"""GraphSAGE + GCN — the paper's evaluation models (2-layer SAGE, dim 64).

SAGE-mean layer:  x_v' = act( W_self x_v + W_neigh mean_{u in N_in(v)} x_u )
GCN layer:        x_v' = act( W sum_u  x_u / sqrt(d_u d_v) )

Both `mean` and deg-normalized `sum` are invertible synopses, which is what
makes the D3-GNN streaming aggregators exact for these models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph, in_degree
from repro.nn import initializers as init
from repro.nn.layers import Linear
from repro.nn.module import Module


@dataclass(frozen=True)
class SAGELayer(Module):
    in_dim: int
    out_dim: int
    act: bool = True
    # aggregator synopsis kind — selects the delta-gate for incremental
    # propagation (core/aggregators.GATES; core/tick.py reads it via
    # getattr(layer, "agg_kind", "mean")). Class attribute, not a
    # dataclass field: it is a property of the layer TYPE.
    agg_kind = "mean"

    def __post_init__(self):
        object.__setattr__(self, "w_self", Linear(self.in_dim, self.out_dim))
        object.__setattr__(self, "w_neigh", Linear(self.in_dim, self.out_dim,
                                                   use_bias=False))

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"self": self.w_self.init(k1), "neigh": self.w_neigh.init(k2)}

    def message(self, params, x_u):
        """phi: identity on source features (SAGE-mean)."""
        return x_u

    def update(self, params, x_v, agg):
        """psi: W_self x_v + W_neigh agg (then relu if not final)."""
        h = self.w_self(params["self"], x_v) + self.w_neigh(params["neigh"], agg)
        return jax.nn.relu(h) if self.act else h

    def __call__(self, params, g: Graph, x):
        agg = segment.segment_mean(x[g.senders], g.receivers, g.n_nodes, g.edge_mask)
        return self.update(params, x, agg)


@dataclass(frozen=True)
class GCNLayer(Module):
    in_dim: int
    out_dim: int
    act: bool = True
    agg_kind = "sum"     # deg-normalized sum synopsis (see SAGELayer note)

    def __post_init__(self):
        object.__setattr__(self, "w", Linear(self.in_dim, self.out_dim))

    def init(self, key):
        return {"w": self.w.init(key)}

    def __call__(self, params, g: Graph, x):
        deg = in_degree(g) + 1.0
        norm = jax.lax.rsqrt(deg)
        msg = (x * norm[:, None])[g.senders]
        agg = segment.segment_sum(msg, g.receivers, g.n_nodes, g.edge_mask)
        h = self.w(params["w"], (agg + x * norm[:, None]) * norm[:, None])
        return jax.nn.relu(h) if self.act else h


@dataclass(frozen=True)
class GraphSAGE(Module):
    """Stack of SAGE layers; the paper's model is dims=(in, 64, 64)."""
    dims: Sequence[int]
    n_classes: int = 0              # 0 = produce embeddings only

    def __post_init__(self):
        n = len(self.dims) - 1
        layers = tuple(
            SAGELayer(self.dims[i], self.dims[i + 1], act=(i < n - 1 or self.n_classes > 0))
            for i in range(n))
        object.__setattr__(self, "layers", layers)
        if self.n_classes:
            object.__setattr__(self, "head", Linear(self.dims[-1], self.n_classes))

    def init(self, key):
        keys = jax.random.split(key, len(self.layers) + 1)
        p = {f"l{i}": l.init(keys[i]) for i, l in enumerate(self.layers)}
        if self.n_classes:
            p["head"] = self.head.init(keys[-1])
        return p

    def __call__(self, params, g: Graph, x=None):
        x = g.x if x is None else x
        for i, l in enumerate(self.layers):
            x = l(params[f"l{i}"], g, x)
        if self.n_classes:
            return self.head(params["head"], x)
        return x

    def loss(self, params, g: Graph, labels, label_mask):
        logits = self(params, g).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        ce = jnp.where(label_mask, -gold, 0.0)
        return jnp.sum(ce) / jnp.maximum(jnp.sum(label_mask), 1)
