"""Masked segment reductions — the message-passing primitive.

All ops take `data [E, ...]`, `segment_ids [E]`, `num_segments` (static) and
an optional boolean `mask [E]` for padded edges. Invalid edges contribute
nothing. `segment_ids` of padded edges may be arbitrary in [0, num_segments).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked(data, mask, fill=0.0):
    if mask is None:
        return data
    # dtype-preserving fill: a Python-float fill would weak-type-promote
    # bf16 data to f32 and silently double the memory traffic
    fill = jnp.asarray(fill, data.dtype)
    return jnp.where(mask.reshape(mask.shape + (1,) * (data.ndim - 1)), data, fill)


def segment_sum(data, segment_ids, num_segments, mask=None):
    return jax.ops.segment_sum(_masked(data, mask), segment_ids, num_segments)


def segment_count(segment_ids, num_segments, mask=None):
    ones = jnp.ones(segment_ids.shape, jnp.float32)
    if mask is not None:
        ones = jnp.where(mask, ones, 0.0)
    return jax.ops.segment_sum(ones, segment_ids, num_segments)


def segment_mean(data, segment_ids, num_segments, mask=None):
    s = segment_sum(data, segment_ids, num_segments, mask)
    n = segment_count(segment_ids, num_segments, mask).astype(s.dtype)
    n = n.reshape(n.shape + (1,) * (s.ndim - 1))
    return s / jnp.maximum(n, jnp.asarray(1.0, s.dtype))


def segment_max(data, segment_ids, num_segments, mask=None):
    d = _masked(data, mask, NEG_INF)
    m = jax.ops.segment_max(d, segment_ids, num_segments)
    return jnp.where(m <= NEG_INF / 2, jnp.asarray(0.0, m.dtype), m)


def segment_min(data, segment_ids, num_segments, mask=None):
    return -segment_max(-data, segment_ids, num_segments, mask)


def segment_std(data, segment_ids, num_segments, mask=None, eps=1e-5):
    """Per-segment standard deviation (PNA's std aggregator).

    Maintained as the invertible synopsis (Σm, Σm², n) — see DESIGN §4: this
    is exactly why PNA remains streaming-compatible in the D3-GNN sense.
    """
    s1 = segment_sum(data, segment_ids, num_segments, mask)
    s2 = segment_sum(jnp.square(data), segment_ids, num_segments, mask)
    n = segment_count(segment_ids, num_segments, mask).astype(s1.dtype)
    n = jnp.maximum(n, 1).reshape(n.shape + (1,) * (s1.ndim - 1))
    var = s2 / n - jnp.square(s1 / n)
    return jnp.sqrt(jnp.maximum(var, jnp.asarray(0.0, var.dtype))
                    + jnp.asarray(eps, var.dtype))


def segment_softmax(scores, segment_ids, num_segments, mask=None):
    """Edge softmax per destination segment (GAT / attention aggregators)."""
    m = segment_max(scores, segment_ids, num_segments, mask)
    z = jnp.exp(_masked(scores - m[segment_ids], mask, NEG_INF))
    denom = jax.ops.segment_sum(z, segment_ids, num_segments)
    return z / jnp.maximum(denom[segment_ids], 1e-30)
