"""Generic MPGNN layer — the paper's §3.3 formulation.

    m_e  = phi(x_u, x_v, x_e)        per incoming edge (u -> v)
    a_v  = rho({m_e})                permutation-invariant aggregation
    x_v' = psi(x_v, a_v)             update

`rho` must be a synopsis (mergeable / commutative / invertible) for the
streaming engine (repro/core) to maintain it incrementally; the aggregators
offered here (sum / mean / max*) satisfy that (max is invertible only via
re-scan on remove — see core/aggregators.py for the exact contract).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.nn.module import Module

AGGREGATORS = {
    "sum": segment.segment_sum,
    "mean": segment.segment_mean,
    "max": segment.segment_max,
    "min": segment.segment_min,
}


@dataclass(frozen=True)
class MPLayer(Module):
    """phi/psi supplied as sub-modules; rho by name."""
    phi: Module                     # (params, x_u, x_v, x_e) -> messages
    psi: Module                     # (params, x_v, a_v) -> x_v'
    rho: str = "mean"

    def init(self, key):
        import jax
        k1, k2 = jax.random.split(key)
        return {"phi": self.phi.init(k1), "psi": self.psi.init(k2)}

    def __call__(self, params, g: Graph, x: jnp.ndarray) -> jnp.ndarray:
        xu = x[g.senders]
        xv = x[g.receivers]
        m = self.phi(params["phi"], xu, xv, g.edge_attr)
        agg = AGGREGATORS[self.rho](m, g.receivers, g.n_nodes, g.edge_mask)
        return self.psi(params["psi"], x, agg)
