"""DimeNet — directional message passing (arXiv:2003.03123).

Assigned config: n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.

Messages live on directed edges m_ji; interaction blocks aggregate over
triplets (k->j->i):

    m_ji' = W m_ji + sum_k  a_SBF(r_kj, angle_kji) (x) W_bilinear (x) m_kj

The 2D spherical basis is factorized as bessel(r) x cos(l * angle)
(l = 0..n_spherical-1): exact spherical-Bessel roots require scipy (not in
this environment); the cosine angular basis spans the same angular
frequencies and keeps flops/shape identical. Noted in DESIGN §2.

The triplet gather is the taxonomy's "triplet/quadruplet gather" kernel
regime: indices come precomputed (triplets.py), compute is gather -> dense
bilinear einsum -> segment_sum, mapping onto kernels/segment_reduce on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.graph.nequip import bessel_basis
from repro.nn import initializers as init
from repro.nn.layers import MLP, Linear
from repro.nn.module import Module


def angular_basis(cos_angle: jnp.ndarray, n_spherical: int) -> jnp.ndarray:
    """cos(l * theta) via Chebyshev recurrence, [T, n_spherical]."""
    c = jnp.clip(cos_angle, -1.0, 1.0)
    outs = [jnp.ones_like(c), c]
    for _ in range(2, n_spherical):
        outs.append(2 * c * outs[-1] - outs[-2])
    return jnp.stack(outs[:n_spherical], axis=-1)


@dataclass(frozen=True)
class DimeNetBlock(Module):
    d_hidden: int
    n_radial: int
    n_spherical: int
    n_bilinear: int

    def __post_init__(self):
        d = self.d_hidden
        object.__setattr__(self, "w_msg", Linear(d, d))
        object.__setattr__(self, "w_kj", Linear(d, d, use_bias=False))
        object.__setattr__(self, "mlp_out", MLP((d, d, d), act=jax.nn.silu))

    def init(self, key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        nb = self.n_bilinear
        return {
            "w_msg": self.w_msg.init(k1),
            "w_kj": self.w_kj.init(k2),
            "w_sbf": init.lecun_normal(
                k3, (self.n_radial * self.n_spherical, nb)),
            # bilinear tensor [n_bilinear, d, d]
            "bilinear": init.normal(1.0 / self.d_hidden)(
                k4, (nb, self.d_hidden, self.d_hidden)),
            "mlp_out": self.mlp_out.init(k5),
        }

    def __call__(self, params, m, sbf, t_kj, t_ji, t_mask, n_edges):
        """m: [E,d] edge messages; sbf: [T, n_rad*n_sph]; t_*: [T] indices."""
        m_kj = self.w_kj(params["w_kj"], m)[t_kj]              # [T, d]
        a = sbf @ params["w_sbf"]                               # [T, nb]
        # bilinear: sum_b a[t,b] * (m_kj[t] @ bilinear[b]) -> [T, d]
        inter = jnp.einsum("tb,td,bdf->tf", a, m_kj, params["bilinear"])
        agg = segment.segment_sum(inter, t_ji, n_edges, t_mask)  # [E, d]
        h = self.w_msg(params["w_msg"], m) + agg
        return m + self.mlp_out(params["mlp_out"], jax.nn.silu(h))


@dataclass(frozen=True)
class DimeNet(Module):
    d_in: int
    d_hidden: int = 128
    n_blocks: int = 6
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_classes: int = 0

    def __post_init__(self):
        d = self.d_hidden
        object.__setattr__(self, "embed_x", Linear(self.d_in, d))
        object.__setattr__(self, "embed_m",
                           MLP((2 * d + self.n_radial, d), act=jax.nn.silu))
        blocks = tuple(DimeNetBlock(d, self.n_radial, self.n_spherical,
                                    self.n_bilinear)
                       for _ in range(self.n_blocks))
        object.__setattr__(self, "blocks", blocks)
        out_dim = self.n_classes if self.n_classes else 1
        object.__setattr__(self, "readout", MLP((d, d, out_dim), act=jax.nn.silu))

    def init(self, key):
        keys = jax.random.split(key, self.n_blocks + 3)
        p = {"embed_x": self.embed_x.init(keys[0]),
             "embed_m": self.embed_m.init(keys[1]),
             "readout": self.readout.init(keys[-1])}
        for i, b in enumerate(self.blocks):
            p[f"b{i}"] = b.init(keys[2 + i])
        return p

    def _geometry(self, g: Graph, t_kj, t_ji):
        vec = g.pos[g.receivers] - g.pos[g.senders]             # edge j->i vector
        r = jnp.linalg.norm(vec + 1e-9, axis=-1)
        rbf = bessel_basis(r, self.n_radial, self.cutoff)       # [E, n_radial]
        # angle between edge (k->j) and edge (j->i): vectors -v_kj and v_ji
        v_ji = vec[t_ji]
        v_kj = vec[t_kj]
        cos_a = jnp.sum(v_ji * (-v_kj), axis=-1) / (
            jnp.linalg.norm(v_ji + 1e-9, axis=-1)
            * jnp.linalg.norm(v_kj + 1e-9, axis=-1))
        ang = angular_basis(cos_a, self.n_spherical)            # [T, n_sph]
        sbf = (rbf[t_kj][:, :, None] * ang[:, None, :]).reshape(
            t_kj.shape[0], self.n_radial * self.n_spherical)
        return rbf, sbf

    def edge_messages(self, params, g: Graph, t_kj, t_ji, t_mask):
        assert g.pos is not None, "DimeNet needs positions"
        rbf, sbf = self._geometry(g, t_kj, t_ji)
        x = self.embed_x(params["embed_x"], g.x)
        m = self.embed_m(params["embed_m"], jnp.concatenate(
            [x[g.senders], x[g.receivers], rbf], axis=-1))      # [E, d]
        if g.edge_mask is not None:
            m = jnp.where(g.edge_mask[:, None], m, 0.0)
        for i, b in enumerate(self.blocks):
            m = b(params[f"b{i}"], m, sbf, t_kj, t_ji, t_mask, g.n_edges)
        return m

    def __call__(self, params, g: Graph, t_kj, t_ji, t_mask):
        m = self.edge_messages(params, g, t_kj, t_ji, t_mask)
        node_h = segment.segment_sum(m, g.receivers, g.n_nodes, g.edge_mask)
        out = self.readout(params["readout"], node_h)
        if self.n_classes:
            return out
        e_node = out[..., 0]
        if g.node_mask is not None:
            e_node = jnp.where(g.node_mask, e_node, 0.0)
        gids = g.graph_ids if g.graph_ids is not None else jnp.zeros(
            (g.n_nodes,), jnp.int32)
        return jax.ops.segment_sum(e_node, gids, g.n_graphs)

    def loss(self, params, g: Graph, targets, t_kj, t_ji, t_mask):
        out = self(params, g, t_kj, t_ji, t_mask)
        if self.n_classes:
            labels, mask = targets
            logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
            return jnp.sum(jnp.where(mask, -gold, 0.0)) / jnp.maximum(
                jnp.sum(mask), 1)
        return jnp.mean(jnp.square(out.astype(jnp.float32) - targets))
