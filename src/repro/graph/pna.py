"""Principal Neighbourhood Aggregation (PNA) — arXiv:2004.05718.

Assigned config: n_layers=4, d_hidden=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation. Message = MLP([x_u ; x_v]);
the 4 aggregators × 3 scalers concat to 12·d, compressed by a linear.

All four aggregators are synopses (std via (Σm, Σm², n)), so PNA is fully
streaming-compatible in the D3-GNN engine (DESIGN §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph, in_degree
from repro.nn.layers import Linear, MLP
from repro.nn.module import Module


@dataclass(frozen=True)
class PNALayer(Module):
    in_dim: int
    out_dim: int
    avg_log_deg: float = 1.0        # dataset statistic 'delta' from the paper
    act: bool = True

    def __post_init__(self):
        object.__setattr__(self, "pre", MLP((2 * self.in_dim, self.in_dim),
                                            act=jax.nn.relu))
        object.__setattr__(self, "post", Linear(12 * self.in_dim + self.in_dim,
                                                self.out_dim))

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"pre": self.pre.init(k1), "post": self.post.init(k2)}

    def __call__(self, params, g: Graph, x):
        m = self.pre(params["pre"],
                     jnp.concatenate([x[g.senders], x[g.receivers]], axis=-1))
        N, r, mask = g.n_nodes, g.receivers, g.edge_mask
        aggs = jnp.concatenate([
            segment.segment_mean(m, r, N, mask),
            segment.segment_max(m, r, N, mask),
            segment.segment_min(m, r, N, mask),
            segment.segment_std(m, r, N, mask),
        ], axis=-1)                                             # [N, 4d]
        deg = in_degree(g)
        logd = jnp.log(deg + 1.0)
        amp = (logd / self.avg_log_deg)[:, None]
        att = (self.avg_log_deg / jnp.maximum(logd, 1e-6))[:, None]
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)  # [N,12d]
        h = self.post(params["post"], jnp.concatenate([x, scaled], axis=-1))
        return jax.nn.relu(h) if self.act else h


@dataclass(frozen=True)
class PNA(Module):
    d_in: int
    d_hidden: int = 75
    n_layers: int = 4
    n_classes: int = 0
    avg_log_deg: float = 1.0

    def __post_init__(self):
        dims = [self.d_in] + [self.d_hidden] * self.n_layers
        layers = tuple(PNALayer(dims[i], dims[i + 1], self.avg_log_deg)
                       for i in range(self.n_layers))
        object.__setattr__(self, "layers", layers)
        if self.n_classes:
            object.__setattr__(self, "head", Linear(self.d_hidden, self.n_classes))

    def init(self, key):
        keys = jax.random.split(key, self.n_layers + 1)
        p = {f"l{i}": l.init(keys[i]) for i, l in enumerate(self.layers)}
        if self.n_classes:
            p["head"] = self.head.init(keys[-1])
        return p

    def __call__(self, params, g: Graph, x=None):
        x = g.x if x is None else x
        for i, l in enumerate(self.layers):
            x = l(params[f"l{i}"], g, x)
        if self.n_classes:
            return self.head(params["head"], x)
        return x

    def loss(self, params, g: Graph, labels, label_mask):
        logits = self(params, g).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        ce = jnp.where(label_mask, -gold, 0.0)
        return jnp.sum(ce) / jnp.maximum(jnp.sum(label_mask), 1)
