"""Graph Attention Network (arXiv:1710.10903) — one of the paper's
supported MPGNN instantiations (§3.3: "GCN, GraphSAGE, GAT, JK").

Edge attention is a segment-softmax over in-edges; note the STREAMING
caveat: softmax normalization is not an invertible synopsis, so GAT runs
exactly in the static/rebuild path while the streaming engine supports it
via windowed re-normalization (the paper's aggregator restrictions apply —
DESIGN §8)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.nn import initializers as init
from repro.nn.layers import Linear
from repro.nn.module import Module


@dataclass(frozen=True)
class GATLayer(Module):
    in_dim: int
    out_dim: int
    n_heads: int = 4
    act: bool = True

    def __post_init__(self):
        assert self.out_dim % self.n_heads == 0
        object.__setattr__(self, "w", Linear(self.in_dim, self.out_dim,
                                             use_bias=False))

    def init(self, key):
        kw, ka, kb = jax.random.split(key, 3)
        dh = self.out_dim // self.n_heads
        return {"w": self.w.init(kw),
                "a_src": init.lecun_normal(ka, (self.n_heads, dh)),
                "a_dst": init.lecun_normal(kb, (self.n_heads, dh))}

    def __call__(self, params, g: Graph, x):
        N, H = g.n_nodes, self.n_heads
        dh = self.out_dim // H
        h = self.w(params["w"], x).reshape(N, H, dh)
        e_src = jnp.einsum("nhd,hd->nh", h, params["a_src"].astype(h.dtype))
        e_dst = jnp.einsum("nhd,hd->nh", h, params["a_dst"].astype(h.dtype))
        scores = jax.nn.leaky_relu(
            e_src[g.senders] + e_dst[g.receivers], 0.2)     # [E, H]
        alpha = jnp.stack(
            [segment.segment_softmax(scores[:, i], g.receivers, N,
                                     g.edge_mask) for i in range(H)], axis=1)
        msgs = h[g.senders] * alpha[..., None]
        agg = segment.segment_sum(msgs, g.receivers, N, g.edge_mask)
        out = agg.reshape(N, self.out_dim)
        return jax.nn.elu(out) if self.act else out


@dataclass(frozen=True)
class GAT(Module):
    dims: tuple
    n_heads: int = 4
    n_classes: int = 0

    def __post_init__(self):
        n = len(self.dims) - 1
        layers = tuple(GATLayer(self.dims[i], self.dims[i + 1], self.n_heads,
                                act=(i < n - 1 or self.n_classes > 0))
                       for i in range(n))
        object.__setattr__(self, "layers", layers)
        if self.n_classes:
            object.__setattr__(self, "head", Linear(self.dims[-1],
                                                    self.n_classes))

    def init(self, key):
        keys = jax.random.split(key, len(self.layers) + 1)
        p = {f"l{i}": l.init(keys[i]) for i, l in enumerate(self.layers)}
        if self.n_classes:
            p["head"] = self.head.init(keys[-1])
        return p

    def __call__(self, params, g: Graph, x=None):
        x = g.x if x is None else x
        for i, l in enumerate(self.layers):
            x = l(params[f"l{i}"], g, x)
        if self.n_classes:
            return self.head(params["head"], x)
        return x
