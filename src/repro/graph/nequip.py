"""NequIP — E(3)-equivariant interatomic potential (arXiv:2101.03164).

Assigned config: n_layers=5, d_hidden(mult)=32, l_max=2, n_rbf=8, cutoff=5,
E(3) tensor-product equivariance.

Structure (faithful to the paper at l_max=2):
  * node features are direct sums of irreps: {l: [N, mult, 2l+1]}
  * each interaction layer computes, per edge, radially-weighted
    Clebsch-Gordan tensor products between sender features (l_in) and the
    edge's real spherical harmonics (l_f), summed into each allowed l_out,
  * messages aggregate at receivers with segment_sum (an invertible synopsis
    — the D3-GNN streaming property holds; DESIGN §4),
  * update = self-interaction linear (per-l channel mixing) + gated
    nonlinearity (scalars: silu; l>0: sigmoid gates generated from scalars).

Hardware note: the CG contraction is einsum over (mult × (2l+1)) blocks —
small dense tensors that map to the MXU after batching over edges; the
gather/scatter halves route through kernels/segment_reduce on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from math import sqrt

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.graph.so3 import coupling_tensor, real_sph_harm
from repro.nn import initializers as init
from repro.nn.layers import MLP, Linear
from repro.nn.module import Module


def bessel_basis(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """sqrt(2/c) sin(n pi r / c) / r with smooth polynomial envelope (p=6)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    r = jnp.maximum(r, 1e-6)
    b = sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * r[..., None] / cutoff) / r[..., None]
    return b * poly_envelope(r / cutoff, p=6)[..., None]


def poly_envelope(x: jnp.ndarray, p: int = 6) -> jnp.ndarray:
    a = -(p + 1) * (p + 2) / 2
    b = p * (p + 2)
    c = -p * (p + 1) / 2
    env = 1 + a * x ** p + b * x ** (p + 1) + c * x ** (p + 2)
    return jnp.where(x < 1.0, env, 0.0)


def allowed_paths(l_max: int):
    paths = []
    for l_in in range(l_max + 1):
        for l_f in range(l_max + 1):
            for l_out in range(abs(l_in - l_f), min(l_max, l_in + l_f) + 1):
                paths.append((l_in, l_f, l_out))
    return tuple(paths)


@dataclass(frozen=True)
class NequIPLayer(Module):
    mult: int
    l_max: int
    n_rbf: int
    avg_degree: float = 8.0

    def __post_init__(self):
        object.__setattr__(self, "paths", allowed_paths(self.l_max))
        n_paths = len(self.paths)
        # radial net: rbf -> hidden -> per-path per-channel weights
        object.__setattr__(self, "radial",
                           MLP((self.n_rbf, 64, n_paths * self.mult),
                               act=jax.nn.silu))

    def init(self, key):
        ks = jax.random.split(key, 3 + 2 * (self.l_max + 1))
        p = {"radial": self.radial.init(ks[0])}
        # self-interaction + post-aggregation linear mixing, per l
        for l in range(self.l_max + 1):
            p[f"self_l{l}"] = init.lecun_normal(ks[1 + 2 * l],
                                                (self.mult, self.mult))
            p[f"mix_l{l}"] = init.lecun_normal(ks[2 + 2 * l],
                                               (self.mult, self.mult))
        # gates for l>0 generated from scalars
        p["gate"] = init.lecun_normal(ks[-1], (self.mult, self.l_max * self.mult))
        return p

    def __call__(self, params, g: Graph, feats: dict, sh: dict, rbf: jnp.ndarray):
        """feats: {l: [N, mult, 2l+1]}; sh: {l: [E, 2l+1]}; rbf: [E, n_rbf]."""
        E = g.n_edges
        R = self.radial(params["radial"], rbf)                 # [E, P*mult]
        R = R.reshape(E, len(self.paths), self.mult)
        agg = {l: jnp.zeros_like(v) for l, v in feats.items()}
        norm = 1.0 / sqrt(self.avg_degree)
        for pidx, (l_in, l_f, l_out) in enumerate(self.paths):
            W = jnp.asarray(coupling_tensor(l_in, l_f, l_out),
                            dtype=feats[l_in].dtype)           # [2li+1,2lf+1,2lo+1]
            xs = feats[l_in][g.senders]                        # [E, mult, 2li+1]
            msg = jnp.einsum("eci,ej,ijk->eck", xs, sh[l_f], W)
            msg = msg * R[:, pidx, :, None]                    # radial weighting
            agg[l_out] = agg[l_out] + segment.segment_sum(
                msg, g.receivers, g.n_nodes, g.edge_mask) * norm
        # update: self-interaction + mixed aggregate, then gate
        new = {}
        for l in range(self.l_max + 1):
            h = (jnp.einsum("ncx,cd->ndx", feats[l], params[f"self_l{l}"])
                 + jnp.einsum("ncx,cd->ndx", agg[l], params[f"mix_l{l}"]))
            new[l] = h
        scal = new[0][..., 0]                                   # [N, mult]
        gates = jax.nn.sigmoid(scal @ params["gate"])           # [N, l_max*mult]
        out = {0: jax.nn.silu(scal)[..., None]}
        for l in range(1, self.l_max + 1):
            gl = gates[:, (l - 1) * self.mult: l * self.mult]
            out[l] = new[l] * gl[..., None]
        return out


@dataclass(frozen=True)
class NequIP(Module):
    d_in: int
    mult: int = 32
    l_max: int = 2
    n_layers: int = 5
    n_rbf: int = 8
    cutoff: float = 5.0
    n_classes: int = 0      # 0 = energy regression (molecule shapes)
    avg_degree: float = 8.0

    def __post_init__(self):
        object.__setattr__(self, "embed", Linear(self.d_in, self.mult))
        layers = tuple(NequIPLayer(self.mult, self.l_max, self.n_rbf,
                                   self.avg_degree)
                       for _ in range(self.n_layers))
        object.__setattr__(self, "layers", layers)
        out_dim = self.n_classes if self.n_classes else 1
        object.__setattr__(self, "readout", MLP((self.mult, self.mult, out_dim),
                                                act=jax.nn.silu))

    def init(self, key):
        keys = jax.random.split(key, self.n_layers + 2)
        p = {"embed": self.embed.init(keys[0]),
             "readout": self.readout.init(keys[-1])}
        for i, l in enumerate(self.layers):
            p[f"l{i}"] = l.init(keys[1 + i])
        return p

    def node_features(self, params, g: Graph):
        assert g.pos is not None, "NequIP needs positions"
        vec = g.pos[g.receivers] - g.pos[g.senders]
        r = jnp.linalg.norm(vec + 1e-9, axis=-1)
        sh = real_sph_harm(vec, self.l_max)
        rbf = bessel_basis(r, self.n_rbf, self.cutoff)
        if g.edge_mask is not None:
            rbf = jnp.where(g.edge_mask[:, None], rbf, 0.0)
        feats = {0: self.embed(params["embed"], g.x)[..., None]}
        for l in range(1, self.l_max + 1):
            feats[l] = jnp.zeros((g.n_nodes, self.mult, 2 * l + 1), g.x.dtype)
        for i, layer in enumerate(self.layers):
            feats = layer(params[f"l{i}"], g, feats, sh, rbf)
        return feats

    def __call__(self, params, g: Graph):
        """Energy per graph [n_graphs] (or per-node logits if n_classes)."""
        feats = self.node_features(params, g)
        out = self.readout(params["readout"], feats[0][..., 0])
        if self.n_classes:
            return out                                          # [N, n_classes]
        e_node = out[..., 0]
        if g.node_mask is not None:
            e_node = jnp.where(g.node_mask, e_node, 0.0)
        gids = g.graph_ids if g.graph_ids is not None else jnp.zeros(
            (g.n_nodes,), jnp.int32)
        return jax.ops.segment_sum(e_node, gids, g.n_graphs)

    def loss(self, params, g: Graph, targets, *_):
        """MSE energy loss (molecule shapes) or CE (node classification)."""
        out = self(params, g)
        if self.n_classes:
            labels, mask = targets
            logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
            return jnp.sum(jnp.where(mask, -gold, 0.0)) / jnp.maximum(
                jnp.sum(mask), 1)
        return jnp.mean(jnp.square(out.astype(jnp.float32) - targets))
