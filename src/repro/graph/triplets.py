"""Triplet index construction for directional message passing (DimeNet).

A triplet (k -> j -> i) pairs each directed edge e1=(j,i) with every
in-edge e2=(k,j) of its source, k != i. DimeNet's interaction blocks gather
messages m_kj for every triplet, modulate them by an angular basis of
angle(k,j,i), and scatter-sum into m_ji.

Triplet counts are data-dependent (sum over edges of in-degree(src)); for
static XLA shapes we cap at `t_max` and mask — the cap is a config knob
(dry-run uses 4x n_edges; see DESIGN).

Host-side (numpy) construction — this runs in the data pipeline, like
neighbor sampling, not inside jit.
"""
from __future__ import annotations

import numpy as np


def build_triplets(senders: np.ndarray, receivers: np.ndarray, n_nodes: int,
                   t_max: int):
    """Returns (edge_kj [T], edge_ji [T], mask [T]) int32 edge indices."""
    E = len(senders)
    # in-edges per node: CSR over receivers
    order = np.argsort(receivers, kind="stable")
    sorted_recv = receivers[order]
    starts = np.searchsorted(sorted_recv, np.arange(n_nodes))
    ends = np.searchsorted(sorted_recv, np.arange(n_nodes) + 1)

    e_kj, e_ji = [], []
    total = 0
    for e1 in range(E):
        j, i = senders[e1], receivers[e1]
        lo, hi = starts[j], ends[j]
        for idx in range(lo, hi):
            e2 = order[idx]
            if senders[e2] == i:          # exclude backtracking k == i
                continue
            e_kj.append(e2)
            e_ji.append(e1)
            total += 1
            if total >= t_max:
                break
        if total >= t_max:
            break
    T = len(e_kj)
    out_kj = np.zeros(t_max, np.int32)
    out_ji = np.zeros(t_max, np.int32)
    mask = np.zeros(t_max, bool)
    out_kj[:T] = e_kj
    out_ji[:T] = e_ji
    mask[:T] = True
    return out_kj, out_ji, mask


def triplet_count(senders: np.ndarray, receivers: np.ndarray, n_nodes: int) -> int:
    """Exact number of (k->j->i) triplets (without the k != i exclusion)."""
    in_deg = np.bincount(receivers, minlength=n_nodes)
    return int(np.sum(in_deg[senders]))
