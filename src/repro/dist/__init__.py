"""Distributed-runtime modules: sharding rules, compressed gradient
exchange, explicit expert parallelism and vertex-cut GNN locality.

Everything here is mesh-facing: the single-device engine (repro/core)
never imports this package, so CPU test runs stay import-light; the
dry-run, the perf variants and the multi-device subprocess tests do.
"""
