"""Distributed-runtime modules: the streaming engine's routing plane
(router.py) and carry sharding rules (sharding.py), compressed gradient
exchange, explicit expert parallelism and vertex-cut GNN locality.

`router.py` and `sharding.py` are the light, jax-only pieces the core
engine imports (LocalRouter is the single-device default router of the
tick program); the rest is mesh-facing only — the dry-run, the perf
variants and the multi-device tests import it, so CPU test runs stay
import-light.
"""
