"""Wire format of the routing plane: one packed f32 buffer per lane.

The pre-ISSUE-5 `MeshRouter.route` exchanged every field of a routed
batch as its OWN `lax.all_to_all` (a MsgBatch is 6 leaves -> 6 collective
launches per round, a QueryBatch 11). The packed wire format fuses a
lane's fields into ONE [C, W] float32 buffer — integer fields are
value-cast (exact for |v| < 2**24, see below), bools become 0/1 — so a
whole lane (and, via `MeshRouter.route_lanes`, SEVERAL lanes) crosses
the mesh in a single collective. The same packed rows are what the
per-lane defer ring carries across ticks (`route_cap` backpressure):
deferred records re-enter the next tick's exchange by simple
concatenation, no re-materialization of the typed batch.

Layout contract: columns follow the batch dataclass's registered
data_fields order; a [C] field takes one column, a [C, d] field takes d.
`field_col` resolves a field name to its column (the router needs the
`part` column to re-derive destinations for carried rows).

Integer transport is VALUE-cast, not bit-cast, because the Pallas
`route_pack` placement runs the rows through a one-hot MXU matmul
(`segment_reduce` machinery) where bit-cast int patterns would be
NaN/Inf-poisonous. Exactness holds for |v| < 2**24 — parts, slots,
ticks and kinds by construction; host-assigned qids must respect it
(documented in serve/query.py).
"""
from __future__ import annotations

from dataclasses import fields as dc_fields

import jax
import jax.numpy as jnp


def _leaf_width(leaf) -> int:
    if leaf.ndim == 1:
        return 1
    assert leaf.ndim == 2, f"wire leaves are [C] or [C, d], got {leaf.shape}"
    return leaf.shape[1]


def lane_width(batch) -> int:
    """Total packed row width W of a part-addressed batch pytree."""
    return sum(_leaf_width(l) for l in jax.tree.leaves(batch))


def field_col(batch, name: str) -> int:
    """First packed column of scalar field `name` (dataclass field order ==
    registered data_fields order == tree-leaf order for every batch)."""
    off = 0
    leaves = jax.tree.leaves(batch)
    for f, leaf in zip(dc_fields(batch), leaves):
        if f.name == name:
            return off
        off += _leaf_width(leaf)
    raise KeyError(f"{type(batch).__name__} has no field {name!r}")


def pack_lane(batch) -> jnp.ndarray:
    """Batch pytree (capacity C) -> packed [C, W] float32 wire rows."""
    cols = []
    for leaf in jax.tree.leaves(batch):
        x = leaf.astype(jnp.float32)
        cols.append(x[:, None] if x.ndim == 1 else x)
    return jnp.concatenate(cols, axis=1)


def unpack_lane(buf: jnp.ndarray, proto):
    """Packed [R, W] rows -> a batch like `proto` with capacity R.

    `proto` only contributes structure/dtypes/trailing dims; its capacity
    is ignored (delivered capacity is the wire's D * cap rows).
    """
    leaves, treedef = jax.tree.flatten(proto)
    out, off = [], 0
    for l in leaves:
        w = _leaf_width(l)
        sl = buf[:, off:off + w]
        off += w
        if l.ndim == 1:
            sl = sl[:, 0]
        if l.dtype == jnp.bool_:
            sl = sl > 0.5
        else:
            sl = sl.astype(l.dtype)       # exact: ints ride as exact floats
        out.append(sl)
    assert off == buf.shape[1], \
        f"wire width mismatch: proto wants {off}, buffer has {buf.shape[1]}"
    return jax.tree.unflatten(treedef, out)


def pad_lane(rows: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Zero-pad packed wire rows [C, W] up to [capacity, W].

    Zero rows unpack as valid=False padding (every lane batch carries a
    bool `valid` column; 0.0 > 0.5 is False), so padded rows are inert at
    delivery. The inter-stage ring of the hybrid-parallel pipeline uses
    this to give the host feature inbox (capacity feat_cap) and the layer
    outboxes (capacity P_loc * cap_pp) ONE common slot shape, letting a
    single `stage_shift` ppermute carry either."""
    C, W = rows.shape
    assert C <= capacity, f"pad_lane: rows {C} exceed slot capacity {capacity}"
    if C == capacity:
        return rows
    return jnp.concatenate(
        [rows, jnp.zeros((capacity - C, W), rows.dtype)])


def init_defer(rows: int, width: int):
    """An empty defer ring: (packed rows [rows, width] f32, occupied [rows]).

    rows == 0 compiles the backpressure path away (the dense default)."""
    return (jnp.zeros((rows, width), jnp.float32),
            jnp.zeros((rows,), bool))
