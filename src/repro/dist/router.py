"""The routing plane: transport of part-addressed `MsgBatch` records.

The streaming tick is split into three planes (ISSUE 2 + ISSUE 3):

  * COMPUTE plane — pure part-local stages in `core/tick.py`
    (`round_a_apply`, `round_b_emit`, `apply_rmis`, `forward_psi`) that
    never write into another part's rows; every cross-part effect is a
    `MsgBatch` (core/events.py) addressed by global (part, slot).
  * ROUTING plane — a Router moves those records to whichever device
    owns the destination part. Two golden-equivalent implementations:

      LocalRouter : one device owns every part; transport is the identity.
      MeshRouter  : parts are block-sharded over a 1-D ("data",) mesh axis
                    (`launch/mesh.py`); transport buckets records by
                    destination device and exchanges them with ONE
                    fixed-capacity `lax.all_to_all` per round. Per-bucket
                    capacity equals the full emission capacity C, so no
                    record can ever overflow a bucket (worst case: all C
                    records target one device) — correctness never depends
                    on traffic shape, at the price of a D x C exchange.
  * DELIVERY plane — once routed, a DeliveryBackend (`core/delivery.py`)
    lands the records in the local state blocks: "xla" reference scatters
    or "pallas" sorted segment-reduce kernels, selected by
    `PipelineConfig.delivery_backend` and orthogonal to the Router choice.
  * QUERY plane — `repro/serve/query.py` answers point queries from the
    state the other three maintain; its link-score forwarding hop rides
    `route` as one extra fixed-capacity all_to_all lane per tick
    (`route` is generic over any part-addressed batch pytree).

Routers are small frozen dataclasses so they can ride jit boundaries as
static arguments. `MeshRouter` methods are only valid INSIDE a
`shard_map` over its axis (they call `lax.axis_index`/`lax.all_to_all`);
`LocalRouter` works anywhere. `psum` abstracts the cross-device reduction
used for scalar TickStats, quiescence voting and the replicated
CountMinSketch update (identity on one device).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class LocalRouter:
    """Single-device router: every part is local, delivery is identity."""
    n_parts: int

    @property
    def n_devices(self) -> int:
        return 1

    @property
    def n_local_parts(self) -> int:
        return self.n_parts

    def part0(self):
        """Global id of the first locally-owned part."""
        return jnp.int32(0)

    def route(self, msg):
        return msg

    def psum(self, x):
        return x


@dataclass(frozen=True)
class MeshRouter:
    """Sharded router: parts block-sharded over `axis`, all_to_all delivery.

    Device d owns parts [d * Pl, (d + 1) * Pl) with Pl = n_parts
    // n_devices (validated by PipelineConfig.validate). Must run inside a
    shard_map over `axis` whose size is exactly `n_devices`.
    """
    n_parts: int
    n_devices: int
    axis: str = "data"

    @property
    def n_local_parts(self) -> int:
        return self.n_parts // self.n_devices

    def part0(self):
        return lax.axis_index(self.axis).astype(jnp.int32) * \
            jnp.int32(self.n_local_parts)

    def psum(self, x):
        return lax.psum(x, self.axis)

    def route(self, msg):
        """Deliver records to the devices owning their destination parts.

        Generic over any part-addressed batch pytree with `part`/`valid`
        fields (`MsgBatch` for the compute plane's two rounds, the query
        plane's `QueryBatch` wire lane): compaction ranks each valid
        record among records bound for the same destination device
        (cumsum over a one-hot [C, D] membership), scatters into a
        [D, C] send buffer per field, one all_to_all, and returns the
        [D * C] received rows (block j = what device j sent here) —
        preserving global (source part, slot) record order, so delivery
        is order-identical to the LocalRouter's. Invalid rows and empty
        bucket tails stay masked out.
        """
        D = self.n_devices
        if D == 1:
            return msg
        Pl = self.n_local_parts
        C = msg.valid.shape[0]
        dst_dev = jnp.clip(msg.part // Pl, 0, D - 1)
        member = (jnp.where(msg.valid, dst_dev, D)[:, None]
                  == jnp.arange(D)[None, :])                      # [C, D]
        pos = jnp.cumsum(member.astype(jnp.int32), axis=0) - 1
        pos_row = jnp.sum(jnp.where(member, pos, 0), axis=1)      # [C]
        send_idx = jnp.where(msg.valid, dst_dev * C + pos_row, D * C)

        def bucket(x):
            buf = jnp.zeros((D * C,) + x.shape[1:], x.dtype)
            return buf.at[send_idx].set(x, mode="drop")

        ex = lambda x: lax.all_to_all(x, self.axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        return jax.tree.map(lambda x: ex(bucket(x)), msg)
