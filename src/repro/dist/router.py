"""The routing plane: transport of part-addressed record batches.

The streaming tick is split into six planes (ISSUE 2-5, 8, 9):

  * COMPUTE plane — pure part-local stages in `core/tick.py`
    (`round_a_apply`, `round_b_emit`, `apply_rmis`, `forward_psi`) that
    never write into another part's rows; every cross-part effect is a
    `MsgBatch` (core/events.py) addressed by global (part, slot).
  * ROUTING plane — a Router moves those records to whichever device
    owns the destination part. Two golden-equivalent implementations:

      LocalRouter : one device owns every part; transport is the identity.
      MeshRouter  : parts are block-sharded over a 1-D ("data",) mesh axis
                    (`launch/mesh.py`); transport compacts records by
                    destination device and exchanges them with ONE
                    `lax.all_to_all` per `route_lanes` call — ALL fields
                    of ALL lanes in the call ride a single packed wire
                    buffer (`dist/wire.py`), so a MsgBatch round costs one
                    collective launch instead of one per field, and the
                    round-B RMI lane + the query-plane wire lane share one
                    launch per tick (ISSUE 5 lane fusion).
  * DELIVERY plane — once routed, a DeliveryBackend (`core/delivery.py`)
    lands the records in the local state blocks: "xla" reference scatters
    or "pallas" sorted segment-reduce kernels, selected by
    `PipelineConfig.delivery_backend` and orthogonal to the Router choice.
  * QUERY plane — `repro/serve/query.py` answers point queries from the
    state the other three maintain; its link-score wire hop rides
    `route_lanes` fused with layer 0's round-B exchange.
  * TRAINING plane — `repro/core/train_plane.py` (ISSUE 8) runs a
    windowed online training step at the end of the tick; its layered
    backward ships dL/dagg to replicas and folds replica gradients onto
    masters through two dense `route_lanes` calls per layer, and its
    parameter averaging (Alg. 3) rides `psum`.
  * TELEMETRY plane — `repro/telemetry/` (ISSUE 9) watches the other
    five: with `MeshRouter.telemetry=True` each exchange also reports
    its peak pre-cap bucket demand (`RouteReceipt.peak`, the zero-defer
    route_cap), reduced over the mesh with `pmax`/`pmax_stage`.

Hybrid parallelism (ISSUE 7): on a 2-D ("stage", "data") mesh the L GNN
layers are placed round-robin on the stage axis (layer l lives on stage
l % S) and MeshRouter gains a second, inter-stage lane: `stage_shift`
posts each round's outbox to the next stage with one circular
`lax.ppermute` immediately after that round's compute (double-buffered —
the hop for round r overlaps round r+1's intra-stage all_to_all), and
`stage_last` rides the final stage's exchange back so every stage can
apply the same sink update. All data-plane collectives (`route_lanes`,
`psum`, `part0`) stay scoped to the "data" axis — inside a stage row
they behave exactly as on the 1-D mesh — while quiescence/silence VOTES
go through `psum_vote` (both axes) so no stage can declare the dataflow
quiet while another still has records in flight.

Traffic-adaptive capped exchange (ISSUE 5 tentpole): the per-destination
send bucket holds `route_cap` rows (default None = the lane's full
emission capacity C — the pre-ISSUE-5 worst-case sizing, under which no
record can ever overflow and the exchange is bit-for-bit the dense one).
With `route_cap < C` the wire shrinks from D x C to D x cap rows per
lane; live records that overflow their bucket are NOT dropped — they are
deferred into a per-lane carry ring (packed rows riding the
`PipelineCarry`, see `dist/wire.py:init_defer`) and re-enter the next
tick's exchange AHEAD of fresh emissions (FIFO per destination, which
keeps feature-broadcast ordering intact). Quiescence voting counts defer
occupancy as pending work (`core/tick.py:has_work`), so a flush never
terminates with records still in flight. Only a defer ring that is
ITSELF full drops rows, and loudly: the per-tick `RouteReceipt.dropped`
count surfaces in TickStats/StreamMetrics — size `route_defer_cap`
accordingly (default: one full emission capacity per lane).

Delta-gated traffic (ISSUE 6): in approximate mode (cfg.delta_eps > 0)
the compute plane suppresses sub-eps re-emissions AND pre-coalesces
same-destination RMI records before handing the lane to `route_lanes`
(`core/events.py:coalesce_msg_batch`), so the capped buckets see one
live row per distinct destination master instead of one per out-edge.
TickStats.reduce_msgs/n_suppressed count at EMISSION time (pre-
coalesce); RouteReceipt.rows counts the wire — their gap is the
coalescing win, visible in `benchmarks/bench_delta_gating.py`.

Compaction uses `kernels/route_pack`: one stable sort by destination +
rank-from-run-start (replacing the O(C * D) one-hot membership cumsum),
with the placement scatter runnable as a Pallas one-hot-MXU pass
(`pack_backend="pallas"`, reusing the segment_reduce machinery) or a
plain XLA scatter (`"xla"`). Invalid destination parts are MASKED OUT of
the exchange (pre-ISSUE-5 the `jnp.clip(part // Pl, 0, D-1)` silently
misrouted them to the last device, where they burned bucket capacity
before being dropped at delivery).

Routers are small frozen dataclasses so they can ride jit boundaries as
static arguments. `MeshRouter` methods are only valid INSIDE a
`shard_map` over its axis (they call `lax.axis_index`/`lax.all_to_all`);
`LocalRouter` works anywhere. `psum` abstracts the cross-device reduction
used for scalar TickStats, quiescence voting and the replicated
CountMinSketch update (identity on one device).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.wire import field_col, pack_lane, unpack_lane
from repro.kernels.route_pack.ops import route_pack, route_plan


@dataclass(frozen=True)
class RouteReceipt:
    """Measured wire telemetry of one route_lanes call (int32 scalars,
    local to the calling device — the tick body psums them into
    TickStats so StreamMetrics reports EXACT exchanged rows).

      rows     : live records actually shipped on the wire this call;
      deferred : live records pushed into the defer rings (backpressure);
      dropped  : live records lost to a FULL defer ring (loud — see
                 module docstring; 0 in any correctly-sized config);
      peak     : telemetry plane (ISSUE 9) — the call's MAX per-
                 destination bucket demand BEFORE capping (carried +
                 fresh live rows aimed at the busiest device). This is
                 the zero-defer route_cap for the traffic the call saw;
                 static 0 unless MeshRouter.telemetry is set. Combined
                 across calls with `jnp.maximum` (see add_receipts) — a
                 peak gauge, never a sum.

    Wire BYTES are deliberately absent: the send-buffer size of a
    route_lanes call is a compile-time constant of (lanes, caps), so the
    pipeline accounts bytes host-side in exact int arithmetic
    (`D3Pipeline._static_wire_bytes`) instead of rounding them through a
    device float or overflowing an int32.
    """
    rows: jnp.ndarray
    deferred: jnp.ndarray
    dropped: jnp.ndarray
    peak: jnp.ndarray


jax.tree_util.register_dataclass(
    RouteReceipt, data_fields=["rows", "deferred", "dropped", "peak"],
    meta_fields=[])


def zero_receipt() -> RouteReceipt:
    z = jnp.zeros((), jnp.int32)
    return RouteReceipt(rows=z, deferred=z, dropped=z, peak=z)


def add_receipts(a: RouteReceipt, b: RouteReceipt) -> RouteReceipt:
    """Field-wise combine: counters add, the peak gauge maxes (summing a
    per-call maximum would be meaningless)."""
    return RouteReceipt(rows=a.rows + b.rows,
                        deferred=a.deferred + b.deferred,
                        dropped=a.dropped + b.dropped,
                        peak=jnp.maximum(a.peak, b.peak))


@dataclass(frozen=True)
class LocalRouter:
    """Single-device router: every part is local, delivery is identity."""
    n_parts: int

    @property
    def n_devices(self) -> int:
        return 1

    @property
    def n_local_parts(self) -> int:
        return self.n_parts

    def part0(self):
        """Global id of the first locally-owned part."""
        return jnp.int32(0)

    def route(self, msg):
        return msg

    def route_lanes(self, lanes, defers):
        """No wire: lanes deliver as-is, defer rings stay empty (they are
        zero-capacity under this router — see core/pipeline.py)."""
        return tuple(lanes), tuple(defers), zero_receipt()

    def psum(self, x):
        return x

    def pmax(self, x):
        return x

    # stage-axis interface (trivial here: LocalRouter never runs with
    # n_stages > 1 — PipelineConfig.validate rejects the combination —
    # but shared code paths in serve/termination call these)
    n_stages = 1

    def psum_stage(self, x):
        return x

    def pmax_stage(self, x):
        return x

    def psum_vote(self, x):
        return x

    def stage_gather(self, x):
        """All stages' copies of `x`, leading [S] axis ([1] here)."""
        return x[None]


@dataclass(frozen=True)
class MeshRouter:
    """Sharded router: parts block-sharded over `axis`, packed capped
    all_to_all delivery.

    Device d owns parts [d * Pl, (d + 1) * Pl) with Pl = n_parts
    // n_devices (validated by PipelineConfig.validate). Must run inside a
    shard_map over `axis` whose size is exactly `n_devices`.

    route_cap   : per-destination send-bucket rows (None = each lane's
                  full capacity — never-overflow dense semantics).
    pack_backend: how route_pack places rows into the send buffer
                  ("xla" scatter | "pallas" one-hot MXU pass); follows
                  PipelineConfig.delivery_backend.
    stage_axis  : name of the pipeline-stage mesh axis, or None on the
                  1-D mesh. n_devices always counts the DATA axis only —
                  parts shard within a stage row, never across stages.
    telemetry   : telemetry plane (ISSUE 9) — when True each route_lanes
                  call also measures its peak per-destination bucket
                  demand pre-cap (RouteReceipt.peak); when False (the
                  default) the gauge is a static 0 and the measurement
                  compiles away, keeping the exchange bit-for-bit.
    """
    n_parts: int
    n_devices: int
    axis: str = "data"
    route_cap: Optional[int] = None
    pack_backend: str = "xla"
    stage_axis: Optional[str] = None
    n_stages: int = 1
    telemetry: bool = False

    @property
    def n_local_parts(self) -> int:
        return self.n_parts // self.n_devices

    def part0(self):
        return lax.axis_index(self.axis).astype(jnp.int32) * \
            jnp.int32(self.n_local_parts)

    def psum(self, x):
        return lax.psum(x, self.axis)

    def pmax(self, x):
        """Max-reduce over the data axis (peak gauges, ISSUE 9)."""
        return lax.pmax(x, self.axis)

    # ---- stage-axis interface (hybrid parallelism, ISSUE 7) ----------
    # Valid inside a shard_map that names `stage_axis`; on a 1-D router
    # (stage_axis=None) every method degrades to its data-plane
    # counterpart so shared call sites trace the exact pre-ISSUE-7 HLO.

    def psum_stage(self, x):
        """Reduce over the stage axis only (identity on a 1-D mesh)."""
        if self.stage_axis is None:
            return x
        return lax.psum(x, self.stage_axis)

    def pmax_stage(self, x):
        """Max-reduce over the stage axis only (identity on a 1-D mesh) —
        peak gauges cross the stage axis with max, never sum."""
        if self.stage_axis is None:
            return x
        return lax.pmax(x, self.stage_axis)

    def psum_vote(self, x):
        """Global reduction for quiescence/silence votes: both axes on a
        2-D mesh, plain data psum on a 1-D mesh."""
        if self.stage_axis is None:
            return lax.psum(x, self.axis)
        return lax.psum(x, (self.stage_axis, self.axis))

    def stage_index(self):
        return lax.axis_index(self.stage_axis).astype(jnp.int32)

    def stage_shift(self, rows):
        """Post packed rows to the next stage: one circular ppermute
        (stage s -> s + 1 mod S) within each data column. Called right
        after each round's compute so the hop is double-buffered behind
        the next round's work."""
        S = self.n_stages
        return lax.ppermute(rows, self.stage_axis,
                            [(i, (i + 1) % S) for i in range(S)])

    def stage_last(self, rows):
        """Every stage's copy of the LAST stage's rows (the final GNN
        layer lives on stage S-1; its outbox must reach every stage's
        replicated sink/serve plane in the same tick)."""
        return lax.all_gather(rows, self.stage_axis)[self.n_stages - 1]

    def stage_gather(self, x):
        """Every stage's copy of `x`, leading [S] axis — the training
        plane gathers all rounds' layer caches so each stage row can run
        the full (stage-replicated) layered backward."""
        if self.stage_axis is None:
            return x[None]
        return lax.all_gather(x, self.stage_axis)

    def lane_cap(self, capacity: int) -> int:
        """Resolved per-destination bucket rows for a lane of the given
        local emission capacity."""
        if self.route_cap is None:
            return capacity
        return max(1, min(self.route_cap, capacity))

    def route_lanes(self, lanes, defers):
        """Deliver several record lanes with ONE all_to_all.

        lanes : tuple of part-addressed batch pytrees with `part`/`valid`
                fields (MsgBatch, QueryBatch, ...), local capacities C_i.
        defers: matching tuple of (packed rows [K_i, W_i] f32, occupied
                [K_i] bool) carry rings; K_i = 0 disables backpressure
                for that lane (then bucket overflow — impossible at the
                dense default — would drop, counted).

        Per lane: carried rows re-enter FIRST, fresh emissions after
        (stable destination sort keeps FIFO per destination, so a
        replica's feature broadcasts always apply in emission order);
        the first `lane_cap(C_i)` records per destination ship, the rest
        defer. Send buffers are concatenated along the row axis so the
        whole call is a single [D, sum_i cap_i * W_i] tiled all_to_all.

        Returns (delivered lanes tuple — capacity D * cap_i each, block
        j = what device j sent here, rank order within a block = source
        emission order; new defers tuple; RouteReceipt).
        """
        D = self.n_devices
        if D == 1:
            return tuple(lanes), tuple(defers), zero_receipt()
        Pl = self.n_local_parts

        sends, metas, new_defers = [], [], []
        n_ship = jnp.zeros((), jnp.int32)
        n_defer = jnp.zeros((), jnp.int32)
        n_drop = jnp.zeros((), jnp.int32)
        n_peak = jnp.zeros((), jnp.int32)
        for lane, (dbuf, dok) in zip(lanes, defers):
            packed = pack_lane(lane)                           # [C, W]
            C, W = packed.shape
            K = dbuf.shape[0]
            cap = self.lane_cap(C)
            allp = jnp.concatenate([dbuf, packed]) if K else packed
            parts = allp[:, field_col(lane, "part")].astype(jnp.int32)
            # mask invalid destinations OUT of the exchange (never clip
            # onto the last device) — deferred rows only ever hold valid
            # records, their occupancy flag is the live mask
            fresh_ok = (lane.valid & (lane.part >= 0)
                        & (lane.part < self.n_parts))
            ok = jnp.concatenate([dok, fresh_ok]) if K else fresh_ok
            dst = jnp.where(ok, parts // Pl, D)
            if self.telemetry:
                # peak per-destination demand BEFORE capping: the
                # route_cap at which this lane would never defer
                demand = jnp.zeros((D,), jnp.int32).at[dst].add(
                    ok.astype(jnp.int32), mode="drop")
                n_peak = jnp.maximum(n_peak, jnp.max(demand))

            order, ship_s, slot_s, left_s = route_plan(dst, ok, D, cap)
            rows_s = allp[order]
            send = route_pack(rows_s, slot_s, D * cap,
                              backend=self.pack_backend)       # [D*cap, W]
            sends.append(send.reshape(D, cap * W))
            metas.append((lane, cap, W))
            n_ship = n_ship + jnp.sum(ship_s.astype(jnp.int32))

            if K:
                lrank = jnp.cumsum(left_s.astype(jnp.int32)) - 1
                keep = left_s & (lrank < K)
                didx = jnp.where(keep, lrank, K)
                nbuf = jnp.zeros_like(dbuf).at[didx].set(rows_s,
                                                         mode="drop")
                nok = jnp.zeros((K,), bool).at[didx].set(True, mode="drop")
                new_defers.append((nbuf, nok))
                n_defer = n_defer + jnp.sum(keep.astype(jnp.int32))
                n_drop = n_drop + jnp.sum((left_s & ~keep
                                           ).astype(jnp.int32))
            else:
                new_defers.append((dbuf, dok))
                n_drop = n_drop + jnp.sum(left_s.astype(jnp.int32))

        buf = jnp.concatenate(sends, axis=1)                   # [D, X]
        got = lax.all_to_all(buf, self.axis, split_axis=0,
                             concat_axis=0, tiled=True)        # [D, X]
        outs, off = [], 0
        for proto, cap, W in metas:
            blk = got[:, off:off + cap * W].reshape(D * cap, W)
            off += cap * W
            outs.append(unpack_lane(blk, proto))
        receipt = RouteReceipt(rows=n_ship, deferred=n_defer,
                               dropped=n_drop, peak=n_peak)
        return tuple(outs), tuple(new_defers), receipt
