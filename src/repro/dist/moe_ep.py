"""Explicit expert parallelism: shard_map all_to_all dispatch.

GSPMD left to its own devices turns token-choice MoE into all-gathers of
the full token buffer (every expert shard sees every token). The explicit
mapping here moves only the routed tokens: each shard groups its (token,
expert) pairs by destination expert shard, all_to_alls the packed slots,
runs its LOCAL experts, and all_to_alls the results back — wire bytes are
2 x routed-tokens x d.

`moe_ep_apply` is the per-shard body: call it inside shard_map with
  x      : [T_loc, d]    local tokens (sharded over the dp axes)
  router : replicated
  wg/wu/wd: [E_loc, d, h] local expert slab (sharded over the ep axis)
as nn/moe.py's `_ep_call` and the system test do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.moe import _segment_positions


def _a2a(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Tiled all_to_all on the leading axis: row block p goes to shard p,
    and block p of the result came from shard p."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


def moe_ep_apply(layer, params, x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Per-shard MoE forward with explicit expert-parallel dispatch.

    Equals `layer.dense_oracle` whenever capacity is ample (no drops) —
    asserted by the system test on a 2-device mesh.
    """
    cfg = layer.cfg
    S = jax.lax.psum(1, axis_name)                 # static axis size
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    assert E % S == 0, f"experts {E} not divisible by {S} shards"
    E_loc = E // S

    ids, w, _ = layer.route(params, x)             # router is replicated
    e_flat = ids.reshape(-1)                       # [T*K]
    tok = jnp.repeat(jnp.arange(T), K)
    w_flat = w.reshape(-1)
    dest = e_flat // E_loc                         # destination expert shard

    # pack (token, expert) pairs into per-destination slots
    order = jnp.argsort(dest, stable=True)
    dest_s, e_s, tok_s, w_s = dest[order], e_flat[order], tok[order], w_flat[order]
    if T <= 4 * E:                                 # dropless for decode-sized T
        C = T * K
    else:
        C = max(1, int(T * K * cfg.capacity_factor / S))
    pos = _segment_positions(dest_s, S)
    keep = pos < C
    slot = jnp.where(keep, dest_s * C + pos, S * C)          # S*C = trash row

    send_x = jnp.zeros((S * C + 1, d), x.dtype).at[slot].set(
        jnp.where(keep[:, None], x[tok_s], 0))[: S * C]
    send_e = jnp.full((S * C + 1,), E_loc, jnp.int32).at[slot].set(
        jnp.where(keep, (e_s % E_loc).astype(jnp.int32), E_loc))[: S * C]

    recv_x = _a2a(send_x, axis_name)               # [S*C, d] tokens for my experts
    recv_e = _a2a(send_e, axis_name)               # local expert id (E_loc = pad)

    # local experts: E_loc is small; masked dense sweep keeps shapes static
    y = jnp.zeros_like(recv_x)
    for e in range(E_loc):
        g = jax.nn.silu(recv_x @ params["wg"][e].astype(x.dtype))
        u = recv_x @ params["wu"][e].astype(x.dtype)
        ye = (g * u) @ params["wd"][e].astype(x.dtype)
        y = jnp.where((recv_e == e)[:, None], ye, y)

    back = _a2a(y, axis_name)                      # results in send-slot order
    contrib = jnp.where(keep[:, None],
                        back[jnp.minimum(slot, S * C - 1)] * w_s[:, None], 0)
    out = jnp.zeros_like(x).at[tok_s].add(contrib)

    if cfg.n_shared:
        sp = params["shared"]
        sg = jax.nn.silu(x @ sp["wg"].astype(x.dtype))
        su = x @ sp["wu"].astype(x.dtype)
        out = out + (sg * su) @ sp["wd"].astype(x.dtype)
    return out
