"""Compressed data-parallel gradient exchange with error feedback.

Top-k sparsification + int8 quantization shrink the DP all-reduce payload;
the part of the gradient that compression discarded is NOT dropped — it is
carried in a per-leaf residual and added back before the next step's
compression (error feedback, Karimireddy et al. 2019). The accumulated
compressed updates therefore track the accumulated true gradients with a
bounded residual (~1/topk_frac steps' worth), so the relative drift decays
like O(1/steps) — which is exactly what the system test asserts.

`compress_decompress` returns the RECONSTRUCTED (decompressed) gradient:
on a real mesh the wire format is (values, indices, scale) per leaf; here
the round-trip is applied immediately so callers can drop it into any
optimizer without knowing the encoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: q = round(x / s), s = max|x| / 127."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads):
    """Residual tree (same structure as the gradients), all zeros."""
    return jax.tree.map(jnp.zeros_like, grads)


def _compress_leaf(g: jnp.ndarray, res: jnp.ndarray, int8: bool,
                   topk_frac: float):
    """One leaf: error-feedback add, top-k mask, optional int8 round-trip.
    Returns (reconstructed update, new residual), each in its input's
    dtype (g's resp. res's).

    The accumulator runs in f32 regardless: `dequantize_int8` returns
    f32, so without the explicit up/down-cast a bf16/f16 gradient would
    silently promote `sent` AND the carried residual to f32 — a
    dtype-drifting carry that breaks fixed-dtype donation (and any
    lax.scan) on the second step. For f32 inputs the casts are no-ops and
    the arithmetic is bit-identical to the pre-fix path."""
    acc = g.astype(jnp.float32) + res.astype(jnp.float32)
    flat = acc.reshape(-1)
    n = flat.shape[0]
    k = max(1, int(n * topk_frac))
    # magnitude top-k: keep the k largest |values|, zero the rest
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0)
    if int8:
        q, s = quantize_int8(kept)
        sent = jnp.where(mask, dequantize_int8(q, s), 0.0)
    else:
        sent = kept
    new_res = flat - sent
    return (sent.reshape(acc.shape).astype(g.dtype),
            new_res.reshape(acc.shape).astype(res.dtype))


def compress_decompress(grads, residual, int8: bool = True,
                        topk_frac: float = 0.25):
    """Compress gradients with error feedback; returns (sent, new_residual).

    sent: the decompressed update actually applied/all-reduced this step.
    """
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = treedef.flatten_up_to(residual)
    out = [_compress_leaf(g, r, int8, topk_frac)
           for g, r in zip(leaves_g, leaves_r)]
    sent = treedef.unflatten([o[0] for o in out])
    new_res = treedef.unflatten([o[1] for o in out])
    return sent, new_res
