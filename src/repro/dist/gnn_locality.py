"""Vertex-cut locality plan + shard_map GNN train step (§Perf cell A).

The D3-GNN idea applied to full-graph training: block-partition vertices
over shards, place every edge on its RECEIVER's shard, and materialize the
senders each shard does not own as halo rows fed by a per-layer all_to_all
exchange. Aggregations then stay shard-local (receivers are always owned),
so the only wire traffic is the halo feature rows — the same
master/replica broadcast structure the streaming engine uses, frozen into
a static plan.

`build_plan` is host-side numpy: it returns padded [S, ...] arrays ready
to reshape into shard_map operands. `make_locality_train_step` returns a
jittable (params, opt_state, batch) -> (params', opt_state', loss) whose
gradients equal the global single-device step (tested on a forced
8-device CPU mesh).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.graph import segment
from repro.graph.graphs import Graph
from repro.optim import adam, apply_updates, clip_by_global_norm


@dataclass
class LocalityPlan:
    """Static routing tables for one graph snapshot.

    Local sender index space per shard: rows [0, n_loc) are owned vertices,
    row n_loc + p * r_cap + r is halo slot r received from shard p.
    """
    n_loc: int                     # owned vertices per shard
    r_cap: int                     # halo rows per (src, dst) shard pair
    senders_local: np.ndarray      # [S, E_cap] int32 into the local buffer
    receivers_local: np.ndarray    # [S, E_cap] int32, < n_loc (owned)
    edge_mask: np.ndarray          # [S, E_cap] bool
    send_idx: np.ndarray           # [S, S, r_cap] int32 owned rows to ship
    send_mask: np.ndarray          # [S, S, r_cap] bool


def build_plan(senders, receivers, n_nodes: int, n_shards: int,
               e_cap: int | None = None,
               r_cap: int | None = None) -> LocalityPlan:
    """Place each edge on its receiver's shard; dedupe halo senders."""
    senders = np.asarray(senders, np.int64)
    receivers = np.asarray(receivers, np.int64)
    S = n_shards
    assert n_nodes % S == 0, f"{n_nodes} nodes not divisible by {S} shards"
    n_loc = n_nodes // S
    owner = lambda v: v // n_loc
    local = lambda v: v % n_loc

    shard_edges = [[] for _ in range(S)]           # (sender_local, recv_local)
    halo = [[dict() for _ in range(S)] for _ in range(S)]  # [src][dst] {lu: r}
    for u, v in zip(senders, receivers):
        s = int(owner(v))
        if owner(u) == s:
            su = int(local(u))
        else:
            p = int(owner(u))
            table = halo[p][s]
            r = table.setdefault(int(local(u)), len(table))
            su = None              # resolved after r_cap is known
            shard_edges[s].append((p, int(local(u)), int(local(v))))
            continue
        shard_edges[s].append((-1, su, int(local(v))))

    if r_cap is None:
        r_cap = max((len(halo[p][q]) for p in range(S) for q in range(S)),
                    default=0)
        r_cap = max(r_cap, 1)
    if e_cap is None:
        e_cap = max(max((len(e) for e in shard_edges), default=0), 1)

    send_idx = np.zeros((S, S, r_cap), np.int32)
    send_mask = np.zeros((S, S, r_cap), bool)
    for p in range(S):
        for q in range(S):
            for lu, r in halo[p][q].items():
                assert r < r_cap, f"halo overflow: pair ({p},{q}) needs {r + 1} > r_cap={r_cap}"
                send_idx[p, q, r] = lu
                send_mask[p, q, r] = True

    senders_local = np.zeros((S, e_cap), np.int32)
    receivers_local = np.zeros((S, e_cap), np.int32)
    edge_mask = np.zeros((S, e_cap), bool)
    for s in range(S):
        assert len(shard_edges[s]) <= e_cap, \
            f"shard {s} has {len(shard_edges[s])} edges > e_cap={e_cap}"
        for i, (p, lu, lv) in enumerate(shard_edges[s]):
            if p < 0:
                senders_local[s, i] = lu
            else:
                senders_local[s, i] = n_loc + p * r_cap + halo[p][s][lu]
            receivers_local[s, i] = lv
            edge_mask[s, i] = True
    return LocalityPlan(n_loc=n_loc, r_cap=r_cap,
                        senders_local=senders_local,
                        receivers_local=receivers_local,
                        edge_mask=edge_mask,
                        send_idx=send_idx, send_mask=send_mask)


def _halo_exchange(x_own, send_idx, send_mask, axis_name):
    """all_to_all the owned rows each peer needs; [S * r_cap, d] halo."""
    S, r_cap = send_idx.shape
    buf = jnp.where(send_mask[:, :, None], x_own[send_idx], 0)   # [S,r_cap,d]
    recv = lax.all_to_all(buf.reshape(S * r_cap, -1), axis_name,
                          split_axis=0, concat_axis=0, tiled=True)
    return recv


def _pna_local_update(layer, lparams, x_full, senders, receivers, edge_mask,
                      n_own):
    """PNA layer with the post-MLP restricted to OWNED rows (halo rows only
    feed messages) — removes the |halo|/|owned| overcompute of running the
    full layer and slicing."""
    x_own = x_full[:n_own]
    m = layer.pre(lparams["pre"],
                  jnp.concatenate([x_full[senders], x_full[receivers]], -1))
    aggs = jnp.concatenate([
        segment.segment_mean(m, receivers, n_own, edge_mask),
        segment.segment_max(m, receivers, n_own, edge_mask),
        segment.segment_min(m, receivers, n_own, edge_mask),
        segment.segment_std(m, receivers, n_own, edge_mask),
    ], axis=-1)
    deg = segment.segment_count(receivers, n_own, edge_mask)
    logd = jnp.log(deg + 1.0)
    amp = (logd / layer.avg_log_deg)[:, None]
    att = (layer.avg_log_deg / jnp.maximum(logd, 1e-6))[:, None]
    scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)
    h = layer.post(lparams["post"], jnp.concatenate([x_own, scaled], -1))
    return jax.nn.relu(h) if layer.act else h


def make_locality_train_step(model, n_classes: int, axes, mesh,
                             local_update: bool = False,
                             compute_dtype=None, lr: float = 1e-3,
                             clip: float = 1.0):
    """(params, opt_state, batch) -> (params', opt_state', loss).

    batch (leading dim S, sharded over `axes`):
      x [S, n_loc, d], labels [S, n_loc], label_mask [S, n_loc],
      senders/receivers/edge_mask [S, E_cap],
      send_idx/send_mask [S, S, r_cap].
    Gradients are psum'd and the update applied replicated, so the result
    is bit-comparable to the global-graph step.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    ax = axes_t if len(axes_t) > 1 else axes_t[0]
    opt = adam()

    def local_ce_sum(params, b):
        x = b["x"]
        if compute_dtype is not None:
            x = x.astype(compute_dtype)
        n_own = x.shape[0]
        for i, layer in enumerate(model.layers):
            halo = _halo_exchange(x, b["send_idx"], b["send_mask"], ax)
            x_full = jnp.concatenate([x, halo.astype(x.dtype)], axis=0)
            if local_update and hasattr(layer, "pre"):
                x = _pna_local_update(layer, params[f"l{i}"], x_full,
                                      b["senders"], b["receivers"],
                                      b["edge_mask"], n_own)
            else:
                g = Graph(senders=b["senders"], receivers=b["receivers"],
                          x=x_full, edge_mask=b["edge_mask"])
                x = layer(params[f"l{i}"], g, x_full)[:n_own]
        logits = model.head(params["head"], x) if n_classes else x
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logp, b["labels"][:, None], -1)[:, 0]
        return jnp.sum(jnp.where(b["label_mask"], -gold, 0.0))

    def shard_body(params, batch):
        b = jax.tree.map(lambda a: a[0], batch)      # strip the S-block dim
        ce_sum, grads = jax.value_and_grad(local_ce_sum)(params, b)
        cnt = lax.psum(jnp.sum(b["label_mask"].astype(jnp.float32)), ax)
        cnt = jnp.maximum(cnt, 1.0)
        loss = lax.psum(ce_sum, ax) / cnt
        grads = jax.tree.map(lambda g: lax.psum(g.astype(jnp.float32), ax)
                             / cnt, grads)
        return loss, grads

    batch_keys = ("x", "labels", "label_mask", "senders", "receivers",
                  "edge_mask", "send_idx", "send_mask")
    in_batch_specs = {k: P(axes_t) for k in batch_keys}
    sharded = shard_map(shard_body, mesh=mesh,
                        in_specs=(P(), in_batch_specs),
                        out_specs=(P(), P()), check_rep=False)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = sharded(params, {k: batch[k] for k in batch_keys})
        grads, _ = clip_by_global_norm(grads, clip)
        updates, new_opt = opt.update(opt_state, grads, params, lr)
        return apply_updates(params, updates), new_opt, loss

    return step
