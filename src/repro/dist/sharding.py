"""Per-family sharding rules for the production mesh (dry-run §Perf).

Rules are heuristics keyed by the ArchSpec family, applied without
allocation to jax.eval_shape trees:

  lm     : tensor parallel — shard the largest axis divisible by the
           "model" axis; embeddings/MoE expert slabs land on their natural
           axis; replicated over data axes (DP handles the batch).
  gnn    : replicated parameters (graphs shard over data axes instead).
  d3gnn  : replicated parameters; the engine shards its part axis itself.
  recsys : embedding tables row-sharded over the model axis (they dwarf
           the dense towers), dense params replicated.

Inputs: leading (batch/part) axis over the data axes when divisible, else
replicated. `spec_tree` maps a rule over an eval_shape tree and returns
NamedShardings ready for jax.jit in_shardings.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _axis_size(mesh: Mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def _model_spec(leaf, mesh: Mesh) -> P:
    """Shard the largest divisible axis over "model"; else replicate."""
    m = _axis_size(mesh, "model")
    if m <= 1 or not hasattr(leaf, "shape") or len(leaf.shape) == 0:
        return P()
    dims = list(leaf.shape)
    order = sorted(range(len(dims)), key=lambda i: -dims[i])
    for i in order:
        if dims[i] % m == 0 and dims[i] >= m:
            spec = [None] * len(dims)
            spec[i] = "model"
            return P(*spec)
    return P()


def _replicated(leaf, mesh: Mesh) -> P:
    return P()


def _recsys_spec(leaf, mesh: Mesh) -> P:
    # row-shard anything that looks like an embedding table (2D and tall)
    if (hasattr(leaf, "shape") and len(leaf.shape) == 2
            and leaf.shape[0] >= 16 * max(1, leaf.shape[1])
            and leaf.shape[0] % max(1, _axis_size(mesh, "model")) == 0):
        return P("model")
    return P()


FAMILY_PARAM_RULES = {
    "lm": _model_spec,
    "gnn": _replicated,
    "d3gnn": _replicated,
    "recsys": _recsys_spec,
}


def spec_tree(tree, rule, mesh: Mesh):
    """Map a (leaf, mesh) -> PartitionSpec rule into NamedShardings."""
    return jax.tree.map(lambda l: NamedSharding(mesh, rule(l, mesh)), tree)


def _batch_sharding(leaf, mesh: Mesh) -> NamedSharding:
    axes = data_axes(mesh)
    n = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1
    if (n > 1 and hasattr(leaf, "shape") and len(leaf.shape) >= 1
            and leaf.shape[0] % n == 0 and leaf.shape[0] >= n):
        return NamedSharding(mesh, P(axes))
    return NamedSharding(mesh, P())


def _input_rule(in_specs: dict, mesh: Mesh, kind: str) -> dict:
    return {k: jax.tree.map(lambda l: _batch_sharding(l, mesh), v)
            for k, v in in_specs.items()}


FAMILY_INPUT_RULES = {
    "lm": _input_rule,
    "gnn": _input_rule,
    "d3gnn": _input_rule,
    "recsys": _input_rule,
}


# ------------------------------------------------ streaming-engine carry
# NamedSharding rules for the streaming `PipelineCarry` (core/state.py):
# every [P, ...] table is block-sharded over the ("data",) axis so the
# donated super-tick carry stays device-resident at its owning shard; the
# CountMinSketch, tick clock and quiet counter are replicated (the tick
# body keeps them consistent via psum). The pspec tree doubles as the
# shard_map in/out specs for the tick program (core/pipeline.py).

def _carry_tree(n_layers: int, part, rep, train=None):
    """Build a PipelineCarry-shaped tree with `part` at every
    part-leading leaf and `rep` at every replicated leaf. `train` is an
    already-built TrainState spec tree (core/train_plane.py:train_pspecs
    / train_shardings) or None when the training plane is off."""
    from repro.core.state import LayerState, PipelineCarry, TopoState
    from repro.serve.query import QueryState
    topo = TopoState(
        e_src_slot=part, e_dst_slot=part, e_dst_mpart=part, e_dst_mslot=part,
        e_valid=part, r_master_slot=part, r_rep_part=part, r_rep_slot=part,
        r_valid=part, v_exists=part, is_master=part,
        m_part=part, m_slot=part)
    # defer rings are [D * K, W] globally — block-sharded on axis 0 like
    # every part-leading table, so each device carries its own [K, W] ring
    layer = LayerState(
        feat=part, has_feat=part, x_sent=part, has_sent=part, agg=part,
        agg_cnt=part, red_pending=part, red_deadline=part, fwd_pending=part,
        fwd_deadline=part, cms=rep, last_touch=part,
        bc_defer=part, bc_defer_ok=part, rmi_defer=part, rmi_defer_ok=part)
    queries = QueryState(
        qid=part, kind=part, slot=part, part2=part, slot2=part,
        consistent=part, ok=part, issue=part, vec=part, pending=part,
        wire_defer=part, wire_defer_ok=part)
    return PipelineCarry(topo=topo, layers=(layer,) * n_layers, sink=part,
                         sink_seen=part, queries=queries, now=rep, quiet=rep,
                         train=train)


def carry_pspecs(n_layers: int, axis: str = "data", train=None):
    """PartitionSpec tree for PipelineCarry (shard_map in/out specs)."""
    return _carry_tree(n_layers, P(axis), P(), train)


def carry_shardings(mesh: Mesh, n_layers: int, axis: str = "data",
                    train=None):
    """NamedSharding tree for device_put-ing the carry onto the mesh."""
    return _carry_tree(n_layers, NamedSharding(mesh, P(axis)),
                       NamedSharding(mesh, P()), train)


def stats_pspecs(n_layers: int, axis: str = "data"):
    """Per-layer TickStats out-specs: scalars are psum'd inside the tick
    body (replicated), the per-part busy vector concatenates over parts."""
    from repro.core.tick import TickStats
    one = TickStats(broadcast_msgs=P(), reduce_msgs=P(), cross_part_msgs=P(),
                    emitted=P(), dropped=P(), wire_rows=P(),
                    route_deferred=P(), route_dropped=P(),
                    n_suppressed=P(), occ_bc_defer=P(), occ_rmi_defer=P(),
                    route_peak=P(), outbox_part_peak=P(), busy=P(axis))
    return tuple(one for _ in range(n_layers))


# -------------------------------------- hybrid 2-D ("stage","data") mesh
# Placement of the layer-pipelined carry (ISSUE 7): layer tables are
# STACKED per round with a leading stage axis (round r's leaf holds layer
# r*S+s at stage index s) and sharded over BOTH axes; every other carry
# field keeps its 1-D placement — part arrays shard over "data" and
# replicate per stage (topo/sink/queries are maintained identically on
# every stage), the per-layer CMS shards over "stage" only, and the
# clock/quiet scalars replicate globally (their updates go through
# psum_vote over both axes). The inter-stage ring is stage-sharded on its
# leading axis and data-sharded on its row axis.

def _stage_carry_tree(n_rounds: int, part, part2, stage, rep, ring,
                      train=None):
    """PipelineCarry-shaped tree for the pipelined program: `part2` at
    stacked per-round layer leaves, `stage` at the stacked CMS, `part` at
    stage-replicated part tables, `rep` at scalars, `ring` at stage_ring,
    `train` an already-built stage-replicated TrainState spec tree (the
    training plane uses the same `train_pspecs` as the 1-D mesh — part
    tables shard over "data" and replicate per stage)."""
    from repro.core.state import LayerState, PipelineCarry, TopoState
    from repro.serve.query import QueryState
    topo = TopoState(
        e_src_slot=part, e_dst_slot=part, e_dst_mpart=part, e_dst_mslot=part,
        e_valid=part, r_master_slot=part, r_rep_part=part, r_rep_slot=part,
        r_valid=part, v_exists=part, is_master=part,
        m_part=part, m_slot=part)
    layer = LayerState(
        feat=part2, has_feat=part2, x_sent=part2, has_sent=part2, agg=part2,
        agg_cnt=part2, red_pending=part2, red_deadline=part2,
        fwd_pending=part2, fwd_deadline=part2, cms=stage, last_touch=part2,
        bc_defer=part2, bc_defer_ok=part2, rmi_defer=part2,
        rmi_defer_ok=part2)
    queries = QueryState(
        qid=part, kind=part, slot=part, part2=part, slot2=part,
        consistent=part, ok=part, issue=part, vec=part, pending=part,
        wire_defer=part, wire_defer_ok=part)
    return PipelineCarry(topo=topo, layers=(layer,) * n_rounds, sink=part,
                         sink_seen=part, queries=queries, now=rep, quiet=rep,
                         stage_ring=ring, train=train)


def stage_carry_pspecs(n_rounds: int, stage_axis: str = "stage",
                       axis: str = "data", train=None):
    """PartitionSpec tree for the pipelined PipelineCarry (shard_map
    in/out specs of `_tick_program_2d`)."""
    return _stage_carry_tree(
        n_rounds, P(axis), P(stage_axis, axis), P(stage_axis), P(),
        P(stage_axis, None, axis), train)


def stage_carry_shardings(mesh: Mesh, n_rounds: int,
                          stage_axis: str = "stage", axis: str = "data",
                          train=None):
    """NamedSharding tree for device_put-ing the pipelined carry."""
    ns = lambda spec: NamedSharding(mesh, spec)
    return _stage_carry_tree(
        n_rounds, ns(P(axis)), ns(P(stage_axis, axis)), ns(P(stage_axis)),
        ns(P()), ns(P(stage_axis, None, axis)), train)


def stage_stats_pspecs(n_rounds: int, stage_axis: str = "stage",
                       axis: str = "data"):
    """Per-ROUND TickStats out-specs for the pipelined tick: each stage's
    scalars cover its own layer of the round (data-psum'd only), so they
    leave the shard_map as [1]-shaped leaves stacked to [S] over the
    stage axis; busy leaves as [1, P_loc] stacked to [S, n_parts]. The
    host unstacks layer l = r*S + s from (round r)[s]."""
    from repro.core.tick import TickStats
    s, b = P(stage_axis), P(stage_axis, axis)
    one = TickStats(broadcast_msgs=s, reduce_msgs=s, cross_part_msgs=s,
                    emitted=s, dropped=s, wire_rows=s, route_deferred=s,
                    route_dropped=s, n_suppressed=s, occ_bc_defer=s,
                    occ_rmi_defer=s, route_peak=s, outbox_part_peak=s,
                    busy=b)
    return tuple(one for _ in range(n_rounds))
