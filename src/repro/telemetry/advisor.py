"""Capacity advisor for the telemetry plane (ISSUE 9).

Reads a recorded trace (`telemetry/trace.py`) and emits recommended
capacity knobs for `PipelineConfig` under a zero-drop / bounded-defer
budget. Every recommendation is derived from an EXACT occupancy gauge
the device measured (never a heuristic over throughput):

  outbox_cap     : n_parts x (max outbox_part_peak x slack). The
                   outbox quota binds PER PART (forward_psi enforces
                   outbox_cap // n_parts slots per part), so zero-drop
                   sizing must come from the recorded per-part demand
                   peak — the global (emitted + dropped) gauge
                   under-sizes the cap whenever demand is skewed
                   across parts;
  feat_cap       : max per-tick feature ingest x slack (also the
                   outbox default, so it is floored at outbox_cap);
  edge_tick_cap  : max per-tick edge ingest x slack;
  route_cap      : defer_budget == 0 -> max route_peak (the recorded
                   zero-defer bucket demand: replay defers nothing and
                   the defer rings compile away). defer_budget > 0 ->
                   the (1 - defer_budget) quantile of route_peak, with
                   route_defer_cap left at the lane default so the
                   overflow of the tail ticks re-enters later exchanges
                   instead of dropping;
  query_tick_cap : max per-tick query ingest x slack (query_cap keeps
                   the recorded per-part slots, floored so the pending
                   peak fits);
  train_cap      : max per-tick label ingest x slack (0 stays 0 — the
                   plane stays compiled away).

Record the observability trace with route_cap=None (dense): occupancy
peaks recorded under an already-capped exchange reflect THAT config's
deferral dynamics, so a looser recommendation could legitimately see
higher per-tick demand than the trace ever did. From a dense trace the
zero-defer sizing (route_cap = max route_peak) replays bit-identically
— nothing defers at the recorded demand — with strictly less wire
whenever the stream is skewed.

The advisor validates its own output against
`PipelineConfig.validate()` before emitting it. REPLAY validation (the
acceptance gate: streaming the same workload through the recommended
caps must report dropped == 0 and route_dropped == 0, with wire bytes
<= the dense config) needs the original stream, which the trace does
not carry — `benchmarks/record_trace.py` does that end-to-end and is
what CI runs; `replay_ok(pipe)` here is the shared assertion.

CLI:  python -m repro.telemetry.advisor TRACE.npz --out RECS.json
"""
from __future__ import annotations

import argparse
import json
import math
from dataclasses import replace

import numpy as np

from repro.telemetry.trace import Trace, load_trace

ADVISOR_SCHEMA = 1


def _ceil_mult(x: float, m: int) -> int:
    return max(m, int(math.ceil(x / m)) * m)


def recommend(trace: Trace, slack: float = 1.25,
              defer_budget: float = 0.0) -> dict:
    """Recommended capacity knobs from a trace's occupancy gauges.

    slack: headroom multiplier on every observed peak (the stream CI
    replays is the recorded one, but recommendations should survive a
    slightly heavier tick). defer_budget: fraction of ticks allowed to
    push route overflow into the defer rings (0 = zero-defer sizing).
    """
    c = trace.columns
    m = trace.meta
    n_parts = int(m["n_parts"])
    peak = lambda col: int(c[col].max()) if len(trace) else 0

    # the outbox quota binds per part: size from the per-part demand
    # peak, never the global demand (skew would blow the hot part's
    # share of a globally-sized cap)
    outbox = n_parts * _ceil_mult(peak("outbox_part_peak") * slack, 1)
    feat = max(_ceil_mult(peak("feats_in") * slack, 1), outbox)
    edge_tick = _ceil_mult(max(peak("edges_in"), 1) * slack, 1)

    rp = c["route_peak"]
    if int(m["n_devices"]) <= 1 or peak("route_peak") == 0:
        route_cap, route_defer = None, None
    elif defer_budget <= 0.0:
        route_cap, route_defer = int(rp.max()), None
    else:
        q = float(np.quantile(rp[rp > 0], 1.0 - defer_budget))
        route_cap = max(1, int(math.ceil(q)))
        route_defer = None          # lane-capacity default: never drops

    query_cap = int(m["query_cap"])
    if query_cap > 0:
        query_cap = max(query_cap,
                        _ceil_mult(peak("query_pending") * slack / n_parts,
                                   1))
        query_tick = _ceil_mult(max(peak("queries_in"), 1) * slack, 1)
    else:
        query_tick = None
    train_cap = (_ceil_mult(max(peak("labels_in"), 1) * slack, 1)
                 if int(m["train_cap"]) > 0 else 0)

    recs = {
        "schema": ADVISOR_SCHEMA,
        "slack": slack,
        "defer_budget": defer_budget,
        "caps": {
            "outbox_cap": outbox, "feat_cap": feat,
            "edge_tick_cap": edge_tick, "route_cap": route_cap,
            "route_defer_cap": route_defer, "query_cap": query_cap,
            "query_tick_cap": query_tick, "train_cap": train_cap,
        },
        "basis": {
            "ticks": len(trace),
            "outbox_demand_peak": peak("outbox_demand"),
            "outbox_part_peak": peak("outbox_part_peak"),
            "route_peak_max": peak("route_peak"),
            "feats_in_peak": peak("feats_in"),
            "edges_in_peak": peak("edges_in"),
            "queries_in_peak": peak("queries_in"),
            "labels_in_peak": peak("labels_in"),
            "query_pending_peak": peak("query_pending"),
            "occ_defer_peak": max(peak("occ_bc_defer"),
                                  peak("occ_rmi_defer")),
        },
        "trace_meta": {k: m[k] for k in
                       ("n_parts", "n_devices", "n_stages", "window",
                        "route_cap", "wire_bytes_per_tick")},
    }
    check_bounds(recs)
    return recs


def apply_recommendation(cfg, recs: dict):
    """A copy of `cfg` with the recommended caps applied (dataclasses
    replace; keys with value None fall back to the config default
    semantics, e.g. route_cap=None = dense)."""
    return replace(cfg, **recs["caps"])


def check_bounds(recs: dict) -> None:
    """Fail fast if the recommended caps would not pass
    `PipelineConfig.validate()` — the advisor must never emit a config
    the pipeline rejects."""
    from repro.core.pipeline import PipelineConfig
    caps = recs["caps"]
    n_parts = int(recs["trace_meta"]["n_parts"])
    cfg = PipelineConfig(n_parts=n_parts, **caps)
    cfg.validate(n_devices=int(recs["trace_meta"]["n_devices"])
                 * max(int(recs["trace_meta"]["n_stages"]), 1))


def replay_ok(pipe) -> dict:
    """The zero-drop replay assertion shared by tests and CI
    (`benchmarks/record_trace.py`): a pipeline that streamed the
    recorded workload under the recommended caps must have dropped
    nothing anywhere."""
    m = pipe.metrics
    out = {"dropped": int(m.dropped), "route_dropped": int(m.route_dropped),
           "queries_dropped": int(m.queries_dropped),
           "wire_bytes": int(m.wire_bytes)}
    if out["dropped"] or out["route_dropped"]:
        raise AssertionError(f"recommended caps dropped work: {out}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.advisor",
        description="Recommend PipelineConfig capacities from a "
                    "telemetry trace.")
    ap.add_argument("trace", help="trace .npz written by save_trace()")
    ap.add_argument("--out", default=None,
                    help="write recommendations JSON here (default: stdout)")
    ap.add_argument("--slack", type=float, default=1.25,
                    help="headroom multiplier on observed peaks")
    ap.add_argument("--defer-budget", type=float, default=0.0,
                    help="fraction of ticks allowed to defer route "
                         "overflow (0 = zero-defer sizing)")
    args = ap.parse_args(argv)
    recs = recommend(load_trace(args.trace), slack=args.slack,
                     defer_budget=args.defer_budget)
    text = json.dumps(recs, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
