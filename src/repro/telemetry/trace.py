"""Trace recorder for the telemetry plane (ISSUE 9).

With `PipelineConfig.telemetry=True` the pipeline appends ONE row per
tick — per-plane occupancy gauges measured on device (the occupancy
vector riding the super-tick scan's ys, see
`core/pipeline.py:_tick_program`), host-side wall timings, exact wire
bytes, and the tick's ingest counts — into a `TraceRecorder`.
`save()` writes a compact `.npz` (one int/float column per field plus
a JSON meta blob: config summary, caps, lane widths, schema version);
`load_trace()` validates the schema and hands the columns back as
numpy arrays. The cost model (`telemetry/cost_model.py`) fits per-plane
cost coefficients from a trace; the capacity advisor
(`telemetry/advisor.py`) turns the occupancy peaks into recommended
`Capacities`.

Column conventions
------------------
Device columns (`TRACE_DEVICE_COLS`, in order — the pipeline stacks
the on-device occupancy row in exactly this order):

  emitted_final  : last layer's forward emissions (the events/s numerator)
  emitted_sum    : forward emissions summed over layers
  reduce_msgs    : round-B RMI records emitted (sum over layers)
  broadcast_msgs : round-A replica broadcasts (sum over layers)
  wire_rows      : live rows actually shipped on all_to_all
  route_deferred : rows pushed into the defer rings this tick
  route_dropped  : rows lost to a FULL defer ring (0 when healthy)
  dropped        : forward emissions deferred by outbox capacity
  suppressed     : delta-gate suppressed out-edge RMIs
  occ_bc_defer   : END-OF-TICK broadcast defer-ring population
  occ_rmi_defer  : END-OF-TICK RMI defer-ring population
  route_peak     : peak per-destination bucket demand PRE-cap (the
                   zero-defer route_cap for this tick's traffic)
  outbox_demand  : max over layers of (emitted + dropped) — the GLOBAL
                   forward-emission demand of the heaviest layer
  outbox_part_peak : max over layers of the max PER-PART eviction
                   demand pre-quota. The outbox cap binds per part
                   (outbox_cap // n_parts slots each), so THIS is the
                   sizing gauge: zero-drop needs
                   outbox_cap >= n_parts x outbox_part_peak
  query_pending  : held consistent queries (slot occupancy gauge)
  query_backlog  : query wire rows waiting in the query defer ring
  train_labeled  : train-table rows holding a label (table occupancy)
  train_dirty    : labeled rows currently dirty (the pending batch)
  q_admitted / q_answered / q_dropped : query-plane flow counters

Host columns (`TRACE_HOST_COLS`):

  tick       : stream clock at the START of the row's tick
  ticks      : micro-ticks this row covers (1; kept for forward compat)
  wall_s     : wall seconds attributed to the tick (per-tick driver:
               the measured round; scan driver: super-tick wall / T)
  host_s     : host-side staging seconds (0 on the scan driver — its
               staging amortizes over the whole super-tick)
  amortized  : 1 when wall_s is a super-tick average, 0 when measured
               per tick (the cost model prefers amortized rows: they
               are far less noisy on CPU)
  wire_bytes : exact bytes on the wire this tick (host-side static
               arithmetic, `D3Pipeline._static_wire_bytes`)
  edges_in / feats_in / queries_in / labels_in : ingest counts
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

TRACE_SCHEMA_VERSION = 1

TRACE_DEVICE_COLS: List[str] = [
    "emitted_final", "emitted_sum", "reduce_msgs", "broadcast_msgs",
    "wire_rows", "route_deferred", "route_dropped", "dropped",
    "suppressed", "occ_bc_defer", "occ_rmi_defer", "route_peak",
    "outbox_demand", "outbox_part_peak",
    "query_pending", "query_backlog", "train_labeled",
    "train_dirty", "q_admitted", "q_answered", "q_dropped",
]

TRACE_HOST_COLS: List[str] = [
    "tick", "ticks", "wall_s", "host_s", "amortized", "wire_bytes",
    "edges_in", "feats_in", "queries_in", "labels_in",
]

_FLOAT_COLS = {"wall_s", "host_s"}


class TraceRecorder:
    """Accumulates per-tick telemetry rows; `save()` -> compact .npz."""

    def __init__(self, meta: dict | None = None):
        self.meta: dict = dict(meta or {})
        self.meta.setdefault("schema", TRACE_SCHEMA_VERSION)
        self._cols: Dict[str, list] = {
            c: [] for c in TRACE_HOST_COLS + TRACE_DEVICE_COLS}

    def __len__(self) -> int:
        return len(self._cols["tick"])

    def annotate(self, **kv) -> None:
        """Attach extra metadata (e.g. serving latency percentiles)."""
        self.meta.update(kv)

    def append(self, host_row: dict, device_row) -> None:
        """One tick: `host_row` keyed by TRACE_HOST_COLS (missing keys
        default to 0), `device_row` an int sequence in TRACE_DEVICE_COLS
        order (the occupancy vector off the device)."""
        dev = np.asarray(device_row).reshape(-1)
        if dev.shape[0] != len(TRACE_DEVICE_COLS):
            raise ValueError(
                f"device row has {dev.shape[0]} columns, expected "
                f"{len(TRACE_DEVICE_COLS)}")
        for c in TRACE_HOST_COLS:
            v = host_row.get(c, 0)
            self._cols[c].append(float(v) if c in _FLOAT_COLS else int(v))
        for c, v in zip(TRACE_DEVICE_COLS, dev):
            self._cols[c].append(int(v))

    def columns(self) -> Dict[str, np.ndarray]:
        out = {}
        for c, vals in self._cols.items():
            dt = np.float64 if c in _FLOAT_COLS else np.int64
            out[c] = np.asarray(vals, dtype=dt)
        return out

    def save(self, path) -> None:
        np.savez_compressed(
            path, __meta__=np.asarray(json.dumps(self.meta)),
            **self.columns())


class Trace:
    """A loaded trace: `.meta` dict + named numpy columns via `col()`."""

    def __init__(self, meta: dict, cols: Dict[str, np.ndarray]):
        self.meta = meta
        self._cols = cols

    def __len__(self) -> int:
        return int(self._cols["tick"].shape[0])

    def col(self, name: str) -> np.ndarray:
        return self._cols[name]

    @property
    def columns(self) -> Dict[str, np.ndarray]:
        return dict(self._cols)


def load_trace(path) -> Trace:
    """Load a trace written by `TraceRecorder.save`, validating schema."""
    with np.load(path, allow_pickle=False) as z:
        if "__meta__" not in z:
            raise ValueError(f"{path}: not a telemetry trace (no meta)")
        meta = json.loads(str(z["__meta__"]))
        schema = meta.get("schema")
        if schema != TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"{path}: trace schema {schema!r}, this loader reads "
                f"{TRACE_SCHEMA_VERSION}")
        cols = {}
        for c in TRACE_HOST_COLS + TRACE_DEVICE_COLS:
            if c not in z:
                raise ValueError(f"{path}: missing trace column {c!r}")
            cols[c] = np.asarray(z[c])
        n = {v.shape[0] for v in cols.values()}
        if len(n) != 1:
            raise ValueError(f"{path}: ragged trace columns {sorted(n)}")
    return Trace(meta, cols)
