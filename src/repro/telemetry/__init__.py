"""Telemetry plane (ISSUE 9): the sixth plane, watching the other five.

`trace` records exact per-plane occupancy gauges + host timings per
tick; `cost_model` fits seconds-per-row coefficients from a trace and
answers what-if queries; `advisor` turns occupancy peaks into
recommended `PipelineConfig` capacities under a zero-drop budget.
Enable recording with `PipelineConfig(telemetry=True)` — the default
compiles the whole plane away.
"""
from repro.telemetry.trace import (TRACE_DEVICE_COLS, TRACE_HOST_COLS,
                                   TRACE_SCHEMA_VERSION, Trace,
                                   TraceRecorder, load_trace)
from repro.telemetry.cost_model import (CostModel, FEATURES,
                                        fit_cost_model)
from repro.telemetry.advisor import (apply_recommendation, recommend,
                                     replay_ok)

__all__ = [
    "TRACE_DEVICE_COLS", "TRACE_HOST_COLS", "TRACE_SCHEMA_VERSION",
    "Trace", "TraceRecorder", "load_trace", "CostModel", "FEATURES",
    "fit_cost_model", "apply_recommendation", "recommend", "replay_ok",
]
