"""Replay cost model for the telemetry plane (ISSUE 9).

Fits per-plane cost coefficients from a recorded trace
(`telemetry/trace.py`) by non-negative least squares over per-tick row
counts:

    wall_s  ~=  c0  +  sum_plane  c_plane * rows_plane(tick)

The features are the per-plane work volumes the trace already carries
(compute emissions, delivery messages, routed wire rows, query rows,
training batch rows, host ingest rows) — so each fitted coefficient
reads directly as "seconds per row through that plane" and a what-if
query is a dot product. Wire BYTES are not fitted: they are exact
compile-time constants of (config, mesh) recorded in the trace meta,
and `what_if` re-prices them with the roofline interconnect bandwidth
(`repro/roofline/analysis.py:ICI_BW`) when asked for a different
route_cap / device count / stage count.

Fitting notes (why the masks exist):

  * amortized rows (scan driver, wall = super-tick / T) are strongly
    preferred — per-tick-driver rows carry host jitter and the first
    rows of a session carry jit compilation, neither of which any
    row-count model should try to explain;
  * rows whose wall time exceeds `outlier x median` are dropped as
    compile/GC spikes before fitting;
  * coefficients are clamped non-negative by iterative re-fitting
    (a negative "seconds per row" is always noise).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.roofline.analysis import ICI_BW
from repro.telemetry.trace import Trace

COST_MODEL_SCHEMA = 1

# feature name -> trace columns summed into it (one feature per plane)
FEATURES: Dict[str, tuple] = {
    "compute_rows": ("emitted_sum",),
    "deliver_rows": ("reduce_msgs", "broadcast_msgs"),
    "wire_rows": ("wire_rows", "route_deferred"),
    "query_rows": ("q_admitted", "query_pending"),
    "train_rows": ("train_dirty",),
    "ingest_rows": ("edges_in", "feats_in", "queries_in", "labels_in"),
}


def feature_matrix(cols: Dict[str, np.ndarray]) -> np.ndarray:
    """[T, F] per-tick plane work volumes in FEATURES order."""
    return np.stack(
        [sum(cols[c].astype(np.float64) for c in parts)
         for parts in FEATURES.values()], axis=1)


def _fit_mask(cols, prefer_amortized: bool, outlier: float) -> np.ndarray:
    y = cols["wall_s"]
    mask = y > 0
    am = cols["amortized"].astype(bool)
    if prefer_amortized and am.any():
        mask &= am
    if mask.any():
        med = np.median(y[mask])
        if med > 0:
            mask &= y <= outlier * med
    return mask


def _nnls(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with coefficients clamped >= 0 by iteratively
    dropping negative columns and re-fitting (column 0, the intercept,
    is never dropped)."""
    active = list(range(X.shape[1]))
    while True:
        beta, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        neg = [i for i, b in zip(active, beta) if b < 0 and i != 0]
        if not neg:
            break
        active = [i for i in active if i not in neg]
    out = np.zeros(X.shape[1])
    out[active] = np.maximum(beta, 0.0)
    return out


@dataclass
class CostModel:
    """Fitted per-plane linear cost model; see `fit_cost_model`."""
    intercept: float
    coef: Dict[str, float]            # feature name -> seconds per row
    meta: dict = field(default_factory=dict)   # the trace's meta blob

    def predict(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """Predicted per-tick wall seconds for trace columns."""
        X = feature_matrix(cols)
        w = np.array([self.coef[k] for k in FEATURES])
        return self.intercept + X @ w

    def report(self, trace: Trace, tol: float = 0.25,
               prefer_amortized: bool = True,
               outlier: float = 10.0) -> dict:
        """Prediction-vs-measured accuracy on the trace's fit-eligible
        rows (the acceptance gate: hit_frac >= 0.8 at tol=0.25)."""
        cols = trace.columns
        mask = _fit_mask(cols, prefer_amortized, outlier)
        y = cols["wall_s"][mask]
        pred = self.predict(cols)[mask]
        if y.size == 0:
            return {"n": 0, "hit_frac": 0.0, "mae_frac": float("nan")}
        rel = np.abs(pred - y) / y
        return {"n": int(y.size),
                "hit_frac": float(np.mean(rel <= tol)),
                "mae_frac": float(np.mean(rel))}

    # ------------------------------------------------------- what-if
    def wire_bytes_at(self, route_cap=..., n_devices: Optional[int] = None,
                      n_stages: Optional[int] = None) -> int:
        """Exact capped-a2a wire bytes per tick at a candidate
        route_cap, re-derived from the recorded lane list (the same
        constants `D3Pipeline._static_wire_bytes` prices). Candidate
        device/stage counts rescale the a2a multiplier exactly and the
        fixed (ring/gather/train) bytes proportionally — the latter is
        an approximation, flagged here rather than hidden."""
        m = self.meta
        D0, S0 = int(m["n_devices"]), int(m["n_stages"])
        D = D0 if n_devices is None else int(n_devices)
        S = S0 if n_stages is None else int(n_stages)
        rc = m.get("route_cap") if route_cap is ... else route_cap
        lane = (lambda c: c) if rc is None else \
            (lambda c: max(1, min(int(rc), c)))
        a2a_mult = S * D * D * 4 if D > 1 else 0
        a2a = a2a_mult * sum(lane(int(c)) * int(w)
                             for c, w in m["wire_lanes"])
        fixed = int(m["fixed_wire_bytes"])
        if (D, S) != (D0, S0) and D0 * S0 > 0:
            fixed = fixed * (D * S) // (D0 * S0)
        return a2a + fixed

    def what_if(self, trace: Trace, route_cap=...,
                n_devices: Optional[int] = None,
                n_stages: Optional[int] = None) -> dict:
        """Predicted mean per-tick seconds if the recorded stream were
        replayed at a candidate route_cap / device count / stage count:
        the fitted per-row model on the observed work volumes, plus the
        EXACT wire-byte delta priced at the roofline interconnect
        bandwidth."""
        cols = trace.columns
        base = float(np.mean(self.predict(cols)))
        bytes0 = int(self.meta["wire_bytes_per_tick"])
        bytes1 = self.wire_bytes_at(route_cap=route_cap,
                                    n_devices=n_devices,
                                    n_stages=n_stages)
        delta_s = (bytes1 - bytes0) / ICI_BW
        return {"wire_bytes_per_tick": bytes1,
                "wire_bytes_delta": bytes1 - bytes0,
                "pred_tick_s": base + delta_s,
                "wire_delta_s": delta_s}

    # ------------------------------------------------- (de)serialization
    def to_dict(self) -> dict:
        return {"schema": COST_MODEL_SCHEMA, "intercept": self.intercept,
                "coef": dict(self.coef), "meta": self.meta}

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        if d.get("schema") != COST_MODEL_SCHEMA:
            raise ValueError(f"cost model schema {d.get('schema')!r}, "
                             f"expected {COST_MODEL_SCHEMA}")
        return cls(intercept=float(d["intercept"]),
                   coef={k: float(d["coef"].get(k, 0.0)) for k in FEATURES},
                   meta=d.get("meta", {}))


def fit_cost_model(trace: Trace, prefer_amortized: bool = True,
                   outlier: float = 10.0) -> CostModel:
    """Fit per-plane cost coefficients from a recorded trace."""
    cols = trace.columns
    mask = _fit_mask(cols, prefer_amortized, outlier)
    if not mask.any():
        raise ValueError("trace has no fit-eligible rows (wall_s > 0)")
    X = feature_matrix(cols)[mask]
    y = cols["wall_s"][mask]
    X1 = np.concatenate([np.ones((X.shape[0], 1)), X], axis=1)
    beta = _nnls(X1, y)
    coef = {k: float(b) for k, b in zip(FEATURES, beta[1:])}
    return CostModel(intercept=float(beta[0]), coef=coef,
                     meta=dict(trace.meta))
