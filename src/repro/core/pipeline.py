"""The D3-GNN dataflow pipeline driver (paper Fig. 1).

Dataset -> Partitioner -> Splitter -> GraphStorage_1 .. GraphStorage_L -> sink

The host side plays Dataset/Partitioner/Splitter: it cuts the stream into
micro-ticks, assigns parts/slots (partitioner.py) and builds padded device
batches. The device side runs one `layer_tick` per GraphStorage operator
per tick; layer l's outbox is layer l+1's inbox (the unrolled computation
graph). The final outbox materializes into a device-side embedding sink —
the paper's "materialized embedding table that can be further queried".
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core import state as st
from repro.core import windowing as win
from repro.core.explosion import layer_parallelisms, physical_busy
from repro.core.partitioner import StreamingPartitioner
from repro.core.tick import layer_tick, has_work
from repro.core.termination import TerminationCoordinator


@dataclass
class PipelineConfig:
    n_parts: int = 8                  # logical parts (= max_parallelism)
    node_cap: int = 512               # per-part vertex slots
    edge_cap: int = 2048              # per-part edge slots
    repl_cap: int = 1024              # per-part replication records
    feat_cap: int = 1024              # inbox/outbox rows per tick
    edge_tick_cap: int = 1024         # new-edge records per tick
    window: win.WindowConfig = field(default_factory=win.WindowConfig)
    partitioner: str = "hdrf"
    base_parallelism: int = 2         # p  (physical, for stats/sharding)
    explosion: float = 1.0            # lambda
    max_nodes: int = 100_000          # global id space for the host tables
    seed: int = 0


@dataclass
class StreamMetrics:
    ticks: int = 0
    emitted_total: int = 0
    reduce_msgs: int = 0
    broadcast_msgs: int = 0
    cross_part_msgs: int = 0
    dropped: int = 0
    wall_seconds: float = 0.0
    busy_logical: Optional[np.ndarray] = None

    @property
    def throughput(self) -> float:
        return self.emitted_total / self.wall_seconds if self.wall_seconds else 0.0


class D3Pipeline:
    """L chained GraphStorage operators + the host driver."""

    def __init__(self, model, params, cfg: PipelineConfig):
        """model: graph/sage.GraphSAGE (or compatible stack of layers with
        .message/.update); params: its param pytree."""
        self.model = model
        self.cfg = cfg
        self.layers = list(model.layers)
        self.params = params
        self.part = StreamingPartitioner(
            cfg.n_parts, cfg.max_nodes, method=cfg.partitioner, seed=cfg.seed)
        self.topo = st.init_topo(cfg.n_parts, cfg.edge_cap, cfg.repl_cap,
                                 cfg.node_cap)
        dims = [l.in_dim for l in self.layers] + [self.layers[-1].out_dim]
        self.states = [st.init_layer(cfg.n_parts, cfg.node_cap, dims[i],
                                     dims[i])
                       for i in range(len(self.layers))]
        self.d_out = dims[-1]
        self.sink = jnp.zeros((cfg.n_parts, cfg.node_cap, self.d_out))
        self.sink_seen = jnp.zeros((cfg.n_parts, cfg.node_cap), bool)
        self.now = 0
        self.metrics = StreamMetrics(
            busy_logical=np.zeros(cfg.n_parts, np.int64))
        self._empty_feat = ev.empty_feat_batch(cfg.feat_cap, dims[0])
        self._empty_edges = ev.edge_batch_from_numpy(
            {k: np.zeros(0, np.int64) for k in
             ("part", "edge_slot", "src_slot", "dst_slot", "dst_master_part",
              "dst_master_slot")}, cfg.edge_tick_cap)

    # ------------------------------------------------------------ host side
    def _build_batches(self, edges: Optional[np.ndarray],
                       feats: Optional[list]):
        cfg = self.cfg
        if edges is not None and len(edges):
            e_rows, r1, v1 = self.part.ingest_edges(edges)
        else:
            e_rows, r1, v1 = None, None, None
        # feature events may create vertices (cold features)
        f_parts, f_slots, f_vecs = [], [], []
        if feats:
            coalesced = {}
            for vid, vec in feats:        # host-side coalescing (last wins)
                coalesced[int(vid)] = vec
            for vid, vec in coalesced.items():
                p, s = self.part.locate_master(vid)
                f_parts.append(p)
                f_slots.append(s)
                f_vecs.append(vec)
        r2, v2 = self.part.drain_allocations()
        if r1 is not None:
            r_rows = {k: np.concatenate([r1[k], r2[k]]) for k in r2}
            v_rows = {k: np.concatenate([v1[k], v2[k]]) for k in v2}
        else:
            r_rows, v_rows = r2, v2

        eb = (ev.edge_batch_from_numpy(e_rows, cfg.edge_tick_cap)
              if e_rows is not None else self._empty_edges)
        rb = ev.repl_batch_from_numpy(r_rows, max(2 * cfg.edge_tick_cap, 1))
        vb = ev.vertex_batch_from_numpy(v_rows, max(2 * cfg.edge_tick_cap +
                                                    cfg.feat_cap, 1))
        fb = ev.feat_batch_from_numpy(
            np.asarray(f_parts), np.asarray(f_slots),
            np.asarray(f_vecs, np.float32).reshape(len(f_parts), -1)
            if f_parts else np.zeros((0, 1)),
            cfg.feat_cap, self.states[0].feat.shape[-1])
        return eb, rb, vb, fb

    # ---------------------------------------------------------- device side
    def tick(self, edges: Optional[np.ndarray] = None,
             feats: Optional[list] = None, window=None):
        """One micro-tick through the full pipeline."""
        cfg = self.cfg
        wconf = window or cfg.window
        t0 = time.perf_counter()
        eb, rb, vb, fb = self._build_batches(edges, feats)
        self.topo = st.apply_vertex_batch(self.topo, vb)
        self.topo = st.apply_repl_batch(self.topo, rb)
        self.topo = st.apply_edge_batch(self.topo, eb)

        inbox = fb
        stats_all = []
        now = jnp.asarray(self.now, jnp.int32)
        for li, layer in enumerate(self.layers):
            # topology reaches every layer; features only layer 0 (Splitter)
            self.states[li], outbox, stats = layer_tick(
                layer, self.params[f"l{li}"], self.topo, self.states[li],
                inbox, eb, rb, now, wconf, cfg.feat_cap)
            stats_all.append(stats)
            inbox = outbox
        # sink: final-layer emissions materialize the embedding table
        self.sink, self.sink_seen = _sink_update(self.sink, self.sink_seen,
                                                 inbox)
        self.now += 1
        self._accumulate(stats_all, time.perf_counter() - t0)
        return stats_all

    def _accumulate(self, stats_all, dt):
        m = self.metrics
        m.ticks += 1
        m.wall_seconds += dt
        for s in stats_all:
            m.reduce_msgs += int(s.reduce_msgs)
            m.broadcast_msgs += int(s.broadcast_msgs)
            m.cross_part_msgs += int(s.cross_part_msgs)
            m.dropped += int(s.dropped)
            m.busy_logical += np.asarray(s.busy, np.int64)
        m.emitted_total += int(stats_all[-1].emitted)

    def run_stream(self, edges: np.ndarray, feats: dict,
                   tick_edges: int = 256, feat_with_first_edge: bool = True):
        """Stream an edge list (+ node features) through the pipeline.

        feats: {vid: np.ndarray} — each vertex's feature event is injected
        in the tick its first edge appears (feature stream aligned with the
        topology stream, as in the paper's temporal edge-list datasets).
        """
        seen = set()
        for lo in range(0, len(edges), tick_edges):
            chunk = edges[lo: lo + tick_edges]
            f_events = []
            if feat_with_first_edge:
                for u in chunk.reshape(-1):
                    u = int(u)
                    if u not in seen and u in feats:
                        seen.add(u)
                        f_events.append((u, feats[u]))
            self.tick(chunk, f_events)
        return self

    def flush(self, max_ticks: int = 64, drain: bool = True) -> int:
        """Run empty ticks until the TerminationCoordinator fires.

        drain=True forces pending windows due immediately (streaming
        eviction) — the training coordinator's flush semantics (§4.3.1).
        drain=False waits for the scheduled timers (pure §5.3 behaviour)."""
        term = TerminationCoordinator()
        override = win.WindowConfig(kind=win.STREAMING) if drain else None
        for i in range(max_ticks):
            stats = self.tick(window=override)
            if term.observe(self.states, stats):
                return i + 1
        raise RuntimeError("pipeline failed to terminate "
                           f"within {max_ticks} flush ticks")

    # ------------------------------------------------------------- queries
    def embeddings(self) -> dict:
        """Materialized final-layer embeddings {vid: vector} (masters)."""
        sink = np.asarray(self.sink)
        seen = np.asarray(self.sink_seen)
        t = self.part.t
        out = {}
        for vid in range(t.max_nodes):
            p, s = t.master[vid], t.master_slot[vid]
            if p >= 0 and seen[p, s]:
                out[vid] = sink[p, s]
        return out

    def physical_busy_per_layer(self):
        """Per-layer physical busy vectors under the explosion factor."""
        cfg = self.cfg
        pars = layer_parallelisms(cfg.base_parallelism, cfg.explosion,
                                  len(self.layers), cfg.n_parts)
        return [physical_busy(self.metrics.busy_logical, p, cfg.n_parts)
                for p in pars]


@jax.jit
def _sink_update(sink, seen, fb: ev.FeatBatch):
    P, N, d = sink.shape
    idx = jnp.where(fb.valid, fb.part * N + fb.slot, P * N)
    sink = sink.reshape(P * N, d).at[idx].set(fb.feat, mode="drop")
    seen = seen.reshape(P * N).at[idx].set(True, mode="drop")
    return sink.reshape(P, N, d), seen.reshape(P, N)
