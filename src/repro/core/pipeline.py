"""The D3-GNN dataflow pipeline driver (paper Fig. 1).

Dataset -> Partitioner -> Splitter -> GraphStorage_1 .. GraphStorage_L -> sink

The host side plays Dataset/Partitioner/Splitter: it cuts the stream into
micro-ticks, assigns parts/slots (partitioner.py) and builds padded device
batches. The device side runs one tick per GraphStorage operator per tick;
layer l's outbox is layer l+1's inbox (the unrolled computation graph). The
final outbox materializes into a device-side embedding sink — the paper's
"materialized embedding table that can be further queried".

Two drivers share ONE device program (`_tick_program`: topology apply + L
staged layer ticks + sink update, all over the local part block):

  * `tick()` — the per-tick REFERENCE path. One host round-trip per
    micro-tick: rebuild numpy batches, launch one jitted tick, block on
    the tick's stats. Simple to step through; use it for debugging, for
    tests, and whenever events must be injected with tick-level control
    flow on the host.

  * `run_super_tick()` — the device-resident SUPER-TICK path (the paper's
    always-on unrolled dataflow). The host pre-stages T micro-ticks of
    padded batches (stacked along a leading T axis, one transfer per
    field), then a single jitted `jax.lax.scan` advances all L layers
    through all T ticks with the `PipelineCarry` donated at the jit
    boundary and exactly ONE host sync per super-tick (the summed stats +
    quiescence flag read). Same math, same event order — the
    golden-equivalence tests pin the two drivers to the static oracle.

Distributed execution: pass `mesh=` (a 1-D ("data",) mesh, see
`launch/mesh.py:make_stream_mesh`) and the SAME program runs inside one
`shard_map` with the part axis block-sharded across devices. Cross-part
traffic then rides the MeshRouter's fixed-capacity all_to_all instead of
the LocalRouter's flat scatter (`repro/dist/router.py`); the carry's
NamedShardings live in `repro/dist/sharding.py`. Both routers are
golden-equivalent by test.

Hybrid parallelism (ISSUE 7): a 2-D ("stage", "data") mesh
(`make_stream_mesh(stage=S)` + `PipelineConfig.n_stages=S`) additionally
pipelines the LAYER axis: layer l lives on stage l % S, each tick every
stage runs its R = L // S layers on data that is s ticks behind the
stream head, and inter-stage hops ride a packed ring in the carry
(`PipelineCarry.stage_ring`), posted with one circular `ppermute` right
after each round's compute so the hop overlaps the next round's work
(`_tick_program_2d`). Per-tick behaviour is schedule-skewed relative to
the 1-D program, but the quiescent state after `flush` is the same
fixed point (aggregator updates telescope; edge counts are
arrival-order-independent — golden-tested against the LocalRouter
reference and the static oracle). At `n_stages=1` NONE of this code is
reached: the 1-D program above runs byte-for-byte unchanged.

Delivery backend: `PipelineConfig.delivery_backend` picks how routed
records land in state — "xla" (reference scatters) or "pallas" (sorted
segment-reduce kernels, `core/delivery.py`). Both backends run the same
program under both drivers and both routers, golden-equivalent by test.

Query plane: `PipelineConfig.query_cap > 0` puts a per-part pending
point-query table (`repro/serve/query.py:QueryState`) in the carry and
runs the query stage at the end of every tick, AFTER the sink update:
embedding reads and on-device link scores answered straight from the
live sharded state, with per-query freshness (`stale_ok` vs
`consistent`). Answers ride the super-tick scan as its ys — still ONE
host sync per super-tick (the stats read now also carries the answers).
Answered rows accumulate host-side; `drain_answers()` pops them
(`repro/serve/session.py:ServeSession` wraps this with latency
accounting). `query_cap=0` (default) statically compiles the plane away.

Training plane (ISSUE 8): pass `train=TrainConfig(...)` with
`PipelineConfig.train_cap > 0` and every tick ENDS with a windowed
online training step through the live sharded state
(`core/train_plane.py`): label events ride a per-tick `LabelBatch`,
the sliding-window batch (recently-touched labeled masters) fires a
fire-masked layered backprop + Algorithm 3 update whose two cross-part
gradient hops ride the same packed wire as the routing plane, and
`TrainState` (labels, live params, per-part optimizer state,
error-feedback residuals) lives in the donated carry — still ONE host
sync per super-tick; `train_stats()` reads progress on demand.
`train_cap=0` (default) statically compiles the plane away:
the program is bit-for-bit the four-plane tick.
`serve/train_session.py:TrainSession` wraps the label queue/driver
loop, mirroring ServeSession.

Telemetry plane (ISSUE 9): `PipelineConfig.telemetry=True` turns on the
SIXTH plane — the one that watches the other five. On device, TickStats
grows exact occupancy gauges (defer-ring populations, pre-cap route and
per-part outbox demand peaks) and each tick emits one occupancy row
that rides the super-tick scan's ys — still ONE host sync. On the host,
every tick appends a row (device gauges + wall/staging timings + exact
wire bytes + ingest counts) to `telemetry/trace.py:TraceRecorder`
(`save_trace()` -> .npz) and feeds `ft/stragglers.py`; the cost model
(`telemetry/cost_model.py`) and capacity advisor
(`telemetry/advisor.py`) consume the trace offline. `telemetry=False`
(default) keeps the gauges as static zeros — XLA dead-code-eliminates
them and the program is bit-for-bit the five-plane tick.

Staging model / constraints:
  - batch capacities derive from PipelineConfig, so every tick's batches
    have identical shapes and stack cleanly along T;
  - the streaming partitioner stays host-side and sequential: staging T
    ticks replays host partitioning for each tick up front, which is valid
    because partitioner state never depends on device results;
  - donation invalidates the previous device buffers — never hold
    references to `pipe.topo`/`pipe.states`/`pipe.sink` across a
    super-tick; re-read them from the pipeline object.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import events as ev
from repro.core import state as st
from repro.core import windowing as win
from repro.core.delivery import BACKENDS as DELIVERY_BACKENDS
from repro.core.delivery import make_delivery
from repro.core.explosion import layer_parallelisms, physical_busy
from repro.core.partitioner import StreamingPartitioner
from repro.core.tick import add_stats, layer_tick_body, zero_stats
from repro.core.termination import (TerminationCoordinator, moved_msgs,
                                    quiet_update)
from repro.dist.router import LocalRouter, MeshRouter
from repro.dist.sharding import (carry_pspecs, carry_shardings,
                                 stage_carry_pspecs, stage_carry_shardings,
                                 stage_stats_pspecs, stats_pspecs)
from repro.dist.wire import field_col, pack_lane, pad_lane, unpack_lane
from repro.ft.stragglers import StragglerMitigator
from repro.telemetry.trace import TRACE_DEVICE_COLS, TraceRecorder
from repro.core.train_plane import (TrainConfig, init_train_state,
                                    train_pspecs, train_shardings,
                                    train_stage)
from repro.serve.query import (KIND_EMBED, KIND_LINK, add_query_stats,
                               empty_query_batch, init_query_state,
                               query_admit_stage, query_answer_stage,
                               query_batch_from_numpy, wire_width,
                               zero_query_stats)


@dataclass(frozen=True)
class Capacities:
    """Every RESOLVED per-tick budget of a (config, mesh) pair — the one
    documented view of the capacity arithmetic that used to be spread
    over `outbox()` / `query_admissions()` / `defer_rows()` (now thin
    deprecated shims).  Read it once per launch site:

        caps = cfg.capacities(n_devices)

    Defer-ring rows are GLOBAL (n_devices * per-device) and 0 whenever
    the capped exchange cannot overflow (dense default, one device, or
    route_cap >= the lane capacity) — a zero compiles the backpressure
    path away (dist/wire.py)."""
    outbox: int            # per-tick emission budget (rows, all parts)
    outbox_per_part: int   # emission slots per part (outbox // n_parts)
    query_admissions: int  # query rows admitted per tick (0 = plane off)
    train_cap: int         # label rows admitted per tick (0 = plane off)
    bc_defer_rows: int     # broadcast-lane defer-ring rows
    rmi_defer_rows: int    # RMI-lane defer-ring rows
    query_defer_rows: int  # query-wire-lane defer-ring rows


@dataclass
class PipelineConfig:
    n_parts: int = 8                  # logical parts (= max_parallelism)
    node_cap: int = 512               # per-part vertex slots
    edge_cap: int = 2048              # per-part edge slots
    repl_cap: int = 1024              # per-part replication records
    feat_cap: int = 1024              # host-inbox feature rows per tick
    outbox_cap: Optional[int] = None  # per-tick emission budget (default:
                                      # feat_cap), split evenly over parts
    edge_tick_cap: int = 1024         # new-edge records per tick
    query_cap: int = 0                # per-part pending point-query slots
                                      # (0 = query plane compiled away)
    query_tick_cap: Optional[int] = None  # query admissions per tick
                                      # (default: query_cap * n_parts)
    train_cap: int = 0                # training plane (ISSUE 8): label
                                      # admissions per tick (0 = the plane
                                      # compiles away; > 0 needs a
                                      # TrainConfig passed as
                                      # D3Pipeline(train=...))
    route_cap: Optional[int] = None   # routing plane: per-destination
                                      # all_to_all bucket rows (None = each
                                      # lane's full capacity — dense,
                                      # never-overflow semantics); smaller
                                      # caps shrink the wire D x C -> D x
                                      # cap and defer overflow as
                                      # backpressure (dist/router.py)
    route_defer_cap: Optional[int] = None  # per-device defer-ring rows per
                                      # lane (default: the lane's local
                                      # capacity); only meaningful with
                                      # route_cap set on a multi-device
                                      # mesh
    window: win.WindowConfig = field(default_factory=win.WindowConfig)
    delta_eps: float = 0.0            # delta-gated propagation (ISSUE 6):
                                      # a touched vertex only re-emits when
                                      # ||phi(x) - phi(x_sent)|| > eps
                                      # (core/tick.py:round_b_emit). 0.0 =
                                      # exact mode, bit-for-bit the ungated
                                      # program; > 0 bounds the per-vertex
                                      # un-sent delta by eps (approximate,
                                      # error-bounded) and coalesces
                                      # same-destination RMIs pre-routing
    delivery_backend: str = "xla"     # how routed records land in state
                                      # ("xla" scatters | "pallas" kernels)
    n_stages: int = 1                 # hybrid parallelism (ISSUE 7): number
                                      # of pipeline stages on a 2-D
                                      # ("stage","data") mesh — layer l runs
                                      # on stage l % n_stages and micro-ticks
                                      # flow as a circular pipeline. Must
                                      # match make_stream_mesh(stage=...);
                                      # 1 (default) = the layer-sequential
                                      # 1-D program, bit-for-bit
    telemetry: bool = False           # telemetry plane (ISSUE 9): True
                                      # lights up exact per-plane occupancy
                                      # gauges (TickStats/RouteReceipt), a
                                      # per-tick occupancy row riding the
                                      # scan ys, and the host-side
                                      # TraceRecorder (D3Pipeline.trace) +
                                      # StragglerMitigator feed. False
                                      # (default) compiles every gauge to a
                                      # static zero — bit-for-bit the
                                      # untraced program
    partitioner: str = "hdrf"
    base_parallelism: int = 2         # p  (physical, for stats/sharding)
    explosion: float = 1.0            # lambda
    max_nodes: int = 100_000          # global id space for the host tables
    seed: int = 0

    # -------------------------------------------- resolved budget views
    def capacities(self, n_devices: int = 1) -> Capacities:
        """The one documented view of every resolved per-tick budget.

        n_devices is the DATA-axis device count (defer-ring rows are
        sized per data shard); 1 covers the LocalRouter and any
        single-data-shard mesh.  See `Capacities` for field semantics.
        """
        p_loc = self.n_parts // max(n_devices, 1)
        return Capacities(
            outbox=self._outbox(),
            outbox_per_part=max(1, self._outbox() // self.n_parts),
            query_admissions=self._query_admissions(),
            train_cap=self.train_cap,
            bc_defer_rows=self._defer_rows(p_loc * self.repl_cap,
                                           n_devices),
            rmi_defer_rows=self._defer_rows(
                self.edge_tick_cap + p_loc * self.edge_cap, n_devices),
            query_defer_rows=self._defer_rows(p_loc * self.query_cap,
                                              n_devices))

    def _outbox(self) -> int:
        return self.feat_cap if self.outbox_cap is None else self.outbox_cap

    def _query_admissions(self) -> int:
        if self.query_cap <= 0:
            return 0
        return (self.query_cap * self.n_parts if self.query_tick_cap is None
                else self.query_tick_cap)

    def _defer_rows(self, lane_capacity: int, n_devices: int) -> int:
        if n_devices <= 1 or self.route_cap is None:
            return 0
        if self.route_cap >= lane_capacity:    # bucket >= lane: no overflow
            return 0
        per_dev = (lane_capacity if self.route_defer_cap is None
                   else self.route_defer_cap)
        return n_devices * per_dev

    # deprecated accessors — the pre-ISSUE-8 API, kept as thin shims
    def outbox(self) -> int:
        """Deprecated: read `capacities().outbox` instead."""
        warnings.warn("PipelineConfig.outbox() is deprecated — read "
                      "capacities().outbox", DeprecationWarning,
                      stacklevel=2)
        return self._outbox()

    def query_admissions(self) -> int:
        """Deprecated: read `capacities().query_admissions` instead."""
        warnings.warn("PipelineConfig.query_admissions() is deprecated — "
                      "read capacities().query_admissions",
                      DeprecationWarning, stacklevel=2)
        return self._query_admissions()

    def defer_rows(self, lane_capacity: int, n_devices: int) -> int:
        """Deprecated: read the `*_defer_rows` fields of
        `capacities(n_devices)` instead."""
        warnings.warn("PipelineConfig.defer_rows() is deprecated — read "
                      "capacities(n_devices).{bc,rmi,query}_defer_rows",
                      DeprecationWarning, stacklevel=2)
        return self._defer_rows(lane_capacity, n_devices)

    def validate(self, n_devices: int = 1, n_layers: Optional[int] = None,
                 local: bool = False) -> None:
        """Fail fast with a clear message instead of a shard_map shape
        error deep inside the tick program.

        n_devices counts the WHOLE mesh (stage * data on a 2-D mesh);
        n_layers enables the layer-placement divisibility check; local
        flags a LocalRouter pipeline (no mesh), which cannot host
        pipeline stages."""
        if self.n_stages < 1:
            raise ValueError(
                f"PipelineConfig.n_stages={self.n_stages} must be >= 1 "
                "(1 = the layer-sequential 1-D program)")
        if self.n_stages > 1:
            if local:
                raise ValueError(
                    f"PipelineConfig.n_stages={self.n_stages} needs a 2-D "
                    "('stage','data') mesh (make_stream_mesh(stage=...)): "
                    "the LocalRouter has no stage axis to place layers on "
                    "and would silently run them layer-sequentially — "
                    "pass mesh= or set n_stages=1")
            if n_devices % self.n_stages:
                raise ValueError(
                    f"n_devices={n_devices} is not divisible by "
                    f"n_stages={self.n_stages}: the mesh factors as "
                    "(stage, data) = (n_stages, n_devices // n_stages), "
                    "so pick a device count that is a multiple of the "
                    "stage count")
            if n_layers is not None and n_layers % self.n_stages:
                raise ValueError(
                    f"n_layers={n_layers} is not divisible by "
                    f"n_stages={self.n_stages}: layers are placed "
                    "round-robin on stages (layer l on stage l % S) and "
                    "every stage must carry the same number of rounds — "
                    "use a stage count that divides the layer count")
        caps = {"n_parts": self.n_parts, "node_cap": self.node_cap,
                "edge_cap": self.edge_cap, "repl_cap": self.repl_cap,
                "feat_cap": self.feat_cap,
                "outbox_cap (capacities().outbox)": self._outbox(),
                "edge_tick_cap": self.edge_tick_cap}
        for name, v in caps.items():
            if v <= 0:
                raise ValueError(f"PipelineConfig.{name}={v} must be > 0")
        if self.query_cap < 0:
            raise ValueError(f"PipelineConfig.query_cap={self.query_cap} "
                             "must be >= 0 (0 disables the query plane)")
        if self.query_cap == 0 and self.query_tick_cap:
            raise ValueError(
                "PipelineConfig.query_tick_cap is set but query_cap=0 — "
                "the query plane is disabled; set query_cap > 0 to serve")
        if self.query_cap > 0 and self._query_admissions() <= 0:
            raise ValueError(
                f"PipelineConfig.query_tick_cap={self.query_tick_cap} "
                "must be > 0 (capacities().query_admissions) when the "
                "query plane is enabled")
        if self.train_cap < 0:
            raise ValueError(
                f"PipelineConfig.train_cap={self.train_cap} must be >= 0 "
                "(0 disables the training plane; see "
                "capacities().train_cap)")
        if not (self.delta_eps >= 0.0):   # rejects negatives AND NaN
            raise ValueError(
                f"PipelineConfig.delta_eps={self.delta_eps} must be a "
                "finite value >= 0 (0 = exact/ungated propagation)")
        if self.route_cap is not None and self.route_cap <= 0:
            raise ValueError(
                f"PipelineConfig.route_cap={self.route_cap} must be > 0 "
                "(or None for the dense never-overflow exchange)")
        if self.route_defer_cap is not None and self.route_defer_cap < 0:
            raise ValueError(
                f"PipelineConfig.route_defer_cap={self.route_defer_cap} "
                "must be >= 0 (0 disables deferral: bucket overflow then "
                "drops, counted in TickStats.route_dropped)")
        # parts shard over the DATA axis only — on a 2-D mesh each stage
        # row replicates the same part blocks over n_devices // n_stages
        # data shards
        data_devs = n_devices // self.n_stages if self.n_stages > 1 \
            else n_devices
        if (self.route_defer_cap == 0 and self.query_cap > 0
                and self.route_cap is not None and data_devs > 1
                and self.route_cap < (self.n_parts // data_devs)
                * self.query_cap):
            raise ValueError(
                "route_defer_cap=0 with a capped query wire lane "
                f"(route_cap={self.route_cap} < per-device wire capacity "
                f"{(self.n_parts // n_devices) * self.query_cap}): a "
                "dropped link-tail record would strand its qid with no "
                "ok=False answer — MsgBatch lanes may drop loudly, the "
                "wire lane must be able to defer. Leave route_defer_cap "
                "unset (defaults to the lane capacity) or raise route_cap")
        if self.delivery_backend not in DELIVERY_BACKENDS:
            raise ValueError(
                f"PipelineConfig.delivery_backend="
                f"{self.delivery_backend!r} is not registered: pick one of "
                f"{sorted(DELIVERY_BACKENDS)} (core/delivery.py)")
        if self._outbox() % self.n_parts:
            raise ValueError(
                f"the emission budget capacities().outbox="
                f"{self._outbox()} (outbox_cap or feat_cap) must be a "
                f"multiple of n_parts={self.n_parts}: it is split into "
                "capacities().outbox_per_part emission slots per part")
        if data_devs > 1 and self.n_parts % data_devs:
            raise ValueError(
                f"n_parts={self.n_parts} is not divisible by the mesh's "
                f"{data_devs} devices: the part axis is block-sharded over "
                "('data',), so pick n_parts as a multiple of the device "
                "count (each device owns n_parts // n_devices parts)")


@dataclass
class StreamMetrics:
    ticks: int = 0
    emitted_total: int = 0
    reduce_msgs: int = 0
    broadcast_msgs: int = 0
    cross_part_msgs: int = 0
    dropped: int = 0
    queries_admitted: int = 0
    queries_answered: int = 0
    queries_dropped: int = 0
    query_hold_ticks: int = 0          # pending-query-ticks (backlog integral)
    # measured routing-plane wire telemetry (ISSUE 5): summed over every
    # all_to_all launch of every tick — what bench_comm_volume.py reports
    wire_rows: int = 0                 # live records shipped on the wire
    wire_bytes: int = 0                # exchanged send-buffer bytes
    route_deferred: int = 0            # records carried by backpressure
    route_dropped: int = 0             # records lost to FULL defer rings
                                       # (0 in any correctly-sized config)
    suppressed: int = 0                # delta-gated RMIs NOT emitted
                                       # (ISSUE 6; 0 at delta_eps=0) —
                                       # the saved message volume:
                                       # reduce_msgs + suppressed tracks
                                       # the ungated reduce_msgs
    stage_idle: int = 0                # hybrid pipeline bubbles (ISSUE 7):
                                       # device-rounds that saw an EMPTY
                                       # inbox, summed over ticks — 0 on a
                                       # 1-D mesh; D3Pipeline.
                                       # bubble_fraction() normalizes it
    # telemetry plane (ISSUE 9) — all 0 unless PipelineConfig.telemetry:
    occ_defer_ticks: int = 0           # defer-ring backlog INTEGRAL
                                       # (end-of-tick bc+rmi ring rows,
                                       # summed over ticks — the
                                       # query_hold_ticks convention)
    route_peak: int = 0                # MAX per-tick per-dest bucket
                                       # demand pre-cap (the zero-defer
                                       # route_cap for the traffic seen)
    outbox_peak: int = 0               # MAX per-tick per-layer GLOBAL
                                       # emission demand (emitted+dropped)
    outbox_part_peak: int = 0          # MAX per-tick PER-PART eviction
                                       # demand — the cap binds per part,
                                       # so zero-drop needs outbox_cap >=
                                       # n_parts x outbox_part_peak
    host_seconds: float = 0.0          # host-side staging time (per-tick
                                       # driver only; the scan driver's
                                       # staging amortizes into wall)
    wall_seconds: float = 0.0
    busy_logical: Optional[np.ndarray] = None

    @property
    def throughput(self) -> float:
        return self.emitted_total / self.wall_seconds if self.wall_seconds else 0.0


@dataclass(frozen=True)
class StagedActLayer:
    """SPMD-uniform stand-in for one pipeline ROUND of layers.

    Under stage parallelism one compiled `layer_tick_body` runs for every
    stage of a round, but GraphSAGE stacks put `act=False` on the final
    layer only — the one per-layer difference that is CODE, not data. The
    wrapper moves it into data: `base` is the round's layer with act
    forced off, and the staged params carry {"p": the layer's params,
    "act": 0/1 float} stacked over the stage axis, so the relu rides a
    `jnp.where` on a per-stage leaf instead of a per-layer Python branch.
    Valid for any layer whose activation is exactly a final relu
    (SAGELayer / GCNLayer); D3Pipeline enforces the rest of the
    uniformity contract (same class / dims / aggregator across layers).
    """
    base: object

    @property
    def agg_kind(self):
        return getattr(self.base, "agg_kind", "mean")

    @property
    def in_dim(self):
        return self.base.in_dim

    @property
    def out_dim(self):
        return self.base.out_dim

    def message(self, params, x):
        return self.base.message(params["p"], x)

    def update(self, params, x, agg):
        h = self.base.update(params["p"], x, agg)
        return jnp.where(params["act"] > 0, jax.nn.relu(h), h)


class D3Pipeline:
    """L chained GraphStorage operators + the host driver."""

    def __init__(self, model, params, cfg: PipelineConfig, mesh=None,
                 train: Optional[TrainConfig] = None):
        """model: graph/sage.GraphSAGE (or compatible stack of layers with
        .message/.update); params: its param pytree.
        mesh: optional jax mesh — 1-D ("data",) shards the part axis of
        the tick program across its devices (MeshRouter); 2-D ("stage",
        "data") with cfg.n_stages > 1 additionally pipelines the layer
        axis (`make_stream_mesh(stage=...)`).
        train: optional TrainConfig — enables the ONLINE training plane
        (cfg.train_cap > 0 required): every tick ends with a windowed
        training step over the live sharded state
        (core/train_plane.py)."""
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        mesh_shape = dict(mesh.shape) if mesh is not None else {}
        S = int(mesh_shape.get("stage", 1))
        n_dev = int(mesh_shape.get("data", 1))
        if mesh is not None and S != cfg.n_stages:
            raise ValueError(
                f"mesh has stage={S} but PipelineConfig.n_stages="
                f"{cfg.n_stages}: the stage counts must agree — build the "
                "mesh with make_stream_mesh(stage=n_stages)")
        cfg.validate(n_devices=S * n_dev, n_layers=len(model.layers),
                     local=mesh is None)
        if (train is not None) != (cfg.train_cap > 0):
            raise ValueError(
                f"train={'set' if train is not None else 'None'} but "
                f"PipelineConfig.train_cap={cfg.train_cap}: the online "
                "training plane needs BOTH a TrainConfig (the knobs) and "
                "train_cap > 0 (the per-tick label admission budget, "
                "capacities().train_cap) — set both or neither")
        if train is not None and "head" not in params:
            raise ValueError(
                "train= needs an output operator: build the model with "
                "n_classes > 0 (GraphSAGE(dims, n_classes=...)) so its "
                "params carry a 'head' entry to train")
        self.train_cfg = train
        self._head = getattr(model, "head", None) if train is not None \
            else None
        self.n_stages = S
        self._n_data = n_dev
        self.router = (MeshRouter(cfg.n_parts, n_dev,
                                  route_cap=cfg.route_cap,
                                  pack_backend=cfg.delivery_backend,
                                  stage_axis="stage" if S > 1 else None,
                                  n_stages=S, telemetry=cfg.telemetry)
                       if mesh is not None else LocalRouter(cfg.n_parts))
        self.delivery = make_delivery(cfg.delivery_backend)
        self.layers = list(model.layers)
        self.params = params
        self.part = StreamingPartitioner(
            cfg.n_parts, cfg.max_nodes, method=cfg.partitioner, seed=cfg.seed)
        self.topo = st.init_topo(cfg.n_parts, cfg.edge_cap, cfg.repl_cap,
                                 cfg.node_cap)
        dims = [l.in_dim for l in self.layers] + [self.layers[-1].out_dim]
        # every resolved per-tick budget, incl. the routing-plane
        # backpressure rings sized per lane from the LOCAL (per-device)
        # emission capacities (0 rows = compiled away)
        caps = cfg.capacities(n_dev)
        p_loc = cfg.n_parts // n_dev
        bc_rows = caps.bc_defer_rows
        rmi_rows = caps.rmi_defer_rows
        if S > 1:
            self._check_uniform_layers(dims)
            self._n_rounds = len(self.layers) // S
            self.rounds = (StagedActLayer(
                base=replace(self.layers[0], act=False)),) * self._n_rounds
            d = dims[0]
            proto = st.init_layer(cfg.n_parts, cfg.node_cap, d, d,
                                  bc_defer_rows=bc_rows,
                                  rmi_defer_rows=rmi_rows)
            # round r's state stacks layers r*S+0 .. r*S+S-1 over a
            # leading stage axis (all layers initialize identically)
            self.states = [jax.tree.map(lambda a: jnp.stack([a] * S), proto)
                           for _ in range(self._n_rounds)]
        else:
            self._n_rounds = len(self.layers)
            self.rounds = None
            self.states = [st.init_layer(cfg.n_parts, cfg.node_cap, dims[i],
                                         dims[i], bc_defer_rows=bc_rows,
                                         rmi_defer_rows=rmi_rows)
                           for i in range(len(self.layers))]
        self.d_out = dims[-1]
        self.sink = jnp.zeros((cfg.n_parts, cfg.node_cap, self.d_out))
        self.sink_seen = jnp.zeros((cfg.n_parts, cfg.node_cap), bool)
        self.queries = init_query_state(
            cfg.n_parts, cfg.query_cap, self.d_out,
            wire_defer_rows=caps.query_defer_rows)
        # the training plane's device state: labels/dirty window, live
        # params, per-part optimizer state (core/train_plane.py)
        self.train_state = (init_train_state(
            cfg.n_parts, cfg.node_cap,
            {f"l{i}": params[f"l{i}"] for i in range(len(self.layers))},
            params["head"], train) if train is not None else None)
        self._acts = tuple(
            1.0 if getattr(l, "act", False) else 0.0 for l in self.layers)
        # inter-stage ring: one fixed packed-FeatBatch slot shape carries
        # both the host inbox (feat_cap rows) and any round's outbox
        # (p_loc * cap_pp rows) between stages
        cap_pp = caps.outbox_per_part
        self._ring_caps = (max(cfg.feat_cap, p_loc * cap_pp), dims[0] + 3)
        self.stage_ring = (jnp.zeros(
            (S, self._n_rounds, n_dev * self._ring_caps[0],
             self._ring_caps[1]), jnp.float32) if S > 1 else None)
        self._wire_bytes_per_tick = self._static_wire_bytes(dims, n_dev, S)
        if mesh is not None and S > 1:
            sh = stage_carry_shardings(mesh, self._n_rounds)
            self.topo = jax.device_put(self.topo, sh.topo)
            self.states = [jax.device_put(s, sh.layers[i])
                           for i, s in enumerate(self.states)]
            self.sink = jax.device_put(self.sink, sh.sink)
            self.sink_seen = jax.device_put(self.sink_seen, sh.sink_seen)
            self.queries = jax.device_put(self.queries, sh.queries)
            self.stage_ring = jax.device_put(self.stage_ring, sh.stage_ring)
        elif mesh is not None:
            sh = carry_shardings(mesh, len(self.layers))
            self.topo = jax.device_put(self.topo, sh.topo)
            self.states = [jax.device_put(s, sh.layers[i])
                           for i, s in enumerate(self.states)]
            self.sink = jax.device_put(self.sink, sh.sink)
            self.sink_seen = jax.device_put(self.sink_seen, sh.sink_seen)
            self.queries = jax.device_put(self.queries, sh.queries)
        if mesh is not None and self.train_state is not None:
            self.train_state = jax.device_put(
                self.train_state, train_shardings(mesh, self.train_state))
        self.now = 0
        self.metrics = StreamMetrics(
            busy_logical=np.zeros(cfg.n_parts, np.int64))
        self._empty_feat = ev.empty_feat_batch(cfg.feat_cap, dims[0])
        empty_rows = {k: np.zeros(0, np.int64) for k in
                      ("part", "edge_slot", "src_slot", "dst_slot",
                       "dst_master_part", "dst_master_slot")}
        self._empty_edges = ev.edge_batch_from_numpy(
            empty_rows, cfg.edge_tick_cap)
        # host-resident twin for super-tick staging (stacked before upload)
        self._empty_edges_np = ev.edge_batch_from_numpy(
            empty_rows, cfg.edge_tick_cap, device=False)
        self._empty_queries = empty_query_batch(caps.query_admissions,
                                                self.d_out)
        self._empty_queries_np = empty_query_batch(caps.query_admissions,
                                                   self.d_out, device=False)
        z0 = np.zeros(0, np.int64)
        self._empty_labels = ev.empty_label_batch(cfg.train_cap)
        self._empty_labels_np = ev.label_batch_from_numpy(
            z0, z0, z0, cfg.train_cap, device=False)
        self._answer_log: list = []    # host-side answered-row columns
        # telemetry plane (ISSUE 9): the trace recorder + straggler feed.
        # The lane list / a2a multiplier let the cost model re-price wire
        # bytes at candidate route_caps without re-deriving the lane
        # arithmetic (same constants as _static_wire_bytes above).
        lanes = self._wire_lane_list(dims, n_dev, S)
        a2a_mult = (S * n_dev * n_dev * 4
                    if mesh is not None and n_dev > 1 else 0)
        a2a = a2a_mult * sum(self.router.lane_cap(c) * w for c, w in lanes)
        if cfg.telemetry:
            from dataclasses import asdict
            self.trace = TraceRecorder(meta={
                "n_parts": cfg.n_parts, "n_devices": n_dev, "n_stages": S,
                "n_layers": len(self.layers), "dims": list(dims),
                "window": cfg.window.kind,
                "delivery_backend": cfg.delivery_backend,
                "delta_eps": cfg.delta_eps,
                "route_cap": cfg.route_cap,
                "route_defer_cap": cfg.route_defer_cap,
                "node_cap": cfg.node_cap, "edge_cap": cfg.edge_cap,
                "repl_cap": cfg.repl_cap, "feat_cap": cfg.feat_cap,
                "edge_tick_cap": cfg.edge_tick_cap,
                "query_cap": cfg.query_cap,
                "query_tick_cap": cfg.query_tick_cap,
                "train_cap": cfg.train_cap,
                "caps": asdict(caps),
                "wire_bytes_per_tick": self._wire_bytes_per_tick,
                "wire_lanes": [list(l) for l in lanes],
                "a2a_mult": a2a_mult,
                "fixed_wire_bytes": self._wire_bytes_per_tick - a2a})
            self.straggler = StragglerMitigator(n_shards=max(n_dev, 1))
        else:
            self.trace = None
            self.straggler = None

    def _wire_lane_list(self, dims, n_dev: int, n_stages: int = 1):
        """The capped-exchange lanes of one tick as (local emission
        capacity, wire width) pairs — the SAME constants
        `_static_wire_bytes` prices (its a2a term is
        a2a_mult * sum(lane_cap(c) * w)); recorded in the trace meta so
        the cost model can replay wire bytes at a different route_cap."""
        if self.mesh is None or n_dev <= 1:
            return []
        cfg = self.cfg
        p_loc = cfg.n_parts // n_dev
        lanes = []
        n_lay = self._n_rounds if n_stages > 1 else len(self.layers)
        for li in range(n_lay):
            d = dims[0] if n_stages > 1 else dims[li]
            lanes.append((p_loc * cfg.repl_cap, d + 5))
            lanes.append((cfg.edge_tick_cap + p_loc * cfg.edge_cap, d + 5))
        if cfg.query_cap > 0:
            lanes.append((p_loc * cfg.query_cap, wire_width(self.d_out)))
        return lanes

    def save_trace(self, path) -> None:
        """Write the recorded telemetry trace (needs cfg.telemetry)."""
        assert self.trace is not None, \
            "telemetry plane disabled (PipelineConfig.telemetry=False)"
        self.trace.save(path)

    def parts_per_shard(self) -> list:
        """Logical parts owned by each data shard (block sharding) — the
        StragglerMitigator's work-steal planner input."""
        D = max(self._n_data, 1)
        p_loc = self.cfg.n_parts // D
        return [np.arange(d * p_loc, (d + 1) * p_loc) for d in range(D)]

    def _static_wire_bytes(self, dims, n_dev: int, n_stages: int = 1) -> int:
        """EXACT collective bytes per tick across the whole mesh — a
        compile-time constant of (config, mesh): every device ships a
        [D, cap * W] f32 send buffer per lane per route_lanes call, so
        per-tick bytes = D * sum_lanes D * cap * W * 4. Accounted here in
        host int arithmetic (StreamMetrics.wire_bytes) instead of on
        device, where a float counter would round past 2**24 and an
        int32 one would overflow at production capacities. The lane
        capacities/widths are the same constants the defer-ring sizing
        above uses (MsgBatch width d + 5, QueryBatch width d + 10).

        On a 2-D mesh the data-axis exchange happens once per ROUND per
        stage row (each stage runs R = L // S layers), the query wire
        rides round 0 on EVERY stage (QueryState is stage-replicated),
        and the stage axis adds its own wires: one [C_buf, W_fb] ppermute
        per round per device plus the final-round all_gather feeding the
        replicated sinks (S - 1 foreign slots per device).

        The TRAINING plane (cfg.train_cap > 0) adds two DENSE lanes per
        layer per tick (hop A: repl_cap rows of dagg; hop B: node_cap
        rows of source gradients — always full capacity, route_cap does
        not apply to gradient lanes) and, on a 2-D mesh, the per-round
        stage all_gather of the layer caches (feat/agg/agg_cnt) every
        stage's backward reads."""
        if self.mesh is None:
            return 0
        cfg = self.cfg
        p_loc = cfg.n_parts // n_dev
        if n_stages > 1:
            lanes = []
            for _ in range(self._n_rounds):
                lanes.append((p_loc * cfg.repl_cap, dims[0] + 5))
                lanes.append((cfg.edge_tick_cap + p_loc * cfg.edge_cap,
                              dims[0] + 5))
            if cfg.query_cap > 0:
                lanes.append((p_loc * cfg.query_cap,
                              wire_width(self.d_out)))
            a2a = (n_stages * n_dev
                   * sum(n_dev * self.router.lane_cap(c) * w * 4
                         for c, w in lanes) if n_dev > 1 else 0)
            C_buf, W_fb = self._ring_caps
            slot = C_buf * W_fb * 4
            ring = n_stages * n_dev * self._n_rounds * slot
            gather = n_stages * n_dev * (n_stages - 1) * slot
            train = 0
            if self.train_cfg is not None:
                d = dims[0]
                if n_dev > 1:
                    train += (n_stages * n_dev * len(self.layers)
                              * n_dev * (p_loc * cfg.repl_cap
                                         + p_loc * cfg.node_cap)
                              * (d + 5) * 4)
                train += (n_stages * n_dev * (n_stages - 1)
                          * self._n_rounds
                          * p_loc * cfg.node_cap * (2 * d + 1) * 4)
            return a2a + ring + gather + train
        if n_dev <= 1:
            return 0
        lanes = []
        for li in range(len(self.layers)):
            lanes.append((p_loc * cfg.repl_cap, dims[li] + 5))
            lanes.append((cfg.edge_tick_cap + p_loc * cfg.edge_cap,
                          dims[li] + 5))
        if cfg.query_cap > 0:
            lanes.append((p_loc * cfg.query_cap, wire_width(self.d_out)))
        total = n_dev * sum(n_dev * self.router.lane_cap(c) * w * 4
                            for c, w in lanes)
        if self.train_cfg is not None:
            total += n_dev * sum(
                n_dev * (p_loc * cfg.repl_cap + p_loc * cfg.node_cap)
                * (dims[li] + 5) * 4 for li in range(len(self.layers)))
        return total

    def _check_uniform_layers(self, dims) -> None:
        """Stage parallelism runs ONE compiled round body for every layer
        of a round, so the stack must be SPMD-uniform: same layer class,
        same aggregator, and in_dim == out_dim == d for every layer (one
        stacked state tree + one ring row width serve all rounds). The
        activation flag is exempt — StagedActLayer turns it into data."""
        base = self.layers[0]
        uniform = (len(set(dims)) == 1 and all(
            type(l) is type(base) and hasattr(l, "act")
            and getattr(l, "agg_kind", "mean")
            == getattr(base, "agg_kind", "mean")
            for l in self.layers))
        if not uniform:
            raise ValueError(
                f"PipelineConfig.n_stages={self.cfg.n_stages} needs an "
                "SPMD-uniform layer stack (same class/aggregator, in_dim "
                "== out_dim on every layer, differing at most in the "
                f"activation flag), got dims={dims} over "
                f"{[type(l).__name__ for l in self.layers]} — pipeline "
                "stages run one shared round body per stage")

    # ----------------------------------------------- hybrid-parallel host
    def _staged_params(self):
        """Per-round staged params for the pipelined program: round r's
        entry stacks layers r*S+0 .. r*S+S-1's params over a leading
        stage axis, plus the per-stage activation flag as a 0/1 float
        leaf (StagedActLayer). Rebuilt per launch from `self.params` so
        checkpoint restores of `params` need no extra bookkeeping."""
        S = self.n_stages
        out = {}
        for r in range(self._n_rounds):
            per = [self.params[f"l{r * S + s}"] for s in range(S)]
            out[f"r{r}"] = {
                "p": jax.tree.map(lambda *xs: jnp.stack(xs), *per),
                "act": jnp.asarray(
                    [1.0 if self.layers[r * S + s].act else 0.0
                     for s in range(S)], jnp.float32)}
        return out

    def _unstack_stats(self, host_stats):
        """Per-ROUND stacked stats ([S] scalars / [S, n_parts] busy) ->
        the 1-D drivers' per-LAYER list: layer l = r*S + s sits at index
        s of round r's stack."""
        out = []
        for l in range(len(self.layers)):
            r, s = divmod(l, self.n_stages)
            out.append(jax.tree.map(lambda a: a[s], host_stats[r]))
        return out

    def layer_state(self, l: int):
        """Host view of layer l's LayerState regardless of mesh shape: the
        1-D engine stores one state per layer; the hybrid engine stores one
        stage-STACKED state per round, with layer l = r*S + s living at
        stage index s of round r."""
        if self.n_stages == 1:
            return self.states[l]
        r, s = divmod(l, self.n_stages)
        return jax.tree.map(lambda a: a[s], self.states[r])

    def set_layer_state(self, l: int, st) -> None:
        """Write a per-layer LayerState back (inverse of layer_state) —
        used by the training coordinator's phased rebuild."""
        if self.n_stages == 1:
            self.states[l] = st
            return
        r, s = divmod(l, self.n_stages)
        self.states[r] = jax.tree.map(
            lambda a, leaf: a.at[s].set(leaf), self.states[r], st)

    def _ring_occupancy_host(self) -> int:
        """Valid rows still in flight between stages (0 on a 1-D mesh) —
        the host-driver flush must not terminate over them."""
        if self.stage_ring is None:
            return 0
        return int(jnp.sum(self.stage_ring[..., -1] > 0.5))

    def bubble_fraction(self) -> float:
        """Measured pipeline-bubble fraction: device-rounds that saw an
        empty inbox over total device-rounds (0.0 on a 1-D mesh)."""
        total = self.metrics.ticks * len(self.layers) * self._n_data
        if self.n_stages <= 1 or total == 0:
            return 0.0
        return self.metrics.stage_idle / total

    # --------------------------------------------- live elastic resharding
    def reshard(self, new_mesh, cfg: Optional[PipelineConfig] = None):
        """LIVE Alg. 5 elastic reshard (ISSUE 10): relay the whole carry —
        layer tables, defer rings, the inter-stage ring, QueryState,
        TrainState + optimizer state — from the current mesh onto
        `new_mesh` (another D-shard or S'xD' grid, or None for the
        LocalRouter) without dropping in-flight work.

        State arrays are keyed by LOGICAL part (fixed at n_parts), so the
        [P, ...] tables relayout with one `jax.device_put` onto the new
        shardings — no host round-trip per array, no graph
        re-partitioning. Only the three packed row buffers whose LAYOUT
        depends on the device count need re-blocking (ft/elastic.py):
        defer rings compact into the new global capacity (rows are
        destination-addressed — the router recomputes dst = part // p_loc
        at exchange time), and inter-stage ring slabs re-block by part
        ownership under the new p_loc (delivery drops rows outside the
        owner's block). Held `consistent` queries ride the QueryState
        tables and answer after the move exactly as without it.

        `cfg` optionally replaces the config (defaults to the current one
        with n_stages matched to the new mesh); it is validated against
        the new grid and installed — the PREVIOUS config object is never
        mutated. A stage-count change requires an empty inter-stage ring
        (flush() first); a reshard that would overflow the new defer
        capacities raises instead of silently dropping rows. Returns the
        installed config."""
        from repro.ft.elastic import repack_defer_ring, repack_stage_slab

        L = len(self.layers)
        mesh_shape = dict(new_mesh.shape) if new_mesh is not None else {}
        S = int(mesh_shape.get("stage", 1))
        n_dev = int(mesh_shape.get("data", 1))
        if cfg is None:
            cfg = replace(self.cfg, n_stages=S)
        if new_mesh is not None and S != cfg.n_stages:
            raise ValueError(
                f"new mesh has stage={S} but cfg.n_stages={cfg.n_stages}: "
                "the stage counts must agree")
        cfg.validate(n_devices=S * n_dev, n_layers=L,
                     local=new_mesh is None)
        if (self.train_state is not None) != (cfg.train_cap > 0):
            raise ValueError(
                "reshard cannot turn the training plane on or off: "
                f"train_state is {'set' if self.train_state is not None else 'None'} "
                f"but cfg.train_cap={cfg.train_cap}")
        dims = [l.in_dim for l in self.layers] + [self.layers[-1].out_dim]
        caps = cfg.capacities(n_dev)
        p_loc = cfg.n_parts // n_dev
        old_S = self.n_stages

        def _lost(n, what):
            if int(n):
                raise RuntimeError(
                    f"reshard would drop {int(n)} in-flight {what} rows — "
                    "flush() to quiescence first or raise route_defer_cap")

        # per-LAYER view of the carry (unstacks the hybrid rounds); defer
        # rings compact into the new global capacities
        layer_states = [self.layer_state(l) for l in range(L)]
        for i, ls in enumerate(layer_states):
            b, bok, lb = repack_defer_ring(ls.bc_defer, ls.bc_defer_ok,
                                           caps.bc_defer_rows)
            r, rok, lr = repack_defer_ring(ls.rmi_defer, ls.rmi_defer_ok,
                                           caps.rmi_defer_rows)
            _lost(lb, f"layer {i} broadcast-defer")
            _lost(lr, f"layer {i} RMI-defer")
            layer_states[i] = replace(ls, bc_defer=b, bc_defer_ok=bok,
                                      rmi_defer=r, rmi_defer_ok=rok)
        qw, qok, lq = repack_defer_ring(self.queries.wire_defer,
                                        self.queries.wire_defer_ok,
                                        caps.query_defer_rows)
        _lost(lq, "query-wire-defer")
        queries = replace(self.queries, wire_defer=qw, wire_defer_ok=qok)

        # inter-stage ring: a stage-count change cannot relabel in-flight
        # rows' (stage, round) coordinates, so it needs an empty ring; a
        # data-axis-only reshard re-blocks rows by part ownership
        cap_pp = caps.outbox_per_part
        ring_caps = (max(cfg.feat_cap, p_loc * cap_pp), dims[0] + 3)
        in_flight = self._ring_occupancy_host()
        if S != old_S and in_flight:
            raise RuntimeError(
                f"reshard {old_S}->{S} stages with {in_flight} rows in the "
                "inter-stage ring — flush() to quiescence first "
                "(data-axis-only reshards keep in-flight rows)")
        new_ring = None
        if S > 1:
            if old_S == 1:
                self._check_uniform_layers(dims)
            n_rounds = L // S
            new_ring = jnp.zeros((S, n_rounds, n_dev * ring_caps[0],
                                  ring_caps[1]), jnp.float32)
            if old_S == S and self.stage_ring is not None:
                proto = ev.empty_feat_batch(1, dims[0])
                pcol = field_col(proto, "part")
                vcol = field_col(proto, "valid")
                slabs = []
                for s_i in range(S):
                    per_round = []
                    for r_i in range(self._n_rounds):
                        slab, lost = repack_stage_slab(
                            self.stage_ring[s_i, r_i], pcol, vcol,
                            p_loc, n_dev, ring_caps[0])
                        _lost(lost, f"stage-ring ({s_i},{r_i})")
                        per_round.append(slab)
                    slabs.append(jnp.stack(per_round))
                new_ring = jnp.stack(slabs)
            states = [jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[layer_states[r * S + s]
                                     for s in range(S)])
                      for r in range(n_rounds)]
            rounds = (StagedActLayer(
                base=replace(self.layers[0], act=False)),) * n_rounds
        else:
            n_rounds = L
            states = layer_states
            rounds = None

        # install the new grid: router, bookkeeping, device placement
        self.mesh = new_mesh
        self.cfg = cfg
        self.n_stages = S
        self._n_data = n_dev
        self._n_rounds = n_rounds
        self.rounds = rounds
        self.router = (MeshRouter(cfg.n_parts, n_dev,
                                  route_cap=cfg.route_cap,
                                  pack_backend=cfg.delivery_backend,
                                  stage_axis="stage" if S > 1 else None,
                                  n_stages=S, telemetry=cfg.telemetry)
                       if new_mesh is not None else LocalRouter(cfg.n_parts))
        self._ring_caps = ring_caps
        self._wire_bytes_per_tick = self._static_wire_bytes(dims, n_dev, S)
        if new_mesh is not None and S > 1:
            sh = stage_carry_shardings(new_mesh, n_rounds)
            self.topo = jax.device_put(self.topo, sh.topo)
            self.states = [jax.device_put(s, sh.layers[i])
                           for i, s in enumerate(states)]
            self.sink = jax.device_put(self.sink, sh.sink)
            self.sink_seen = jax.device_put(self.sink_seen, sh.sink_seen)
            self.queries = jax.device_put(queries, sh.queries)
            self.stage_ring = jax.device_put(new_ring, sh.stage_ring)
        elif new_mesh is not None:
            sh = carry_shardings(new_mesh, L)
            self.topo = jax.device_put(self.topo, sh.topo)
            self.states = [jax.device_put(s, sh.layers[i])
                           for i, s in enumerate(states)]
            self.sink = jax.device_put(self.sink, sh.sink)
            self.sink_seen = jax.device_put(self.sink_seen, sh.sink_seen)
            self.queries = jax.device_put(queries, sh.queries)
            self.stage_ring = None
        else:
            dev = jax.devices()[0]
            self.topo = jax.device_put(self.topo, dev)
            self.states = [jax.device_put(s, dev) for s in states]
            self.sink = jax.device_put(self.sink, dev)
            self.sink_seen = jax.device_put(self.sink_seen, dev)
            self.queries = jax.device_put(queries, dev)
            self.stage_ring = None
        if self.train_state is not None:
            self.train_state = (
                jax.device_put(self.train_state,
                               train_shardings(new_mesh, self.train_state))
                if new_mesh is not None
                else jax.device_put(self.train_state, jax.devices()[0]))
        if cfg.telemetry:
            if self.trace is not None:
                self.trace.meta["n_devices"] = n_dev
                self.trace.meta["n_stages"] = S
                self.trace.meta.setdefault("reshards", []).append(
                    {"tick": int(self.now), "n_devices": n_dev,
                     "n_stages": S})
            self.straggler = StragglerMitigator(n_shards=max(n_dev, 1))
        return cfg

    def mitigate_stragglers(self):
        """Consume the StragglerMitigator's persistent-straggler flags
        (fed live by the telemetry plane) end-to-end: a shard that stays
        flagged past `patience` is treated as fail-slow == fail-stop and
        the pipeline LIVE-reshards onto fewer data shards, re-mapping
        `parts_per_shard()` so the slow shard owns nothing. Returns the
        RescalePlan when a reshard happened, else None.

        Block sharding keeps parts contiguous, so the survivor count is
        the largest divisor of n_parts below the current D that also
        keeps the stage grid intact — work-steal overrides
        (`plan_work_steal`) stay the planner's advisory view; the reshard
        is the executable re-map."""
        from repro.ft.elastic import rescale_parts
        if self.straggler is None or self.mesh is None or self._n_data <= 1:
            return None
        slow = self.straggler.persistent_stragglers()
        if not slow:
            return None
        old_d = self._n_data
        new_d = old_d - len(set(slow))
        while new_d > 1 and self.cfg.n_parts % new_d:
            new_d -= 1
        new_d = max(new_d, 1)
        from repro.launch.mesh import survivor_mesh
        new_mesh = survivor_mesh(self.mesh, slow, n_data=new_d)
        plan = rescale_parts(old_d, new_d, self.cfg.n_parts)
        self.reshard(new_mesh)
        return plan

    # ------------------------------------------------------------ host side
    def _resolve_queries(self, queries, issue_tick: int) -> dict:
        """Resolve host query requests [(qid, kind, vid, [vid2], consistent)]
        to master-(part, slot)-addressed rows. Requests naming a vertex the
        partitioner has never seen are answered HERE (ok=False, zero
        payload, answer tick = issue tick) instead of burning device slots.
        """
        rows = {k: [] for k in ("qid", "kind", "part", "slot", "part2",
                                "slot2", "consistent", "issue")}
        rejects = []

        def locate(vid):
            if not 0 <= vid < self.cfg.max_nodes:
                return None
            return self.part.locate_master(vid, create=False)

        for q in queries:
            qid, kind, vid = int(q[0]), int(q[1]), int(q[2])
            vid2 = int(q[3]) if kind == KIND_LINK else 0
            # qids ride the packed f32 wire (dist/wire.py): values at or
            # beyond 2**24 would round and answer under the WRONG qid —
            # reject here, where the answer still carries the exact qid
            if not 0 <= qid < 2 ** 24:
                rejects.append((qid, kind))
                continue
            m = locate(vid)
            m2 = locate(vid2) if kind == KIND_LINK else (0, 0)
            if m is None or m2 is None:
                rejects.append((qid, kind))
                continue
            rows["qid"].append(qid)
            rows["kind"].append(kind)
            rows["part"].append(m[0])
            rows["slot"].append(m[1])
            rows["part2"].append(m2[0])
            rows["slot2"].append(m2[1])
            rows["consistent"].append(bool(q[-1]))
            rows["issue"].append(issue_tick)
        if rejects:
            r = np.asarray(rejects, np.int64).reshape(-1, 2)
            self._answer_log.append({
                "qid": r[:, 0], "kind": r[:, 1],
                "ok": np.zeros(len(r), bool),
                "tick": np.full(len(r), issue_tick, np.int64),
                "issue": np.full(len(r), issue_tick, np.int64),
                "vec": np.zeros((len(r), self.d_out), np.float32),
                "score": np.zeros(len(r), np.float32)})
        return {k: np.asarray(v) for k, v in rows.items()}

    def _build_batches(self, edges: Optional[np.ndarray],
                       feats: Optional[list], device: bool = True,
                       queries: Optional[list] = None,
                       issue_tick: Optional[int] = None,
                       labels: Optional[list] = None):
        """One tick's padded batches. device=False keeps numpy leaves for
        the super-tick staging path (stack first, upload once).
        labels: [(vid, gold_class), ...] training-plane admissions —
        resolved to master coordinates; vids the partitioner has never
        seen are silently skipped (no master slot to label)."""
        cfg = self.cfg
        if edges is not None and len(edges):
            e_rows, r1, v1 = self.part.ingest_edges(edges)
        else:
            e_rows, r1, v1 = None, None, None
        # feature events may create vertices (cold features)
        f_parts, f_slots, f_vecs = [], [], []
        if feats:
            coalesced = {}
            for vid, vec in feats:        # host-side coalescing (last wins)
                coalesced[int(vid)] = vec
            for vid, vec in coalesced.items():
                p, s = self.part.locate_master(vid)
                f_parts.append(p)
                f_slots.append(s)
                f_vecs.append(vec)
        r2, v2 = self.part.drain_allocations()
        if r1 is not None:
            r_rows = {k: np.concatenate([r1[k], r2[k]]) for k in r2}
            v_rows = {k: np.concatenate([v1[k], v2[k]]) for k in v2}
        else:
            r_rows, v_rows = r2, v2

        eb = (ev.edge_batch_from_numpy(e_rows, cfg.edge_tick_cap, device)
              if e_rows is not None
              else (self._empty_edges if device else self._empty_edges_np))
        rb = ev.repl_batch_from_numpy(r_rows, max(2 * cfg.edge_tick_cap, 1),
                                      device)
        vb = ev.vertex_batch_from_numpy(v_rows, max(2 * cfg.edge_tick_cap +
                                                    cfg.feat_cap, 1), device)
        fb = ev.feat_batch_from_numpy(
            np.asarray(f_parts), np.asarray(f_slots),
            np.asarray(f_vecs, np.float32).reshape(len(f_parts), -1)
            if f_parts else np.zeros((0, 1)),
            cfg.feat_cap, self.states[0].feat.shape[-1], device)
        if queries:
            assert cfg.query_cap > 0, \
                "queries submitted but PipelineConfig.query_cap=0"
            q_rows = self._resolve_queries(
                queries, self.now if issue_tick is None else issue_tick)
            qb = query_batch_from_numpy(q_rows, cfg._query_admissions(),
                                        self.d_out, device)
        else:
            qb = (self._empty_queries if device else self._empty_queries_np)
        if labels:
            assert cfg.train_cap > 0, \
                "labels submitted but PipelineConfig.train_cap=0"
            l_parts, l_slots, l_gold = [], [], []
            for vid, y in labels:
                m = self.part.locate_master(int(vid), create=False)
                if m is None:
                    continue
                l_parts.append(m[0])
                l_slots.append(m[1])
                l_gold.append(int(y))
            lb = ev.label_batch_from_numpy(
                np.asarray(l_parts, np.int64), np.asarray(l_slots, np.int64),
                np.asarray(l_gold, np.int64), cfg.train_cap, device)
        else:
            lb = (self._empty_labels if device else self._empty_labels_np)
        return eb, rb, vb, fb, qb, lb

    # ---------------------------------------------------------- device side
    def tick(self, edges: Optional[np.ndarray] = None,
             feats: Optional[list] = None, window=None,
             queries: Optional[list] = None,
             labels: Optional[list] = None):
        """One micro-tick through the full pipeline.

        queries: optional [(qid, kind, vid, [vid2,] consistent), ...]
        point-query admissions for this tick (needs cfg.query_cap > 0);
        answered rows accumulate in `drain_answers()`.
        labels: optional [(vid, gold_class), ...] training-plane label
        admissions for this tick (needs cfg.train_cap > 0 and a
        TrainConfig); training progress is read via `train_stats()`.
        """
        cfg = self.cfg
        wconf = window or cfg.window
        t0 = time.perf_counter()
        tick0 = self.now
        outbox_cap = cfg.capacities().outbox
        eb, rb, vb, fb, qb, lb = self._build_batches(edges, feats,
                                                     queries=queries,
                                                     labels=labels)
        host_s = time.perf_counter() - t0   # host-side staging round timer
        counts = (len(edges) if edges is not None else 0,
                  len(feats) if feats else 0,
                  len(queries) if queries else 0,
                  len(labels) if labels else 0)
        now = jnp.asarray(self.now, jnp.int32)
        if self.n_stages > 1:
            (self.topo, new_states, self.sink, self.sink_seen,
             self.queries, self.stage_ring, stats_all, idle, answers,
             qstats, new_ts, occ) = _tick_jit_2d(
                self.rounds, self._staged_params(), self.topo,
                tuple(self.states), self.sink, self.sink_seen,
                self.queries, self.stage_ring, fb, eb, rb, vb, qb, lb,
                self.train_state, now, wconf, outbox_cap, self.router,
                self.delivery, self.mesh, cfg.delta_eps, self.train_cfg,
                self._head, self._acts, cfg.telemetry)
            self.states = list(new_states)
            self.train_state = new_ts
            self._sync_params_from_train()
            self.now += 1
            self._harvest_answers(answers)
            per_layer = self._unstack_stats(jax.device_get(stats_all))
            self.metrics.stage_idle += int(np.sum(jax.device_get(idle)))
            dt = time.perf_counter() - t0
            occ_np = (np.asarray(jax.device_get(occ))
                      if self.trace is not None else None)
            self._accumulate(per_layer, dt, qstats=qstats,
                             occ_rows=occ_np)
            self._trace_ticks(occ_np, tick0, dt, host_s, counts,
                              per_layer)
            return per_layer
        (self.topo, new_states, self.sink, self.sink_seen, self.queries,
         stats_all, answers, qstats, new_ts, occ) = _tick_jit(
            tuple(self.layers), self.params, self.topo, tuple(self.states),
            self.sink, self.sink_seen, self.queries, fb, eb, rb, vb, qb,
            lb, self.train_state, now, wconf, outbox_cap, self.router,
            self.delivery, self.mesh, cfg.delta_eps, self.train_cfg,
            self._head, cfg.telemetry)
        self.states = list(new_states)
        self.train_state = new_ts
        self._sync_params_from_train()
        self.now += 1
        self._harvest_answers(answers)
        dt = time.perf_counter() - t0
        occ_np = (np.asarray(jax.device_get(occ))
                  if self.trace is not None else None)
        self._accumulate(stats_all, dt, qstats=qstats, occ_rows=occ_np)
        self._trace_ticks(occ_np, tick0, dt, host_s, counts, stats_all)
        return list(stats_all)

    def _sync_params_from_train(self) -> None:
        """Mirror the live trained parameters back into `self.params` so
        host-side consumers (checkpointing, `_staged_params`, the legacy
        coordinator) always see the online plane's latest step."""
        ts = self.train_state
        if ts is None:
            return
        for k, v in ts.params.items():
            self.params[k] = v
        self.params["head"] = ts.head_params

    def train_stats(self) -> dict:
        """Training-plane progress in ONE host sync: the last fired
        step's global loss, gradient norm and the fired-step count."""
        ts = self.train_state
        assert ts is not None, \
            "training plane disabled (train_cap=0 / no TrainConfig)"
        loss, gn, steps = jax.device_get((ts.loss, ts.grad_norm, ts.steps))
        return {"loss": float(loss), "grad_norm": float(gn),
                "steps": int(steps)}

    def _harvest_answers(self, answers) -> None:
        """Pull this launch's answered rows (valid mask) into the host-side
        answer log. `answers` leaves are [A, ...] (per-tick driver) or
        [T, A, ...] (super-tick ys); zero-capacity leaves mean the query
        plane is off."""
        if answers.valid.size == 0:
            return
        a = jax.device_get(answers)
        mask = np.asarray(a.valid).reshape(-1)
        if not mask.any():
            return
        flat = lambda x: np.asarray(x).reshape(-1)[mask]
        self._answer_log.append({
            "qid": flat(a.qid), "kind": flat(a.kind), "ok": flat(a.ok),
            "tick": flat(a.tick), "issue": flat(a.issue),
            "vec": np.asarray(a.vec).reshape(-1, a.vec.shape[-1])[mask],
            "score": flat(a.score)})

    def drain_answers(self) -> dict:
        """Pop every answered query collected so far as one dict of
        concatenated numpy columns (qid, kind, ok, tick, issue, vec,
        score) — empty arrays when nothing answered."""
        log, self._answer_log = self._answer_log, []
        if not log:
            return {"qid": np.zeros(0, np.int64),
                    "kind": np.zeros(0, np.int64),
                    "ok": np.zeros(0, bool),
                    "tick": np.zeros(0, np.int64),
                    "issue": np.zeros(0, np.int64),
                    "vec": np.zeros((0, self.d_out), np.float32),
                    "score": np.zeros(0, np.float32)}
        return {k: np.concatenate([chunk[k] for chunk in log])
                for k in log[0]}

    def _accumulate(self, stats_all, dt, ticks: int = 1, qstats=None,
                    occ_rows=None):
        """Fold per-layer stats into StreamMetrics — one tick's stats from
        the per-tick driver, or `ticks` micro-ticks' summed stats from a
        super-tick (the counters are additive either way).

        occ_rows (telemetry plane): [ticks, len(TRACE_DEVICE_COLS)] int
        per-tick occupancy rows off the device — backlog integrals add,
        the peak gauges fold with max (their scan SUM is meaningless)."""
        m = self.metrics
        m.ticks += ticks
        m.wall_seconds += dt
        m.wire_bytes += ticks * self._wire_bytes_per_tick
        for s in stats_all:
            m.reduce_msgs += int(s.reduce_msgs)
            m.broadcast_msgs += int(s.broadcast_msgs)
            m.cross_part_msgs += int(s.cross_part_msgs)
            m.dropped += int(s.dropped)
            m.wire_rows += int(s.wire_rows)
            m.route_deferred += int(s.route_deferred)
            m.route_dropped += int(s.route_dropped)
            m.suppressed += int(s.n_suppressed)
            m.occ_defer_ticks += int(s.occ_bc_defer) + int(s.occ_rmi_defer)
            m.busy_logical += np.asarray(s.busy, np.int64)
        m.emitted_total += int(stats_all[-1].emitted)
        if occ_rows is not None:
            occ = np.asarray(occ_rows).reshape(-1, len(TRACE_DEVICE_COLS))
            ci = {c: i for i, c in enumerate(TRACE_DEVICE_COLS)}
            if occ.size:
                m.route_peak = max(m.route_peak,
                                   int(occ[:, ci["route_peak"]].max()))
                m.outbox_peak = max(m.outbox_peak,
                                    int(occ[:, ci["outbox_demand"]].max()))
                m.outbox_part_peak = max(
                    m.outbox_part_peak,
                    int(occ[:, ci["outbox_part_peak"]].max()))
        if qstats is not None:
            m.queries_admitted += int(qstats.admitted)
            m.queries_answered += int(qstats.answered)
            m.queries_dropped += int(qstats.dropped)
            m.query_hold_ticks += int(qstats.held_ticks)

    def _trace_ticks(self, occ_rows, tick0, wall_s, host_s, counts,
                     stats_all, ticks: int = 1, amortized: int = 0):
        """Telemetry-plane host side: append per-tick trace rows and feed
        the straggler mitigator. No-op when telemetry is off.

        occ_rows: [ticks, C] device occupancy rows; counts: per-tick
        (edges, feats, queries, labels) ingest tuples — a single tuple on
        the per-tick driver, a list of `ticks` tuples on the scan driver
        (whose wall time is attributed uniformly, amortized=1)."""
        if self.trace is None:
            return
        occ = np.asarray(occ_rows).reshape(-1, len(TRACE_DEVICE_COLS))
        rows = [counts] if ticks == 1 else list(counts)
        per = wall_s / max(ticks, 1)
        for i in range(ticks):
            e, f, q, l = rows[i]
            self.trace.append(
                {"tick": tick0 + i, "ticks": 1, "wall_s": per,
                 "host_s": host_s if ticks == 1 else 0.0,
                 "amortized": amortized,
                 "wire_bytes": self._wire_bytes_per_tick,
                 "edges_in": e, "feats_in": f, "queries_in": q,
                 "labels_in": l},
                occ[i])
        # straggler feed: per-part busy proxies folded to their shard
        busy = np.zeros(self.cfg.n_parts, np.int64)
        for s in stats_all:
            busy += np.asarray(jax.device_get(s.busy), np.int64)
        shards = busy.reshape(max(self._n_data, 1), -1).sum(axis=1)
        self.straggler.observe_tick(per, shards)
        self.metrics.host_seconds += host_s

    def chunk_stream(self, edges, feats, tick_edges: int,
                     feat_with_first_edge: bool = True, seen=None):
        """Cut an edge stream into micro-tick chunks + aligned feature
        events (each vertex's feature fires in the tick of its first edge).
        Shared by both drivers so their tick boundaries always agree —
        serving loops that chunk a stream in several calls pass a
        persistent `seen` set so features still fire exactly once."""
        seen = set() if seen is None else seen
        e_chunks, f_chunks = [], []
        for lo in range(0, len(edges), tick_edges):
            chunk = edges[lo: lo + tick_edges]
            f_events = []
            if feat_with_first_edge:
                for u in chunk.reshape(-1):
                    u = int(u)
                    if u not in seen and u in feats:
                        seen.add(u)
                        f_events.append((u, feats[u]))
            e_chunks.append(chunk)
            f_chunks.append(f_events)
        return e_chunks, f_chunks

    # ------------------------------------------------------ super-tick path
    def _stage_super_batches(self, edge_chunks, feat_chunks, query_chunks,
                             label_chunks):
        """Host staging: build T per-tick padded batches, stack along T.

        Returns (fb, eb, rb, vb, qb, lb) pytrees with a leading [T] axis —
        the xs of the super-tick scan. Host partitioner state advances tick
        by tick exactly as the per-tick driver would have advanced it;
        query issue ticks are stamped with the tick the scan will admit
        them in.
        """
        ebs, rbs, vbs, fbs, qbs, lbs = [], [], [], [], [], []
        for i, (edges_t, feats_t, queries_t, labels_t) in enumerate(
                zip(edge_chunks, feat_chunks, query_chunks, label_chunks)):
            eb, rb, vb, fb, qb, lb = self._build_batches(
                edges_t, feats_t, device=False, queries=queries_t,
                issue_tick=self.now + i, labels=labels_t)
            ebs.append(eb)
            rbs.append(rb)
            vbs.append(vb)
            fbs.append(fb)
            qbs.append(qb)
            lbs.append(lb)
        return (ev.stack_batches(fbs), ev.stack_batches(ebs),
                ev.stack_batches(rbs), ev.stack_batches(vbs),
                ev.stack_batches(qbs), ev.stack_batches(lbs))

    def run_super_tick(self, edge_chunks=None, feat_chunks=None,
                       T: Optional[int] = None, window=None,
                       quiet0: int = 0, query_chunks=None,
                       label_chunks=None):
        """Advance T micro-ticks in ONE device program (`lax.scan`).

        edge_chunks: list of per-tick edge arrays (or None entries);
        feat_chunks: list of per-tick [(vid, vec), ...] lists (or None);
        query_chunks: list of per-tick query-request lists (or None) —
        the tick() `queries` format, admitted at their staged tick;
        label_chunks: list of per-tick [(vid, gold_class), ...] lists (or
        None) — the tick() `labels` format, admitted at their staged tick.
        Shorter lists are padded with empty ticks up to T.
        quiet0 seeds the consecutive-quiet-tick counter (flush chaining).

        Returns (per-layer summed TickStats tuple, quiet_ticks) — the ONLY
        host sync of the super-tick (one device_get that also carries the
        T ticks' stacked answers and the summed QueryStats; training-plane
        progress stays device-resident until `train_stats()` is read).
        """
        cfg = self.cfg
        t0 = time.perf_counter()
        outbox_cap = cfg.capacities().outbox
        edge_chunks = list(edge_chunks) if edge_chunks is not None else []
        feat_chunks = list(feat_chunks) if feat_chunks is not None else []
        query_chunks = list(query_chunks) if query_chunks is not None else []
        label_chunks = list(label_chunks) if label_chunks is not None else []
        n = max(len(edge_chunks), len(feat_chunks), len(query_chunks),
                len(label_chunks), 1)
        T = int(T) if T is not None else n
        assert T >= n, f"T={T} smaller than the {n} staged ticks"
        edge_chunks += [None] * (T - len(edge_chunks))
        feat_chunks += [None] * (T - len(feat_chunks))
        query_chunks += [None] * (T - len(query_chunks))
        label_chunks += [None] * (T - len(label_chunks))
        batches = self._stage_super_batches(edge_chunks, feat_chunks,
                                            query_chunks, label_chunks)
        host_s = time.perf_counter() - t0
        tick0 = self.now
        counts = [(len(e) if e is not None else 0,
                   len(f) if f else 0, len(q) if q else 0,
                   len(l) if l else 0)
                  for e, f, q, l in zip(edge_chunks, feat_chunks,
                                        query_chunks, label_chunks)]

        if self.n_stages > 1:
            carry = st.PipelineCarry(
                topo=self.topo, layers=tuple(self.states), sink=self.sink,
                sink_seen=self.sink_seen, queries=self.queries,
                now=jnp.asarray(self.now, jnp.int32),
                quiet=jnp.asarray(quiet0, jnp.int32),
                stage_ring=self.stage_ring, train=self.train_state)
            (final, stats_sum, idle_sum, qstats_sum, answers,
             occ_t) = _super_tick_scan_2d(
                self.rounds, self._staged_params(), carry, batches,
                window or cfg.window, outbox_cap, self.router,
                self.delivery, self.mesh, cfg.delta_eps, self.train_cfg,
                self._head, self._acts, cfg.telemetry)
            self.topo = final.topo
            self.states = list(final.layers)
            self.sink = final.sink
            self.sink_seen = final.sink_seen
            self.queries = final.queries
            self.stage_ring = final.stage_ring
            self.train_state = final.train
            self._sync_params_from_train()
            self.now += T
            (host_stats, quiet, host_idle, host_qstats, host_answers,
             host_occ) = jax.device_get(
                (stats_sum, final.quiet, idle_sum, qstats_sum, answers,
                 occ_t))
            self._harvest_answers(host_answers)
            per_layer = self._unstack_stats(host_stats)
            self.metrics.stage_idle += int(np.sum(host_idle))
            dt = time.perf_counter() - t0
            occ_np = (np.asarray(host_occ)
                      if self.trace is not None else None)
            self._accumulate(per_layer, dt, ticks=T, qstats=host_qstats,
                             occ_rows=occ_np)
            self._trace_ticks(occ_np, tick0, dt, host_s, counts,
                              per_layer, ticks=T, amortized=1)
            return per_layer, int(quiet)

        carry = st.PipelineCarry(
            topo=self.topo, layers=tuple(self.states), sink=self.sink,
            sink_seen=self.sink_seen, queries=self.queries,
            now=jnp.asarray(self.now, jnp.int32),
            quiet=jnp.asarray(quiet0, jnp.int32), train=self.train_state)
        final, stats_sum, qstats_sum, answers, occ_t = _super_tick_scan(
            tuple(self.layers), self.params, carry, batches,
            window or cfg.window, outbox_cap, self.router, self.delivery,
            self.mesh, cfg.delta_eps, self.train_cfg, self._head,
            cfg.telemetry)
        self.topo = final.topo
        self.states = list(final.layers)
        self.sink = final.sink
        self.sink_seen = final.sink_seen
        self.queries = final.queries
        self.train_state = final.train
        self._sync_params_from_train()
        self.now += T
        # the one host sync per super-tick: summed stats + quiet counter +
        # query stats + the T ticks' stacked answers + the telemetry
        # occupancy rows, in ONE device_get
        (host_stats, quiet, host_qstats, host_answers,
         host_occ) = jax.device_get(
            (stats_sum, final.quiet, qstats_sum, answers, occ_t))
        self._harvest_answers(host_answers)
        dt = time.perf_counter() - t0
        occ_np = np.asarray(host_occ) if self.trace is not None else None
        self._accumulate(host_stats, dt, ticks=T, qstats=host_qstats,
                         occ_rows=occ_np)
        self._trace_ticks(occ_np, tick0, dt, host_s, counts, host_stats,
                          ticks=T, amortized=1)
        return host_stats, int(quiet)

    def run_stream_super(self, edges: np.ndarray, feats: dict,
                         tick_edges: int = 256, super_ticks: int = 16,
                         feat_with_first_edge: bool = True):
        """`run_stream`, but T micro-ticks per device launch.

        Cuts the stream into `tick_edges`-sized micro-ticks, groups them
        into super-ticks of `super_ticks` ticks each (the tail group is
        padded with empty ticks so every launch reuses one compiled scan).
        """
        e_chunks, f_chunks = self.chunk_stream(edges, feats, tick_edges,
                                               feat_with_first_edge)
        for lo in range(0, len(e_chunks), super_ticks):
            self.run_super_tick(e_chunks[lo: lo + super_ticks],
                                f_chunks[lo: lo + super_ticks],
                                T=super_ticks)
        return self

    def flush_super(self, max_ticks: int = 64, T: int = 8,
                    drain: bool = True) -> int:
        """`flush`, super-tick style: empty ticks until device quiescence.

        The consecutive-quiet counter lives in the scan carry; the host
        reads it once per super-tick and re-seeds the next launch through
        the coordinator's public seed_quiet()."""
        term = TerminationCoordinator()
        override = win.WindowConfig(kind=win.STREAMING) if drain else None
        ran = 0
        while ran < max_ticks:
            step = min(T, max_ticks - ran)
            _, quiet = self.run_super_tick(T=step, window=override,
                                           quiet0=term.seed_quiet())
            ran += step
            if term.observe_flag(quiet):
                return ran
        raise RuntimeError("pipeline failed to terminate "
                           f"within {max_ticks} flush ticks")

    def run_stream(self, edges: np.ndarray, feats: dict,
                   tick_edges: int = 256, feat_with_first_edge: bool = True):
        """Stream an edge list (+ node features) through the pipeline.

        feats: {vid: np.ndarray} — each vertex's feature event is injected
        in the tick its first edge appears (feature stream aligned with the
        topology stream, as in the paper's temporal edge-list datasets).
        """
        e_chunks, f_chunks = self.chunk_stream(edges, feats, tick_edges,
                                               feat_with_first_edge)
        for chunk, f_events in zip(e_chunks, f_chunks):
            self.tick(chunk, f_events)
        return self

    def flush(self, max_ticks: int = 64, drain: bool = True) -> int:
        """Run empty ticks until the TerminationCoordinator fires.

        drain=True forces pending windows due immediately (streaming
        eviction) — the training coordinator's flush semantics (§4.3.1).
        drain=False waits for the scheduled timers (pure §5.3 behaviour)."""
        term = TerminationCoordinator()
        override = win.WindowConfig(kind=win.STREAMING) if drain else None
        for i in range(max_ticks):
            stats = self.tick(window=override)
            # in-flight inter-stage rows are pending work the host cannot
            # see in the layer states (0 on a 1-D mesh)
            if term.observe(self.states, stats, queries=self.queries,
                            extra_work=self._ring_occupancy_host()):
                return i + 1
        raise RuntimeError("pipeline failed to terminate "
                           f"within {max_ticks} flush ticks")

    # ------------------------------------------------------------- queries
    def read_nodes(self, vids) -> dict:
        """Device-side partial gather of sink embeddings for a vid set.

        Only the requested rows are gathered (on device, from the live —
        possibly sharded — sink) and transferred; vids the partitioner has
        never seen, or whose master never materialized an embedding, are
        absent from the result. This is the host-side oracle of the query
        plane's stale_ok reads: a stale_ok answer at tick t bit-matches
        `read_nodes` called right after tick t.
        """
        vids = np.asarray(list(vids) if not isinstance(vids, np.ndarray)
                          else vids, np.int64).reshape(-1)
        t = self.part.t
        vids = vids[(vids >= 0) & (vids < t.max_nodes)]
        vids = vids[t.master[vids] >= 0]
        if vids.size == 0:
            return {}
        p = jnp.asarray(t.master[vids])
        s = jnp.asarray(t.master_slot[vids])
        vecs, seen = jax.device_get((self.sink[p, s], self.sink_seen[p, s]))
        return {int(v): vecs[i] for i, v in enumerate(vids) if seen[i]}

    def embeddings(self) -> dict:
        """Materialized final-layer embeddings {vid: vector} (masters) —
        a thin wrapper over `read_nodes` for every vid with a master."""
        return self.read_nodes(np.flatnonzero(self.part.t.master >= 0))

    def physical_busy_per_layer(self):
        """Per-layer physical busy vectors under the explosion factor."""
        cfg = self.cfg
        pars = layer_parallelisms(cfg.base_parallelism, cfg.explosion,
                                  len(self.layers), cfg.n_parts)
        return [physical_busy(self.metrics.busy_logical, p, cfg.n_parts)
                for p in pars]


def _occ_row(stats_all, qstats, ts, router, stage: bool = False):
    """The telemetry plane's per-tick device occupancy row — int32
    [len(TRACE_DEVICE_COLS)] in exactly `telemetry/trace.py`'s column
    order. All entries are EXACT integers, already reduced over the data
    axis by the tick body; `stage=True` (the 2-D program) additionally
    folds the per-stage partial stats over the stage axis — additive
    counters with psum_stage, the peak gauges with pmax_stage, and the
    final layer's emissions masked to stage S-1 (layer L-1 lives there).
    Query/train entries are stage-replicated already and skip the stage
    reduction."""
    if stage:
        add, mx = router.psum_stage, router.pmax_stage
        last_w = (router.stage_index()
                  == jnp.int32(router.n_stages - 1)).astype(jnp.int32)
    else:
        add = mx = lambda x: x
        last_w = jnp.int32(1)
    fsum = lambda f: add(sum(getattr(s, f) for s in stats_all))

    def fmax(vals):
        m = vals[0]
        for v in vals[1:]:
            m = jnp.maximum(m, v)
        return mx(m)

    z = jnp.zeros((), jnp.int32)
    if ts is not None:
        labeled = router.psum(jnp.sum(ts.label_mask.astype(jnp.int32)))
        dirty = router.psum(jnp.sum(
            (ts.dirty & ts.label_mask).astype(jnp.int32)))
    else:
        labeled, dirty = z, z
    row = (
        add(stats_all[-1].emitted * last_w),            # emitted_final
        fsum("emitted"),                                # emitted_sum
        fsum("reduce_msgs"),
        fsum("broadcast_msgs"),
        fsum("wire_rows"),
        fsum("route_deferred"),
        fsum("route_dropped"),
        fsum("dropped"),
        fsum("n_suppressed"),                           # suppressed
        fsum("occ_bc_defer"),
        fsum("occ_rmi_defer"),
        fmax([s.route_peak for s in stats_all]),        # route_peak
        fmax([s.emitted + s.dropped
              for s in stats_all]),                     # outbox_demand
        fmax([s.outbox_part_peak
              for s in stats_all]),                     # outbox_part_peak
        qstats.held_ticks,                              # query_pending
        qstats.wire_backlog,                            # query_backlog
        labeled,                                        # train_labeled
        dirty,                                          # train_dirty
        qstats.admitted,                                # q_admitted
        qstats.answered,                                # q_answered
        qstats.dropped,                                 # q_dropped
    )
    assert len(row) == len(TRACE_DEVICE_COLS)
    return jnp.stack([jnp.asarray(v, jnp.int32) for v in row])


def _zero_occ_row():
    return jnp.zeros((len(TRACE_DEVICE_COLS),), jnp.int32)


def _sink_update_body(sink, seen, fb: ev.FeatBatch, part0=0):
    P_loc, N, d = sink.shape
    idx, _ = st.local_index(fb.part, fb.slot, part0, P_loc, N, fb.valid)
    sink = sink.reshape(P_loc * N, d).at[idx].set(fb.feat, mode="drop")
    seen = seen.reshape(P_loc * N).at[idx].set(True, mode="drop")
    return sink.reshape(P_loc, N, d), seen.reshape(P_loc, N)


def _tick_program(layers, params, topo, states, sink, sink_seen, queries,
                  inbox, eb, rb, vb, qb, lb, now, wconf, outbox_cap,
                  router, delivery, delta_eps=0.0, ts=None, tcfg=None,
                  head=None, telemetry=False):
    """ONE full micro-tick over the local part block: topology application,
    the query plane's admit/head-hop stage (start-of-tick), L staged layer
    ticks — with the query wire lane FUSED into layer 0's round-B exchange
    (one all_to_all carries both, ISSUE 5) — the sink update, the query
    plane's answer stage, and the TRAINING plane's windowed online step
    (end-of-tick, ISSUE 8; `tcfg is None` — the train_cap=0 default —
    compiles the whole plane away and the program is bit-for-bit the
    four-plane tick). Runs directly under the LocalRouter and as the
    shard_map body under the MeshRouter — the two drivers, the two
    routers and the two delivery backends all share this program."""
    part0 = router.part0()
    topo = st.apply_vertex_batch(topo, vb, part0)
    topo = st.apply_repl_batch(topo, rb, part0)
    topo = st.apply_edge_batch(topo, eb, part0)
    # does this tick ingest anything that could move state? (replicated
    # batches — every device votes identically); consistent link heads
    # only fire when the whole tick is provably still (serve/query.py)
    batch_work = (jnp.any(inbox.valid) | jnp.any(eb.valid)
                  | jnp.any(rb.valid))
    queries, wire, adm_drop, n_adm = query_admit_stage(
        queries, qb, states, sink, sink_seen, router, batch_work)
    wire_d = None
    new_states, stats_all = [], []
    for li, layer in enumerate(layers):
        # topology reaches every layer; features only layer 0 (Splitter);
        # the query wire rides layer 0's round-B collective. With the
        # training plane on, the forward reads the LIVE trained params.
        lp = ts.params[f"l{li}"] if tcfg is not None else params[f"l{li}"]
        extra = ((wire, (queries.wire_defer, queries.wire_defer_ok))
                 if li == 0 and wire is not None else None)
        ls, outbox, stats, extra_out = layer_tick_body(
            layer, lp, topo, states[li], inbox, eb, rb,
            now, wconf, outbox_cap, router, delivery, extra_lane=extra,
            delta_eps=delta_eps, telemetry=telemetry)
        if extra is not None:
            wire_d, (wdb, wdo) = extra_out
            queries = replace(queries, wire_defer=wdb, wire_defer_ok=wdo)
        new_states.append(ls)
        stats_all.append(stats)
        inbox = outbox
    # sink: final-layer emissions materialize the embedding table
    sink, sink_seen = _sink_update_body(sink, sink_seen, inbox, part0)
    # query plane: answer point queries from the fresh sink
    queries, ans, qstats = query_answer_stage(
        queries, wire_d, qb, adm_drop, n_adm, tuple(new_states), sink,
        sink_seen, now, stats_all, router)
    # training plane: one windowed online step through the live state
    new_ts = ts
    if tcfg is not None:
        # 1-D stats scalars are already globally psum'd by the tick body
        moved = sum(moved_msgs(s) for s in stats_all)
        layers_bw = tuple((layers[li], ts.params[f"l{li}"], False)
                          for li in range(len(layers)))
        layer_feats = tuple(
            (new_states[li].feat, new_states[li].agg, new_states[li].agg_cnt)
            for li in range(len(layers)))
        new_ts = train_stage(tcfg, head, layers_bw, layer_feats, topo,
                             sink, sink_seen, ts, lb, inbox, now, moved,
                             router, part0)
    # telemetry plane: the per-tick occupancy row (trace.py column order)
    occ = (_occ_row(stats_all, qstats, new_ts, router) if telemetry
           else _zero_occ_row())
    return (topo, tuple(new_states), sink, sink_seen, queries,
            tuple(stats_all), ans, qstats, new_ts, occ)


@partial(jax.jit, static_argnames=("layers", "wconf", "outbox_cap",
                                   "router", "delivery", "mesh",
                                   "delta_eps", "tcfg", "head",
                                   "telemetry"))
def _tick_jit(layers, params, topo, states, sink, sink_seen, queries,
              inbox, eb, rb, vb, qb, lb, ts, now, wconf, outbox_cap,
              router, delivery, mesh, delta_eps=0.0, tcfg=None, head=None,
              telemetry=False):
    """The per-tick driver's device program (reference path)."""
    def prog(params, topo, states, sink, sink_seen, queries, inbox, eb,
             rb, vb, qb, lb, ts, now):
        return _tick_program(
            layers, params, topo, states, sink, sink_seen, queries, inbox,
            eb, rb, vb, qb, lb, now, wconf, outbox_cap, router, delivery,
            delta_eps, ts, tcfg, head, telemetry)

    if mesh is None:
        return prog(params, topo, states, sink, sink_seen, queries, inbox,
                    eb, rb, vb, qb, lb, ts, now)
    cp = carry_pspecs(len(layers))
    tspec = train_pspecs(ts) if tcfg is not None else P()
    sharded = shard_map(
        prog, mesh=mesh,
        in_specs=(P(), cp.topo, cp.layers, cp.sink, cp.sink_seen,
                  cp.queries, P(), P(), P(), P(), P(), P(), tspec, P()),
        out_specs=(cp.topo, cp.layers, cp.sink, cp.sink_seen, cp.queries,
                   stats_pspecs(len(layers)), P("data"), P(), tspec, P()),
        check_rep=False)
    return sharded(params, topo, states, sink, sink_seen, queries, inbox,
                   eb, rb, vb, qb, lb, ts, now)


@partial(jax.jit, static_argnames=("layers", "wconf", "outbox_cap",
                                   "router", "delivery", "mesh",
                                   "delta_eps", "tcfg", "head",
                                   "telemetry"),
         donate_argnums=(2,))
def _super_tick_scan(layers, params, carry: st.PipelineCarry, batches,
                     wconf: win.WindowConfig, outbox_cap: int, router,
                     delivery=None, mesh=None, delta_eps=0.0, tcfg=None,
                     head=None, telemetry=False):
    """T micro-ticks x L layers as one `lax.scan` — the super-tick body.

    carry (donated): PipelineCarry — topology, per-layer states, sink,
    the pending-query table, the training-plane TrainState (None when
    the plane is off) and the tick clock / quiet counter, all
    device-resident (and part-sharded when a mesh is given: the scan runs
    INSIDE the shard_map, so the carry never leaves its owning shard
    between ticks).
    batches: (fb, eb, rb, vb, qb, lb) pytrees with leading [T] axis (xs).
    Returns (final carry, per-layer TickStats summed over the T ticks,
    summed QueryStats, per-tick stacked AnswerBatch and the per-tick
    [T, len(TRACE_DEVICE_COLS)] occupancy rows — the scan's ys; the occ
    rows are static zeros unless `telemetry`).
    """
    def scan_prog(params, carry, batches):
        n_parts_loc = carry.topo.n_parts          # LOCAL block under mesh

        def body(state, batch_t):
            c, ssum, qsum = state
            fb, eb, rb, vb, qb, lb = batch_t
            (topo, new_layers, sink, sink_seen, queries, stats_t, ans,
             qstats_t, new_ts, occ) = _tick_program(
                layers, params, c.topo, c.layers, c.sink, c.sink_seen,
                c.queries, fb, eb, rb, vb, qb, lb, c.now, wconf,
                outbox_cap, router, delivery, delta_eps, c.train, tcfg,
                head, telemetry)
            quiet = quiet_update(c.quiet, new_layers, stats_t, router,
                                 queries=queries)
            new_c = st.PipelineCarry(
                topo=topo, layers=new_layers, sink=sink,
                sink_seen=sink_seen, queries=queries,
                now=c.now + jnp.int32(1), quiet=quiet, train=new_ts)
            ssum = tuple(add_stats(a, b) for a, b in zip(ssum, stats_t))
            return (new_c, ssum, add_query_stats(qsum, qstats_t)), \
                (ans, occ)

        zeros = tuple(zero_stats(n_parts_loc) for _ in layers)
        (final, stats_sum, qstats_sum), (answers, occ_t) = jax.lax.scan(
            body, (carry, zeros, zero_query_stats()), batches)
        return final, stats_sum, qstats_sum, answers, occ_t

    if mesh is None:
        return scan_prog(params, carry, batches)
    cp = carry_pspecs(len(layers),
                      train=(train_pspecs(carry.train)
                             if tcfg is not None else None))
    sharded = shard_map(scan_prog, mesh=mesh,
                        in_specs=(P(), cp, P()),
                        out_specs=(cp, stats_pspecs(len(layers)), P(),
                                   P(None, "data"), P()),
                        check_rep=False)
    return sharded(params, carry, batches)


# --------------------------------------------- hybrid-parallel pipeline
def _tick_program_2d(rounds, params, topo, states, sink, sink_seen,
                     queries, ring, inbox, eb, rb, vb, qb, lb, now, wconf,
                     outbox_cap, router, delivery, delta_eps=0.0, ts=None,
                     tcfg=None, head=None, acts=None, telemetry=False):
    """ONE micro-tick of the LAYER-PIPELINED program (ISSUE 7) — the
    shard_map body on a 2-D ("stage", "data") mesh.

    Layer l = r*S + s lives on stage s and runs at round r; each tick
    every stage runs its R = L // S rounds against inputs one hop
    behind: round r's inbox is what the PREVIOUS stage shifted into ring
    slot r last tick, except stage 0 — whose round 0 reads the host
    feature inbox and whose round r > 0 reads slot r-1 (the wrap hop
    from stage S-1's round r-1). Every round's outbox is ppermute'd to
    the next stage IMMEDIATELY after its compute (`stage_shift`) so the
    hop overlaps the remaining rounds' work (double buffering). The
    final layer's rows reach the stage-replicated sink SAME-tick via
    `stage_last`; the redundant wrap copy stage 0 receives in slot R-1
    has its valid column zeroed — it is never a round input.

    Topology batches are stage-replicated and applied identically on
    every stage; the query plane runs identically per stage (its wire
    lane rides round 0's exchange on EVERY stage, which keeps QueryState
    stage-replicated — wire-row telemetry therefore counts the lane S
    times, once per stage's round-0 layer). Per-layer stats stay
    data-psum'd only: each stage's round-r scalars describe layer r*S+s,
    left as [1]-shaped leaves that stack to [S] over the stage out-spec.

    TRAINING plane (ISSUE 8, `tcfg` set): TrainState is stage-REPLICATED
    — the forward takes round r's params from ts.params at the stage's
    own layer index (l = r*S + stage), and after the answer stage every
    stage all_gathers the per-round layer caches over the stage axis and
    runs the SAME deterministic full-L backward, so data-axis collectives
    keep all stage copies bit-identical (acts: the static per-layer 0/1
    activation flags driving the StagedActLayer relu).
    """
    R = len(rounds)
    part0 = router.part0()
    topo = st.apply_vertex_batch(topo, vb, part0)
    topo = st.apply_repl_batch(topo, rb, part0)
    topo = st.apply_edge_batch(topo, eb, part0)
    batch_work = (jnp.any(inbox.valid) | jnp.any(eb.valid)
                  | jnp.any(rb.valid))
    ring = ring[0]                            # local [R, C_buf, W_fb]
    d = states[0].feat.shape[-1]
    proto = ev.empty_feat_batch(ring.shape[1], d)
    vcol = field_col(proto, "valid")
    occ0 = jnp.sum((ring[..., vcol] > 0.5).astype(jnp.int32))
    sq = lambda t: jax.tree.map(lambda a: a[0], t)
    ex = lambda t: jax.tree.map(lambda a: a[None], t)
    sq_states = [sq(s) for s in states]
    queries, wire, adm_drop, n_adm = query_admit_stage(
        queries, qb, sq_states, sink, sink_seen, router, batch_work,
        extra_work=occ0)
    host_rows = pad_lane(pack_lane(inbox), ring.shape[1])
    is0 = router.stage_index() == 0
    wire_d = None
    new_states, stats_all, new_slots, idle = [], [], [], []
    out_rows = None
    for r in range(R):
        if r == 0:
            rows_in = jnp.where(is0, host_rows, ring[0])
        else:
            rows_in = jnp.where(is0, ring[r - 1], ring[r])
        round_inbox = unpack_lane(rows_in, proto)
        idle.append((~jnp.any(round_inbox.valid)).astype(jnp.int32))
        extra = ((wire, (queries.wire_defer, queries.wire_defer_ok))
                 if r == 0 and wire is not None else None)
        if tcfg is not None:
            # live trained params: round r's layer on THIS stage is
            # l = r*S + stage_index — gather it from the replicated
            # TrainState by dynamic stage index
            S = router.n_stages
            sidx = router.stage_index()
            stk = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[ts.params[f"l{r * S + s}"] for s in range(S)])
            rparams = {
                "p": jax.tree.map(lambda a: jnp.take(a, sidx, axis=0), stk),
                "act": jnp.take(jnp.asarray(acts, jnp.float32),
                                jnp.int32(r) * S + sidx)}
        else:
            rparams = sq(params[f"r{r}"])
        ls, outbox, stats, extra_out = layer_tick_body(
            rounds[r], rparams, topo, sq_states[r],
            round_inbox, eb, rb, now, wconf, outbox_cap, router,
            delivery, extra_lane=extra, delta_eps=delta_eps,
            telemetry=telemetry)
        if extra is not None:
            wire_d, (wdb, wdo) = extra_out
            queries = replace(queries, wire_defer=wdb, wire_defer_ok=wdo)
        new_states.append(ls)
        stats_all.append(stats)
        out_rows = pad_lane(pack_lane(outbox), ring.shape[1])
        # DOUBLE BUFFER: post the hop now — the remaining rounds' compute
        # overlaps the transfer
        new_slots.append(router.stage_shift(out_rows))
    # same-tick sink feed: the LAST stage's final-round outbox, delivered
    # to every stage's replica of the sink
    final_fb = unpack_lane(router.stage_last(out_rows), proto)
    sink, sink_seen = _sink_update_body(sink, sink_seen, final_fb, part0)
    # the wrap copy stage 0 received in slot R-1 is the final layer's
    # outbox again (already materialized above) — never a round input
    last = new_slots[R - 1]
    last = last.at[:, vcol].set(jnp.where(is0, 0.0, last[:, vcol]))
    new_slots[R - 1] = last
    new_ring = jnp.stack(new_slots)[None]     # back to [1, R, C_buf, W]
    occ1 = jnp.sum((new_ring[0, ..., vcol] > 0.5).astype(jnp.int32))
    queries, ans, qstats = query_answer_stage(
        queries, wire_d, qb, adm_drop, n_adm, tuple(new_states), sink,
        sink_seen, now, stats_all, router, extra_work=occ1)
    # training plane: every stage gathers ALL rounds' caches over the
    # stage axis and runs the identical full-L backward (TrainState stays
    # stage-replicated; see module docstring of core/train_plane.py)
    new_ts = ts
    if tcfg is not None:
        S = router.n_stages
        L = R * S
        # per-stage stats cover only that stage's layers: the movement
        # vote needs the extra stage-axis reduction
        moved = router.psum_stage(sum(moved_msgs(s) for s in stats_all))
        feats_all = [None] * L
        for r in range(R):
            gf = router.stage_gather(new_states[r].feat)
            ga = router.stage_gather(new_states[r].agg)
            gc = router.stage_gather(new_states[r].agg_cnt)
            for s in range(S):
                feats_all[r * S + s] = (gf[s], ga[s], gc[s])
        layers_bw = tuple(
            (rounds[0], {"p": ts.params[f"l{l}"],
                         "act": jnp.asarray(acts[l], jnp.float32)}, True)
            for l in range(L))
        new_ts = train_stage(tcfg, head, layers_bw, tuple(feats_all),
                             topo, sink, sink_seen, ts, lb, final_fb,
                             now, moved, router, part0)
    idle_v = router.psum(jnp.stack(idle))[None]   # [1, R] -> [S, R]
    # telemetry plane: the occ row folds the per-stage partial stats over
    # the stage axis (psum_stage / pmax_stage) so it is globally
    # replicated — same row on every device, P() out-spec
    occ = (_occ_row(stats_all, qstats, new_ts, router, stage=True)
           if telemetry else _zero_occ_row())
    return (topo, tuple(ex(s) for s in new_states), sink, sink_seen,
            queries, new_ring, tuple(ex(s) for s in stats_all), idle_v,
            ans, qstats, new_ts, occ)


@partial(jax.jit, static_argnames=("rounds", "wconf", "outbox_cap",
                                   "router", "delivery", "mesh",
                                   "delta_eps", "tcfg", "head", "acts",
                                   "telemetry"))
def _tick_jit_2d(rounds, params, topo, states, sink, sink_seen, queries,
                 ring, inbox, eb, rb, vb, qb, lb, ts, now, wconf,
                 outbox_cap, router, delivery, mesh, delta_eps=0.0,
                 tcfg=None, head=None, acts=None, telemetry=False):
    """The per-tick driver's device program on the 2-D mesh."""
    def prog(params, topo, states, sink, sink_seen, queries, ring, inbox,
             eb, rb, vb, qb, lb, ts, now):
        return _tick_program_2d(
            rounds, params, topo, states, sink, sink_seen, queries, ring,
            inbox, eb, rb, vb, qb, lb, now, wconf, outbox_cap, router,
            delivery, delta_eps, ts, tcfg, head, acts, telemetry)

    cp = stage_carry_pspecs(len(rounds))
    tspec = train_pspecs(ts) if tcfg is not None else P()
    pspec = jax.tree.map(lambda _: P("stage"), params)
    sharded = shard_map(
        prog, mesh=mesh,
        in_specs=(pspec, cp.topo, cp.layers, cp.sink, cp.sink_seen,
                  cp.queries, cp.stage_ring, P(), P(), P(), P(), P(),
                  P(), tspec, P()),
        out_specs=(cp.topo, cp.layers, cp.sink, cp.sink_seen, cp.queries,
                   cp.stage_ring, stage_stats_pspecs(len(rounds)),
                   P("stage"), P("data"), P(), tspec, P()),
        check_rep=False)
    return sharded(params, topo, states, sink, sink_seen, queries, ring,
                   inbox, eb, rb, vb, qb, lb, ts, now)


@partial(jax.jit, static_argnames=("rounds", "wconf", "outbox_cap",
                                   "router", "delivery", "mesh",
                                   "delta_eps", "tcfg", "head", "acts",
                                   "telemetry"),
         donate_argnums=(2,))
def _super_tick_scan_2d(rounds, params, carry: st.PipelineCarry, batches,
                        wconf: win.WindowConfig, outbox_cap: int, router,
                        delivery=None, mesh=None, delta_eps=0.0,
                        tcfg=None, head=None, acts=None, telemetry=False):
    """T micro-ticks of the PIPELINED program as one `lax.scan`.

    Same contract as `_super_tick_scan` plus: the donated carry includes
    the inter-stage ring (in-flight rows stay device-resident between
    ticks AND between super-ticks), quiescence counts ring occupancy as
    pending work (a flush super-tick keeps draining until the skewed
    tail has telescoped through every stage), and a third summed output
    carries the [S, R] idle-device-round bubble counters."""
    R = len(rounds)

    def scan_prog(params, carry, batches):
        n_parts_loc = carry.topo.n_parts      # LOCAL block under mesh
        sq = lambda t: jax.tree.map(lambda a: a[0], t)

        def body(state, batch_t):
            c, ssum, isum, qsum = state
            fb, eb, rb, vb, qb, lb = batch_t
            (topo, new_layers, sink, sink_seen, queries, ring, stats_t,
             idle_t, ans, qstats_t, new_ts, occ_row) = _tick_program_2d(
                rounds, params, c.topo, c.layers, c.sink, c.sink_seen,
                c.queries, c.stage_ring, fb, eb, rb, vb, qb, lb, c.now,
                wconf, outbox_cap, router, delivery, delta_eps, c.train,
                tcfg, head, acts, telemetry)
            # rows still in flight between stages are pending work; the
            # valid flag packs LAST in a FeatBatch wire row
            occ = jnp.sum((ring[0, ..., -1] > 0.5).astype(jnp.int32))
            quiet = quiet_update(c.quiet, [sq(s) for s in new_layers],
                                 [sq(s) for s in stats_t], router,
                                 queries=queries, extra_work=occ)
            new_c = st.PipelineCarry(
                topo=topo, layers=new_layers, sink=sink,
                sink_seen=sink_seen, queries=queries,
                now=c.now + jnp.int32(1), quiet=quiet, stage_ring=ring,
                train=new_ts)
            ssum = tuple(add_stats(a, b) for a, b in zip(ssum, stats_t))
            return (new_c, ssum, isum + idle_t,
                    add_query_stats(qsum, qstats_t)), (ans, occ_row)

        zeros = tuple(jax.tree.map(lambda a: a[None],
                                   zero_stats(n_parts_loc))
                      for _ in range(R))
        izero = jnp.zeros((1, R), jnp.int32)
        (final, ssum, isum, qsum), (answers, occ_t) = jax.lax.scan(
            body, (carry, zeros, izero, zero_query_stats()), batches)
        return final, ssum, isum, qsum, answers, occ_t

    cp = stage_carry_pspecs(R, train=(train_pspecs(carry.train)
                                      if tcfg is not None else None))
    pspec = jax.tree.map(lambda _: P("stage"), params)
    sharded = shard_map(scan_prog, mesh=mesh,
                        in_specs=(pspec, cp, P()),
                        out_specs=(cp, stage_stats_pspecs(R), P("stage"),
                                   P(), P(None, "data"), P()),
                        check_rep=False)
    return sharded(params, carry, batches)
