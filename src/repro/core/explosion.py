"""Explosion factor (paper §4.2.3) + logical->physical mapping (Alg. 5).

Layer i of L gets parallelism p_i = p * lambda^(i-1): deeper GraphStorage
operators get more sub-operators to absorb neighborhood explosion. Logical
parts are fixed at max_parallelism; the physical sub-operator of a logical
part under parallelism `par` is Alg. 5:

    key_group     = logical_part % max_parallelism
    physical_part = key_group * par // max_parallelism

which keeps every sub-operator non-idle (contiguous key ranges) and makes
re-scaling a pure remap — state moves with its logical part (used by
ft/elastic.py).
"""
from __future__ import annotations

import numpy as np


def physical_part(logical_part, parallelism: int, max_parallelism: int):
    """Algorithm 5 (vectorized: works on ints or numpy arrays)."""
    key_group = logical_part % max_parallelism
    return key_group * parallelism // max_parallelism


def layer_parallelisms(p: int, lam: float, n_layers: int,
                       max_parallelism: int) -> list[int]:
    """p_i = p * lam^(i-1), capped at max_parallelism."""
    return [max(1, min(max_parallelism, int(round(p * lam ** i))))
            for i in range(n_layers)]


def physical_busy(logical_busy: np.ndarray, parallelism: int,
                  max_parallelism: int) -> np.ndarray:
    """Aggregate a [P_logical] busy vector onto physical sub-operators."""
    phys = physical_part(np.arange(len(logical_busy)), parallelism,
                         max_parallelism)
    out = np.zeros(parallelism)
    np.add.at(out, phys, logical_busy)
    return out


def imbalance_factor(busy: np.ndarray) -> float:
    """Paper's metric: max(busy) / mean(busy)."""
    m = busy.mean()
    return float(busy.max() / m) if m > 0 else 0.0
