"""Unified streaming event format (paper §4.1) + padded device batches.

Host events are light dataclasses; the partitioner turns a tick's worth of
them into fixed-capacity, mask-padded struct-of-arrays batches that the
jitted layer tick consumes. Every batch row is pre-addressed: the host
partitioner resolves global vertex ids to (part, slot) coordinates — the
JVM-side master tables of the paper live in the Partitioner here, so the
device program never needs a hash lookup.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

EDGE_ADD = 1
FEAT_UPDATE = 3


@dataclass(frozen=True)
class EdgeBatch:
    """New-edge records for one tick (device-ready).

    Each record scatters one directed edge (u -> v) into the part that the
    vertex-cut partitioner chose. Both endpoints have (replica) slots there.
    """
    part: jnp.ndarray            # [C] int32 destination part of the record
    edge_slot: jnp.ndarray       # [C] int32 slot in the part's edge table
    src_slot: jnp.ndarray        # [C] int32 local slot of u in `part`
    dst_slot: jnp.ndarray        # [C] int32 local slot of v in `part`
    dst_master_part: jnp.ndarray # [C] int32 master coordinates of v
    dst_master_slot: jnp.ndarray # [C] int32
    valid: jnp.ndarray           # [C] bool

    @property
    def capacity(self):
        return self.part.shape[0]


@dataclass(frozen=True)
class ReplBatch:
    """New replica records: master (part, slot) -> replica (part, slot).

    Scattered into the master part's replication adjacency, used for the
    selectiveBroadcast of features to replicas (paper §5.1).
    """
    part: jnp.ndarray            # [C] int32 master part (where record lives)
    repl_slot: jnp.ndarray       # [C] int32 slot in the replication table
    master_slot: jnp.ndarray     # [C] int32 master's local slot
    rep_part: jnp.ndarray        # [C] int32 replica coordinates
    rep_slot: jnp.ndarray        # [C] int32
    valid: jnp.ndarray           # [C] bool


@dataclass(frozen=True)
class VertexBatch:
    """New vertex (replica) records: existence + mastership flags."""
    part: jnp.ndarray            # [C] int32
    slot: jnp.ndarray            # [C] int32
    is_master: jnp.ndarray       # [C] bool
    valid: jnp.ndarray           # [C] bool


@dataclass(frozen=True)
class FeatBatch:
    """Feature updates addressed to master (part, slot)."""
    part: jnp.ndarray            # [C] int32
    slot: jnp.ndarray            # [C] int32
    feat: jnp.ndarray            # [C, d] float
    valid: jnp.ndarray           # [C] bool

    @property
    def capacity(self):
        return self.part.shape[0]


@dataclass(frozen=True)
class LabelBatch:
    """Label observations addressed to master (part, slot) — the training
    plane's admission unit (capacity = PipelineConfig.train_cap; 0
    compiles the plane away)."""
    part: jnp.ndarray            # [C] int32
    slot: jnp.ndarray            # [C] int32
    label: jnp.ndarray           # [C] int32 gold class
    valid: jnp.ndarray           # [C] bool

    @property
    def capacity(self):
        return self.part.shape[0]


@dataclass(frozen=True)
class MsgBatch:
    """Fixed-capacity, part-addressed message records — the routing plane's
    unit of exchange (one tick's cross-part traffic for one round).

    The compute plane emits these instead of scattering into other parts'
    rows; a Router delivers them (identity on one device, fixed-capacity
    all_to_all on the mesh) and a part-local apply stage consumes them.
    Payload semantics are the consumer's: Round-A broadcast rows SET a
    feature value, Round-B RMI rows ADD an aggregator (delta, dcnt) record.
    """
    part: jnp.ndarray            # [C] int32 destination part (global id)
    slot: jnp.ndarray            # [C] int32 destination slot in that part
    vec: jnp.ndarray             # [C, d] float payload
    cnt: jnp.ndarray             # [C] float count delta (Round B; zeros for A)
    src_part: jnp.ndarray        # [C] int32 emitting part (cross-part stats)
    valid: jnp.ndarray           # [C] bool

    @property
    def capacity(self):
        return self.part.shape[0]

    @property
    def payload_dim(self):
        return self.vec.shape[1]


for _cls, _fields in ((EdgeBatch, ["part", "edge_slot", "src_slot", "dst_slot",
                                   "dst_master_part", "dst_master_slot", "valid"]),
                      (ReplBatch, ["part", "repl_slot", "master_slot",
                                   "rep_part", "rep_slot", "valid"]),
                      (VertexBatch, ["part", "slot", "is_master", "valid"]),
                      (FeatBatch, ["part", "slot", "feat", "valid"]),
                      (LabelBatch, ["part", "slot", "label", "valid"]),
                      (MsgBatch, ["part", "slot", "vec", "cnt", "src_part",
                                  "valid"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields, meta_fields=[])


def empty_edge_batch(cap: int) -> EdgeBatch:
    z = jnp.zeros((cap,), jnp.int32)
    return EdgeBatch(part=z, edge_slot=z, src_slot=z, dst_slot=z,
                     dst_master_part=z, dst_master_slot=z,
                     valid=jnp.zeros((cap,), bool))


def empty_repl_batch(cap: int) -> ReplBatch:
    z = jnp.zeros((cap,), jnp.int32)
    return ReplBatch(part=z, repl_slot=z, master_slot=z, rep_part=z,
                     rep_slot=z, valid=jnp.zeros((cap,), bool))


def empty_feat_batch(cap: int, d: int) -> FeatBatch:
    return FeatBatch(part=jnp.zeros((cap,), jnp.int32),
                     slot=jnp.zeros((cap,), jnp.int32),
                     feat=jnp.zeros((cap, d), jnp.float32),
                     valid=jnp.zeros((cap,), bool))


def vertex_batch_from_numpy(rows: dict, cap: int,
                            device: bool = True) -> VertexBatch:
    """device=False keeps numpy leaves — the super-tick staging path stacks
    T batches on host and ships ONE transfer per field, so materializing
    each tick's batch on device first would round-trip every row twice."""
    n = len(rows["part"])
    assert n <= cap, f"vertex batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)
    p = np.zeros((cap,), np.int32)
    s = np.zeros((cap,), np.int32)
    m = np.zeros((cap,), bool)
    v = np.zeros((cap,), bool)
    p[:n] = rows["part"]
    s[:n] = rows["slot"]
    m[:n] = rows["is_master"]
    v[:n] = True
    return VertexBatch(part=conv(p), slot=conv(s),
                       is_master=conv(m), valid=conv(v))


def edge_batch_from_numpy(rows: dict, cap: int,
                          device: bool = True) -> EdgeBatch:
    n = len(rows["part"])
    assert n <= cap, f"edge batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)

    def pad(a, dtype=np.int32):
        out = np.zeros((cap,), dtype)
        out[:n] = a
        return conv(out)

    valid = np.zeros((cap,), bool)
    valid[:n] = True
    return EdgeBatch(part=pad(rows["part"]), edge_slot=pad(rows["edge_slot"]),
                     src_slot=pad(rows["src_slot"]), dst_slot=pad(rows["dst_slot"]),
                     dst_master_part=pad(rows["dst_master_part"]),
                     dst_master_slot=pad(rows["dst_master_slot"]),
                     valid=conv(valid))


def repl_batch_from_numpy(rows: dict, cap: int,
                          device: bool = True) -> ReplBatch:
    n = len(rows["part"])
    assert n <= cap, f"repl batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)

    def pad(a):
        out = np.zeros((cap,), np.int32)
        out[:n] = a
        return conv(out)

    valid = np.zeros((cap,), bool)
    valid[:n] = True
    return ReplBatch(part=pad(rows["part"]), repl_slot=pad(rows["repl_slot"]),
                     master_slot=pad(rows["master_slot"]),
                     rep_part=pad(rows["rep_part"]), rep_slot=pad(rows["rep_slot"]),
                     valid=conv(valid))


def concat_msg_batches(a: MsgBatch, b: MsgBatch) -> MsgBatch:
    """Concatenate two MsgBatches along the record axis (same payload dim).

    Round B emits new-edge RMIs and windowed delta RMIs as separate
    batches; one concatenated batch rides the router and the delivery
    backend consumes it as a single fixed-capacity segment reduction.
    """
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y]), a, b)


def coalesce_msg_batch(b: MsgBatch, n_slots: int) -> MsgBatch:
    """Coalesce same-destination records of one MsgBatch (ISSUE 6).

    Aggregator RMIs are additive (core/aggregators.py), so every record
    addressed to the same (part, slot) within one tick can be pre-summed
    BEFORE the routing plane: the coalesced batch keeps the capacity (the
    routing wire is fixed-shape) but carries one live row per distinct
    destination — fewer live rows through the capped all_to_all buckets
    and the defer rings. `n_slots` is the per-part slot count (the
    destination key is part * n_slots + slot).

    Each run's vec/cnt are the sum over the run's records, its src_part
    the first record's (cross-part stats are counted at emission time,
    pre-coalesce — see round_b_emit). The summation ORDER of f32 payloads
    differs from record order, so the delta-gated tick only coalesces in
    approximate mode (delta_eps > 0), where reordering is within budget.
    ADD semantics only — never coalesce a set-semantics lane this way.
    """
    C = b.part.shape[0]
    big = jnp.int32(n_slots) * jnp.max(b.part + 1) + jnp.int32(C)
    key = jnp.where(b.valid, b.part * n_slots + b.slot, big)
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    valid_s = b.valid[order]
    head = jnp.concatenate([jnp.ones((1,), bool), key_s[1:] != key_s[:-1]])
    run = jnp.cumsum(head) - 1                   # run index per sorted row
    vec = jnp.zeros_like(b.vec).at[run].add(
        jnp.where(valid_s[:, None], b.vec[order], 0.0))
    cnt = jnp.zeros_like(b.cnt).at[run].add(
        jnp.where(valid_s, b.cnt[order], 0.0))
    # run-head rows carry the destination; non-head rows are dead padding
    pos = jnp.where(head, run, C - 1)
    take = jnp.zeros((C,), jnp.int32).at[pos].max(
        jnp.arange(C, dtype=jnp.int32))          # head's sorted position
    src = order[take]                            # original row of each head
    live = jnp.zeros((C,), bool).at[pos].set(head & valid_s, mode="drop")
    return MsgBatch(part=b.part[src], slot=b.slot[src], vec=vec, cnt=cnt,
                    src_part=b.src_part[src], valid=live)


def stack_batches(batches):
    """Stack same-capacity event batches along a new leading tick axis.

    Host staging for the super-tick driver: T per-tick padded batches become
    one pytree whose leaves carry a leading [T] axis, so `lax.scan` can slice
    one micro-tick per step with zero host round-trips. Stacking happens in
    numpy and ships each field to the device in ONE transfer instead of T.
    All batches must share capacities (they do: capacities derive from the
    PipelineConfig, not from the tick's payload).
    """
    assert batches, "cannot stack an empty batch list"
    return jax.tree.map(
        lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])),
        *batches)


def empty_label_batch(cap: int) -> LabelBatch:
    z = jnp.zeros((cap,), jnp.int32)
    return LabelBatch(part=z, slot=z, label=z,
                      valid=jnp.zeros((cap,), bool))


def label_batch_from_numpy(parts, slots, labels, cap: int,
                           device: bool = True) -> LabelBatch:
    n = len(parts)
    assert n <= cap, f"label batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)
    p = np.zeros((cap,), np.int32)
    s = np.zeros((cap,), np.int32)
    y = np.zeros((cap,), np.int32)
    v = np.zeros((cap,), bool)
    p[:n] = parts
    s[:n] = slots
    y[:n] = labels
    v[:n] = True
    return LabelBatch(part=conv(p), slot=conv(s), label=conv(y),
                      valid=conv(v))


def feat_batch_from_numpy(parts, slots, feats, cap: int, d: int,
                          device: bool = True) -> FeatBatch:
    n = len(parts)
    assert n <= cap, f"feat batch overflow: {n} > {cap}"
    conv = jnp.asarray if device else (lambda a: a)
    p = np.zeros((cap,), np.int32)
    s = np.zeros((cap,), np.int32)
    f = np.zeros((cap, d), np.float32)
    v = np.zeros((cap,), bool)
    p[:n] = parts
    s[:n] = slots
    if n:
        f[:n] = feats
    v[:n] = True
    return FeatBatch(part=conv(p), slot=conv(s), feat=conv(f), valid=conv(v))
