"""Streaming vertex-cut partitioners (paper §4.4): HDRF, CLDA-like, Random.

The Partitioner is a host-side operator (as in the paper, where it is a
dedicated Flink operator with shared degree/partition tables). It assigns:
  * a logical part to every edge (vertex-cut: edges are atomic, vertices
    replicate),
  * master parts (first placement) and per-part local slots for vertices,
  * replication records used for master->replica feature broadcast.

Edges are scored in vectorized chunks against a frozen table snapshot, with
tables updated between chunks — the same mild staleness the paper accepts
when distributing the partitioner across threads (§4.4.1, vertex-locking).

HDRF (Petroni et al., CIKM'15) score for edge (u,v) and part p:
    C_REP = g(u,p) + g(v,p),  g(u,p) = [u in p] * (1 + (1 - theta_u))
      with theta_u = d(u) / (d(u) + d(v))  (normalized partial degree)
    C_BAL = bal * (maxsize - size_p) / (eps + maxsize - minsize)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PartitionTables:
    n_parts: int
    max_nodes: int
    degree: np.ndarray                  # [V] partial degrees
    replicas: np.ndarray                # [V, P] bool membership
    load: np.ndarray                    # [P] edge counts
    master: np.ndarray                  # [V] int32, -1 = unseen
    master_slot: np.ndarray             # [V] int32
    slot_of: dict                       # (part, vid) -> slot
    next_vslot: np.ndarray              # [P] next free vertex slot
    next_eslot: np.ndarray              # [P] next free edge slot


class StreamingPartitioner:
    def __init__(self, n_parts: int, max_nodes: int, method: str = "hdrf",
                 bal: float = 2.0, eps: float = 1.0, seed: int = 0,
                 chunk: int = 1024):
        self.method = method
        self.bal = bal
        self.eps = eps
        self.chunk = chunk
        self.rng = np.random.default_rng(seed)
        self.t = PartitionTables(
            n_parts=n_parts, max_nodes=max_nodes,
            degree=np.zeros(max_nodes, np.int64),
            replicas=np.zeros((max_nodes, n_parts), bool),
            load=np.zeros(n_parts, np.int64),
            master=np.full(max_nodes, -1, np.int32),
            master_slot=np.full(max_nodes, -1, np.int32),
            slot_of={}, next_vslot=np.zeros(n_parts, np.int64),
            next_eslot=np.zeros(n_parts, np.int64))
        self._repl_counters = np.zeros(n_parts, np.int64)
        self._v_rows = {k: [] for k in ("part", "slot", "is_master")}
        self._r_rows = {k: [] for k in ("part", "repl_slot", "master_slot",
                                        "rep_part", "rep_slot")}

    # ------------------------------------------------------------- scoring
    def _affinity_chunk(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Replication-affinity term per (edge, part). Degree/replica tables
        are frozen per chunk (the paper's concurrent-partitioner staleness,
        §4.4.1); the balance term is applied per edge with live loads in
        _pick_part to keep parts even."""
        t = self.t
        du = t.degree[src] + 1.0
        dv = t.degree[dst] + 1.0
        theta_u = (du / (du + dv))[:, None]                    # [C,1]
        theta_v = 1.0 - theta_u
        in_u = t.replicas[src]                                 # [C,P]
        in_v = t.replicas[dst]
        if self.method == "hdrf":
            return in_u * (1 + (1 - theta_u)) + in_v * (1 + (1 - theta_v))
        if self.method == "clda":
            # CLDA-like: degree-attenuated replication affinity — replicas of
            # low-degree endpoints pull harder (clustered placement).
            return in_u * (1 + (1.0 / np.sqrt(du))[:, None]) + \
                in_v * (1 + (1.0 / np.sqrt(dv))[:, None])
        raise ValueError(self.method)

    def _pick_part(self, g_row: np.ndarray) -> int:
        t = self.t
        mx, mn = t.load.max(), t.load.min()
        c_bal = self.bal * (mx - t.load) / (self.eps + mx - mn)
        return int(np.argmax(g_row + c_bal))

    # ------------------------------------------------------------- ingest
    def ingest_edges(self, edges: np.ndarray):
        """edges: [n,2] int (src, dst) global ids.

        Returns (edge_rows, repl_rows, vertex_rows) dicts of numpy columns,
        ready for the events.*_batch_from_numpy constructors. Repl/vertex
        rows include any allocations made via locate_master since the last
        call (the buffers are drained here).
        """
        t = self.t
        e_rows = {k: [] for k in ("part", "edge_slot", "src_slot", "dst_slot",
                                  "dst_master_part", "dst_master_slot")}
        for lo in range(0, len(edges), self.chunk):
            chunk = edges[lo: lo + self.chunk]
            if self.method == "random":
                parts = self.rng.integers(0, t.n_parts, size=len(chunk))
                aff = None
            else:
                aff = self._affinity_chunk(chunk[:, 0], chunk[:, 1])
            for ci, (u, v) in enumerate(chunk):
                p = int(parts[ci]) if aff is None else self._pick_part(aff[ci])
                u, v = int(u), int(v)
                su = self._ensure_vertex(u, p)
                sv = self._ensure_vertex(v, p)
                es = t.next_eslot[p]
                t.next_eslot[p] += 1
                e_rows["part"].append(p)
                e_rows["edge_slot"].append(es)
                e_rows["src_slot"].append(su)
                e_rows["dst_slot"].append(sv)
                e_rows["dst_master_part"].append(t.master[v])
                e_rows["dst_master_slot"].append(t.master_slot[v])
                t.load[p] += 1
                t.degree[u] += 1
                t.degree[v] += 1
        e_rows = {k: np.asarray(v, np.int64) for k, v in e_rows.items()}
        r_rows, v_rows = self.drain_allocations()
        return e_rows, r_rows, v_rows

    def drain_allocations(self):
        """Pop accumulated replica + vertex rows (numpy columns)."""
        r = {k: np.asarray(v, np.int64) for k, v in self._r_rows.items()}
        vr = {k: np.asarray(v) for k, v in self._v_rows.items()}
        self._r_rows = {k: [] for k in self._r_rows}
        self._v_rows = {k: [] for k in self._v_rows}
        return r, vr

    def _ensure_vertex(self, vid: int, part: int) -> int:
        """Make sure vid has a slot in `part`; allocate master/replica."""
        t = self.t
        key = (part, vid)
        slot = t.slot_of.get(key)
        if slot is not None:
            return slot
        slot = int(t.next_vslot[part])
        t.next_vslot[part] += 1
        t.slot_of[key] = slot
        t.replicas[vid, part] = True
        first = t.master[vid] < 0
        if first:
            t.master[vid] = part
            t.master_slot[vid] = slot
        else:
            # new replica: record master -> replica broadcast edge
            self._r_rows["part"].append(int(t.master[vid]))
            self._r_rows["repl_slot"].append(self._alloc_repl(int(t.master[vid])))
            self._r_rows["master_slot"].append(int(t.master_slot[vid]))
            self._r_rows["rep_part"].append(part)
            self._r_rows["rep_slot"].append(slot)
        self._v_rows["part"].append(part)
        self._v_rows["slot"].append(slot)
        self._v_rows["is_master"].append(bool(first))
        return slot

    def _alloc_repl(self, master_part: int) -> int:
        c = int(self._repl_counters[master_part])
        self._repl_counters[master_part] += 1
        return c

    # --------------------------------------------------------- feature path
    def locate_master(self, vid: int, create: bool = True):
        """(part, slot) of vid's master; optionally create on least-loaded."""
        t = self.t
        if t.master[vid] < 0:
            if not create:
                return None
            p = int(np.argmin(t.load))
            self._ensure_vertex(vid, p)
        return int(t.master[vid]), int(t.master_slot[vid])

    # ------------------------------------------------------------- metrics
    def replication_factor(self) -> float:
        seen = self.t.master >= 0
        if not seen.any():
            return 0.0
        return float(self.t.replicas[seen].sum() / seen.sum())

    def load_imbalance(self) -> float:
        ld = self.t.load
        return float(ld.max() / max(ld.mean(), 1e-9))

    @property
    def n_parts(self):
        return self.t.n_parts
