"""The DELIVERY plane: how routed records land in operator state.

The streaming tick is three planes (ISSUE 3 tentpole):

  COMPUTE  (core/tick.py)   — pure part-local stages that emit
                              part-addressed records;
  ROUTING  (dist/router.py) — a Router moves records to the device that
                              owns their destination part;
  DELIVERY (here)           — a DeliveryBackend lands the delivered
                              records in the local state blocks.

A backend provides the three state effects the tick's hot path needs:

  deliver_set   : feature rows SET at local masters/replicas (Round A
                  inbox apply, Round B broadcast apply) — last-writer-
                  wins plus a touched flag per row;
  deliver_add   : aggregator RMI records ADD (delta vec, delta cnt) at
                  local masters plus a dirty flag (apply_rmis) — one
                  delivery regardless of the reduce/replace/remove mix;
  agg_read_rows : the MEAN-synopsis read at the forward stage's picked
                  rows (forward_psi).

Two registered implementations, golden-equivalent by test
(tests/test_delivery_backend.py):

  "xla"    — the reference: flat `.at[].set/.add(mode="drop")` scatters
             with the one-past-the-end drop sentinel (state.local_index).
  "pallas" — sorted fixed-capacity segment reductions through
             `kernels/segment_reduce`: each delivery is one stable sort
             plus one one-hot MXU matmul pass (`segment_deliver`), and
             the aggregator read goes through `mean_rows` so the full
             [P*N, d] mean table is never materialized — only the picked
             rows are divided. Off-TPU the kernels run with
             `interpret=True`, which is how CI pins pallas ≡ xla on CPU.

Backends are small frozen dataclasses (hashable) so they ride jit
boundaries as static arguments, exactly like the Routers; both work
unchanged inside `shard_map` (they only ever see the local part block).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

from repro.core.aggregators import mean_read
from repro.kernels.segment_reduce.ops import mean_rows, segment_deliver


@dataclass(frozen=True)
class XlaDelivery:
    """Reference backend: XLA scatters guarded by the drop sentinel."""

    name = "xla"

    def deliver_set(self, dst, idx, vals):
        """Set rows of dst [R, d] at idx [C] to vals [C, d]; sentinel rows
        (idx outside [0, R)) drop. Returns (dst', touched [R] bool)."""
        touched = jnp.zeros((dst.shape[0],), bool).at[idx].set(
            True, mode="drop")
        return dst.at[idx].set(vals, mode="drop"), touched

    def deliver_add(self, agg, cnt, idx, vec, dcnt):
        """Add (vec [C, d], dcnt [C]) into (agg [R, d], cnt [R]) at idx.
        Returns (agg', cnt', dirty [R] bool)."""
        live = (idx >= 0) & (idx < agg.shape[0])
        agg = agg.at[idx].add(jnp.where(live[:, None], vec, 0.0),
                              mode="drop")
        cnt = cnt.at[idx].add(dcnt * live, mode="drop")
        dirty = jnp.zeros((agg.shape[0],), bool).at[idx].max(live,
                                                             mode="drop")
        return agg, cnt, dirty

    def agg_read_rows(self, agg, cnt, rows):
        """MEAN synopsis at `rows` [K] (materializes the full mean table,
        then gathers — XLA fuses the division into the gather anyway)."""
        return mean_read(agg, cnt)[rows]


@dataclass(frozen=True)
class PallasDelivery:
    """Pallas backend: sorted segment-reduce deliveries + fused agg read.

    Block sizes default to the MXU-aligned minimum (128) — the streaming
    tick's per-round capacities are hundreds of records, not millions.
    interpret=None resolves per-call to `jax.default_backend() != "tpu"`.
    """

    name = "pallas"
    block_e: int = 128
    block_v: int = 128
    block_r: int = 128
    interpret: Optional[bool] = None

    def deliver_set(self, dst, idx, vals):
        vec_out, _, touched = segment_deliver(
            idx, vals, jnp.zeros((idx.shape[0],), dst.dtype), dst.shape[0],
            mode="set", block_e=self.block_e, block_v=self.block_v,
            interpret=self.interpret)
        return jnp.where(touched[:, None], vec_out, dst), touched

    def deliver_add(self, agg, cnt, idx, vec, dcnt):
        d_vec, d_cnt, dirty = segment_deliver(
            idx, vec, dcnt, agg.shape[0], mode="add", block_e=self.block_e,
            block_v=self.block_v, interpret=self.interpret)
        return agg + d_vec, cnt + d_cnt, dirty

    def agg_read_rows(self, agg, cnt, rows):
        return mean_rows(agg[rows], cnt[rows], block_r=self.block_r,
                         interpret=self.interpret)


BACKENDS = {"xla": XlaDelivery, "pallas": PallasDelivery}


def make_delivery(name: str, **overrides):
    """Build a registered delivery backend (PipelineConfig.delivery_backend
    resolves here); unknown names fail with the registry listed."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown delivery_backend {name!r}: expected one of "
            f"{sorted(BACKENDS)}") from None
    return cls(**overrides)
