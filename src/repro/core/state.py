"""Device-side operator state (the paper's Graph Storage, §4.1/§5.2).

All arrays are [P, cap, ...] — P logical parts stacked on the leading axis.
Every function here operates on the LOCAL block of parts it is handed:
on one device that block is the full [P, ...] axis (LocalRouter, part0=0);
under `D3Pipeline(mesh=...)` the part axis is block-sharded over the
("data",) mesh axis and each shard_map instance sees [P/D, ...] with
part0 = axis_index * P/D. Cross-part traffic is explicit: the tick emits
part-addressed `MsgBatch` records and `repro/dist/router.py` delivers them
(identity locally, fixed-capacity all_to_all on the mesh) — the sharding
rules for the carry live in `repro/dist/sharding.py`.

Topology is stored once and shared by all layer operators (the paper ships
the same topology events to every GraphStorage; storing it once per job is
an optimization with identical semantics — DESIGN §2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def local_index(part, slot, part0, n_local_parts: int, stride: int,
                valid):
    """Guarded local flat index for globally part-addressed records.

    Returns (flat_idx, local_part): flat = (part - part0) * stride + slot
    for rows that are valid AND belong to a locally-owned part, else the
    one-past-the-end sentinel (n_local_parts * stride resp. n_local_parts)
    so `.at[idx].op(..., mode="drop")` discards them. The explicit >= 0
    guard matters: negative indices WRAP in jax, they are not dropped.
    """
    lp = part - part0
    ok = valid & (lp >= 0) & (lp < n_local_parts)
    flat = jnp.where(ok, lp * stride + slot, n_local_parts * stride)
    return flat, jnp.where(ok, lp, n_local_parts)


@dataclass(frozen=True)
class TopoState:
    """Shared adjacency + replication tables."""
    # out-edge records, stored in the part the edge was assigned to
    e_src_slot: jnp.ndarray       # [P, E] int32 local slot of u
    e_dst_slot: jnp.ndarray       # [P, E] int32 local slot of v (same part)
    e_dst_mpart: jnp.ndarray      # [P, E] int32 master part of v
    e_dst_mslot: jnp.ndarray      # [P, E] int32 master slot of v
    e_valid: jnp.ndarray          # [P, E] bool
    # replication records, stored in the master's part
    r_master_slot: jnp.ndarray    # [P, R] int32
    r_rep_part: jnp.ndarray       # [P, R] int32
    r_rep_slot: jnp.ndarray       # [P, R] int32
    r_valid: jnp.ndarray          # [P, R] bool
    # vertex flags
    v_exists: jnp.ndarray         # [P, N] bool
    is_master: jnp.ndarray        # [P, N] bool
    # master-coordinate mirror: every local vertex row knows its master's
    # global (part, slot) — a master row points at itself, a replica row
    # learns its master from the ReplBatch that created it, -1 = unknown.
    # The training plane's replica->master gradient fold (hop B in
    # core/train_plane.py) addresses its wire rows with these.
    m_part: jnp.ndarray           # [P, N] int32 (-1 until materialized)
    m_slot: jnp.ndarray           # [P, N] int32

    @property
    def n_parts(self):
        return self.e_src_slot.shape[0]

    @property
    def edge_cap(self):
        return self.e_src_slot.shape[1]


@dataclass(frozen=True)
class LayerState:
    """Per-GNN-layer feature/aggregator state (one per GraphStorage op)."""
    feat: jnp.ndarray             # [P, N, d_in] layer-input features (replicas too)
    has_feat: jnp.ndarray        # [P, N] bool
    # x_sent is the value whose phi the downstream aggregators actually
    # hold. Under delta gating (ISSUE 6, cfg.delta_eps > 0) a suppressed
    # re-emission leaves x_sent at the last EMITTED value while feat moves
    # on, so ||phi(feat) - phi(x_sent)|| is the vertex's cumulative un-sent
    # residual (<= eps whenever red_pending is clear).
    x_sent: jnp.ndarray           # [P, N, d_in] feature value last pushed into aggs
    has_sent: jnp.ndarray         # [P, N] bool
    agg: jnp.ndarray              # [P, N, d_agg] synopsis value (masters only)
    agg_cnt: jnp.ndarray          # [P, N] float counts
    # windowing state
    red_pending: jnp.ndarray      # [P, N] bool   (inter-layer: delayed reduce)
    red_deadline: jnp.ndarray     # [P, N] int32
    fwd_pending: jnp.ndarray      # [P, N] bool   (intra-layer: delayed forward)
    fwd_deadline: jnp.ndarray     # [P, N] int32
    # adaptive-session state: CountMinSketch of per-vertex update frequency
    cms: jnp.ndarray              # [depth, width] float32
    last_touch: jnp.ndarray       # [P, N] int32
    # routing-plane backpressure (ISSUE 5): per-lane defer rings of packed
    # wire rows that overflowed a capped all_to_all bucket and re-enter the
    # next tick's exchange (dist/wire.py format; [D * K, W] globally,
    # block-sharded like every part-leading table so each device carries
    # its own [K, W] ring; K = 0 under the dense default / LocalRouter)
    bc_defer: jnp.ndarray         # [DK_b, W_b] f32  round-A broadcast lane
    bc_defer_ok: jnp.ndarray      # [DK_b] bool      occupied ring slots
    rmi_defer: jnp.ndarray        # [DK_r, W_r] f32  round-B RMI lane
    rmi_defer_ok: jnp.ndarray     # [DK_r] bool

    @property
    def node_cap(self):
        return self.feat.shape[2 - 1]  # [P, N, d] -> N


@dataclass(frozen=True)
class PipelineCarry:
    """Everything the device mutates across micro-ticks, as ONE pytree.

    The super-tick driver threads this through `lax.scan` and donates it at
    the jit boundary (`donate_argnums`), so XLA reuses the topology/layer/
    sink buffers in place instead of allocating a second copy per super-tick.
    Donation-safety is why every field keeps a fixed shape and dtype:
    `now`/`quiet` are int32 device scalars, never Python ints.
    """
    topo: TopoState
    layers: tuple                 # tuple[LayerState, ...] (one per GNN layer)
    sink: jnp.ndarray             # [P, N, d_out] materialized embeddings
    sink_seen: jnp.ndarray        # [P, N] bool
    queries: object               # serve/query.py QueryState — the pending
                                  # point-query table ([P, Q] slots; Q=0
                                  # compiles the query plane away)
    now: jnp.ndarray              # int32 scalar — the tick clock
    quiet: jnp.ndarray            # int32 scalar — consecutive quiescent ticks
    stage_ring: object = None     # hybrid-parallel in-flight inter-stage
                                  # outboxes, packed f32 [S, R, D*C, W_fb]
                                  # (None on 1-D meshes: the field flattens
                                  # to zero leaves and the carry pytree is
                                  # unchanged from the stage-free program)
    train: object = None          # training-plane TrainState
                                  # (core/train_plane.py) — None when
                                  # cfg.train_cap == 0: zero leaves, the
                                  # fifth plane compiles away and the
                                  # carry pytree matches the prior program


for _cls, _df in (
    (TopoState, ["e_src_slot", "e_dst_slot", "e_dst_mpart", "e_dst_mslot",
                 "e_valid", "r_master_slot", "r_rep_part", "r_rep_slot",
                 "r_valid", "v_exists", "is_master", "m_part", "m_slot"]),
    (LayerState, ["feat", "has_feat", "x_sent", "has_sent", "agg", "agg_cnt",
                  "red_pending", "red_deadline", "fwd_pending", "fwd_deadline",
                  "cms", "last_touch", "bc_defer", "bc_defer_ok",
                  "rmi_defer", "rmi_defer_ok"]),
    (PipelineCarry, ["topo", "layers", "sink", "sink_seen", "queries",
                     "now", "quiet", "stage_ring", "train"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_df, meta_fields=[])


def init_topo(n_parts: int, edge_cap: int, repl_cap: int,
              node_cap: int) -> TopoState:
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    zb = lambda *s: jnp.zeros(s, bool)
    return TopoState(
        e_src_slot=zi(n_parts, edge_cap), e_dst_slot=zi(n_parts, edge_cap),
        e_dst_mpart=zi(n_parts, edge_cap), e_dst_mslot=zi(n_parts, edge_cap),
        e_valid=zb(n_parts, edge_cap),
        r_master_slot=zi(n_parts, repl_cap), r_rep_part=zi(n_parts, repl_cap),
        r_rep_slot=zi(n_parts, repl_cap), r_valid=zb(n_parts, repl_cap),
        v_exists=zb(n_parts, node_cap), is_master=zb(n_parts, node_cap),
        m_part=jnp.full((n_parts, node_cap), -1, jnp.int32),
        m_slot=jnp.full((n_parts, node_cap), -1, jnp.int32))


def init_layer(n_parts: int, node_cap: int, d_in: int, d_agg: int,
               cms_depth: int = 4, cms_width: int = 2048,
               bc_defer_rows: int = 0, rmi_defer_rows: int = 0) -> LayerState:
    """bc/rmi_defer_rows are the GLOBAL (n_devices * per-device) defer-ring
    row counts for the routing plane's backpressure path — 0 (the dense
    default and the only valid value off-mesh) compiles it away. The wire
    row width is the lane's MsgBatch packed width: d + 5 scalar columns
    (part, slot, cnt, src_part, valid), see dist/wire.py."""
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    zb = lambda *s: jnp.zeros(s, bool)
    w_b, w_r = d_in + 5, d_agg + 5
    return LayerState(
        feat=zf(n_parts, node_cap, d_in), has_feat=zb(n_parts, node_cap),
        x_sent=zf(n_parts, node_cap, d_in), has_sent=zb(n_parts, node_cap),
        agg=zf(n_parts, node_cap, d_agg), agg_cnt=zf(n_parts, node_cap),
        red_pending=zb(n_parts, node_cap), red_deadline=zi(n_parts, node_cap),
        fwd_pending=zb(n_parts, node_cap), fwd_deadline=zi(n_parts, node_cap),
        cms=zf(cms_depth, cms_width), last_touch=zi(n_parts, node_cap),
        bc_defer=zf(bc_defer_rows, w_b), bc_defer_ok=zb(bc_defer_rows),
        rmi_defer=zf(rmi_defer_rows, w_r), rmi_defer_ok=zb(rmi_defer_rows))


def defer_occupancy(ls: LayerState):
    """Exact occupied-slot counts of a layer's routing defer rings as
    (broadcast_rows, rmi_rows) int scalars — the oracle the telemetry
    plane's `occ_bc_defer`/`occ_rmi_defer` gauges must reproduce
    (ISSUE 9). Works on host numpy arrays and device arrays alike."""
    return (jnp.sum(jnp.asarray(ls.bc_defer_ok).astype(jnp.int32)),
            jnp.sum(jnp.asarray(ls.rmi_defer_ok).astype(jnp.int32)))


def apply_edge_batch(topo: TopoState, eb, part0=0) -> TopoState:
    """Scatter new edge records into the (local block of the) adjacency
    tables; records addressed to non-local parts are dropped."""
    P, E = topo.e_src_slot.shape
    flat = lambda a: a.reshape(P * E)
    idx, _ = local_index(eb.part, eb.edge_slot, part0, P, E, eb.valid)

    def scat(dst, val):
        return flat(dst).at[idx].set(val, mode="drop").reshape(P, E)

    from dataclasses import replace as _replace
    return _replace(
        topo,
        e_src_slot=scat(topo.e_src_slot, eb.src_slot),
        e_dst_slot=scat(topo.e_dst_slot, eb.dst_slot),
        e_dst_mpart=scat(topo.e_dst_mpart, eb.dst_master_part),
        e_dst_mslot=scat(topo.e_dst_mslot, eb.dst_master_slot),
        e_valid=scat(topo.e_valid, eb.valid))


def apply_repl_batch(topo: TopoState, rb, part0=0) -> TopoState:
    P, R = topo.r_master_slot.shape
    flat = lambda a: a.reshape(P * R)
    idx, _ = local_index(rb.part, rb.repl_slot, part0, P, R, rb.valid)

    def scat(dst, val):
        return flat(dst).at[idx].set(val, mode="drop").reshape(P, R)

    # mirror fill: the REPLICA row (possibly on another device's block)
    # learns its master coordinate — a separate node-table scatter, since
    # the record itself lives in the master's replication table
    N = topo.v_exists.shape[1]
    ridx, _ = local_index(rb.rep_part, rb.rep_slot, part0, P, N, rb.valid)
    m_part = topo.m_part.reshape(P * N).at[ridx].set(
        rb.part, mode="drop").reshape(P, N)
    m_slot = topo.m_slot.reshape(P * N).at[ridx].set(
        rb.master_slot, mode="drop").reshape(P, N)

    from dataclasses import replace as _replace
    return _replace(
        topo,
        r_master_slot=scat(topo.r_master_slot, rb.master_slot),
        r_rep_part=scat(topo.r_rep_part, rb.rep_part),
        r_rep_slot=scat(topo.r_rep_slot, rb.rep_slot),
        r_valid=scat(topo.r_valid, rb.valid),
        m_part=m_part, m_slot=m_slot)


def apply_vertex_batch(topo: TopoState, vb, part0=0) -> TopoState:
    from dataclasses import replace as _replace
    P, N = topo.v_exists.shape
    idx, _ = local_index(vb.part, vb.slot, part0, P, N, vb.valid)
    v_exists = topo.v_exists.reshape(P * N).at[idx].set(
        True, mode="drop").reshape(P, N)
    is_master = topo.is_master.reshape(P * N).at[idx].max(
        vb.is_master, mode="drop").reshape(P, N)
    # mirror fill: a master row's master coordinate is itself
    idx_m, _ = local_index(vb.part, vb.slot, part0, P, N,
                           vb.valid & vb.is_master)
    m_part = topo.m_part.reshape(P * N).at[idx_m].set(
        vb.part, mode="drop").reshape(P, N)
    m_slot = topo.m_slot.reshape(P * N).at[idx_m].set(
        vb.slot, mode="drop").reshape(P, N)
    return _replace(topo, v_exists=v_exists, is_master=is_master,
                    m_part=m_part, m_slot=m_slot)
