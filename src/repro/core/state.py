"""Device-side operator state (the paper's Graph Storage, §4.1/§5.2).

All arrays are [P, cap, ...] — P logical parts stacked on the leading axis.
On one device the tick processes all parts with flat indexing; on the
production mesh the P axis is sharded over ("data",) (and "pod") and the
routing segment-sums become all_to_all + local scatters (repro/dist).

Topology is stored once and shared by all layer operators (the paper ships
the same topology events to every GraphStorage; storing it once per job is
an optimization with identical semantics — DESIGN §2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TopoState:
    """Shared adjacency + replication tables."""
    # out-edge records, stored in the part the edge was assigned to
    e_src_slot: jnp.ndarray       # [P, E] int32 local slot of u
    e_dst_slot: jnp.ndarray       # [P, E] int32 local slot of v (same part)
    e_dst_mpart: jnp.ndarray      # [P, E] int32 master part of v
    e_dst_mslot: jnp.ndarray      # [P, E] int32 master slot of v
    e_valid: jnp.ndarray          # [P, E] bool
    # replication records, stored in the master's part
    r_master_slot: jnp.ndarray    # [P, R] int32
    r_rep_part: jnp.ndarray       # [P, R] int32
    r_rep_slot: jnp.ndarray       # [P, R] int32
    r_valid: jnp.ndarray          # [P, R] bool
    # vertex flags
    v_exists: jnp.ndarray         # [P, N] bool
    is_master: jnp.ndarray        # [P, N] bool

    @property
    def n_parts(self):
        return self.e_src_slot.shape[0]

    @property
    def edge_cap(self):
        return self.e_src_slot.shape[1]


@dataclass(frozen=True)
class LayerState:
    """Per-GNN-layer feature/aggregator state (one per GraphStorage op)."""
    feat: jnp.ndarray             # [P, N, d_in] layer-input features (replicas too)
    has_feat: jnp.ndarray        # [P, N] bool
    x_sent: jnp.ndarray           # [P, N, d_in] feature value last pushed into aggs
    has_sent: jnp.ndarray         # [P, N] bool
    agg: jnp.ndarray              # [P, N, d_agg] synopsis value (masters only)
    agg_cnt: jnp.ndarray          # [P, N] float counts
    # windowing state
    red_pending: jnp.ndarray      # [P, N] bool   (inter-layer: delayed reduce)
    red_deadline: jnp.ndarray     # [P, N] int32
    fwd_pending: jnp.ndarray      # [P, N] bool   (intra-layer: delayed forward)
    fwd_deadline: jnp.ndarray     # [P, N] int32
    # adaptive-session state: CountMinSketch of per-vertex update frequency
    cms: jnp.ndarray              # [depth, width] float32
    last_touch: jnp.ndarray       # [P, N] int32

    @property
    def node_cap(self):
        return self.feat.shape[2 - 1]  # [P, N, d] -> N


@dataclass(frozen=True)
class PipelineCarry:
    """Everything the device mutates across micro-ticks, as ONE pytree.

    The super-tick driver threads this through `lax.scan` and donates it at
    the jit boundary (`donate_argnums`), so XLA reuses the topology/layer/
    sink buffers in place instead of allocating a second copy per super-tick.
    Donation-safety is why every field keeps a fixed shape and dtype:
    `now`/`quiet` are int32 device scalars, never Python ints.
    """
    topo: TopoState
    layers: tuple                 # tuple[LayerState, ...] (one per GNN layer)
    sink: jnp.ndarray             # [P, N, d_out] materialized embeddings
    sink_seen: jnp.ndarray        # [P, N] bool
    now: jnp.ndarray              # int32 scalar — the tick clock
    quiet: jnp.ndarray            # int32 scalar — consecutive quiescent ticks


for _cls, _df in (
    (TopoState, ["e_src_slot", "e_dst_slot", "e_dst_mpart", "e_dst_mslot",
                 "e_valid", "r_master_slot", "r_rep_part", "r_rep_slot",
                 "r_valid", "v_exists", "is_master"]),
    (LayerState, ["feat", "has_feat", "x_sent", "has_sent", "agg", "agg_cnt",
                  "red_pending", "red_deadline", "fwd_pending", "fwd_deadline",
                  "cms", "last_touch"]),
    (PipelineCarry, ["topo", "layers", "sink", "sink_seen", "now", "quiet"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_df, meta_fields=[])


def init_topo(n_parts: int, edge_cap: int, repl_cap: int,
              node_cap: int) -> TopoState:
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    zb = lambda *s: jnp.zeros(s, bool)
    return TopoState(
        e_src_slot=zi(n_parts, edge_cap), e_dst_slot=zi(n_parts, edge_cap),
        e_dst_mpart=zi(n_parts, edge_cap), e_dst_mslot=zi(n_parts, edge_cap),
        e_valid=zb(n_parts, edge_cap),
        r_master_slot=zi(n_parts, repl_cap), r_rep_part=zi(n_parts, repl_cap),
        r_rep_slot=zi(n_parts, repl_cap), r_valid=zb(n_parts, repl_cap),
        v_exists=zb(n_parts, node_cap), is_master=zb(n_parts, node_cap))


def init_layer(n_parts: int, node_cap: int, d_in: int, d_agg: int,
               cms_depth: int = 4, cms_width: int = 2048) -> LayerState:
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    zb = lambda *s: jnp.zeros(s, bool)
    return LayerState(
        feat=zf(n_parts, node_cap, d_in), has_feat=zb(n_parts, node_cap),
        x_sent=zf(n_parts, node_cap, d_in), has_sent=zb(n_parts, node_cap),
        agg=zf(n_parts, node_cap, d_agg), agg_cnt=zf(n_parts, node_cap),
        red_pending=zb(n_parts, node_cap), red_deadline=zi(n_parts, node_cap),
        fwd_pending=zb(n_parts, node_cap), fwd_deadline=zi(n_parts, node_cap),
        cms=zf(cms_depth, cms_width), last_touch=zi(n_parts, node_cap))


def apply_edge_batch(topo: TopoState, eb) -> TopoState:
    """Scatter new edge records into the adjacency tables."""
    P, E = topo.e_src_slot.shape
    flat = lambda a: a.reshape(P * E)
    idx = eb.part * E + eb.edge_slot
    idx = jnp.where(eb.valid, idx, P * E)          # OOB drop for padding

    def scat(dst, val):
        return flat(dst).at[idx].set(val, mode="drop").reshape(P, E)

    from dataclasses import replace as _replace
    return _replace(
        topo,
        e_src_slot=scat(topo.e_src_slot, eb.src_slot),
        e_dst_slot=scat(topo.e_dst_slot, eb.dst_slot),
        e_dst_mpart=scat(topo.e_dst_mpart, eb.dst_master_part),
        e_dst_mslot=scat(topo.e_dst_mslot, eb.dst_master_slot),
        e_valid=scat(topo.e_valid, eb.valid))


def apply_repl_batch(topo: TopoState, rb) -> TopoState:
    P, R = topo.r_master_slot.shape
    flat = lambda a: a.reshape(P * R)
    idx = rb.part * R + rb.repl_slot
    idx = jnp.where(rb.valid, idx, P * R)

    def scat(dst, val):
        return flat(dst).at[idx].set(val, mode="drop").reshape(P, R)

    from dataclasses import replace as _replace
    return _replace(
        topo,
        r_master_slot=scat(topo.r_master_slot, rb.master_slot),
        r_rep_part=scat(topo.r_rep_part, rb.rep_part),
        r_rep_slot=scat(topo.r_rep_slot, rb.rep_slot),
        r_valid=scat(topo.r_valid, rb.valid))


def apply_vertex_batch(topo: TopoState, vb) -> TopoState:
    from dataclasses import replace as _replace
    P, N = topo.v_exists.shape
    idx = vb.part * N + vb.slot
    idx = jnp.where(vb.valid, idx, P * N)
    v_exists = topo.v_exists.reshape(P * N).at[idx].set(
        True, mode="drop").reshape(P, N)
    is_master = topo.is_master.reshape(P * N).at[idx].max(
        vb.is_master, mode="drop").reshape(P, N)
    return _replace(topo, v_exists=v_exists, is_master=is_master)
