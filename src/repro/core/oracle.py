"""Static full-graph oracle for exactness tests.

The paper: "D3-GNN and its streaming incremental aggregators produce the
same embeddings as those from a static model executed on the equivalent
final graph snapshot". This module builds that snapshot from the raw event
log and runs the same model statically — tests assert allclose between the
pipeline sink and this oracle.

Edges form a multiset (duplicates count), matching the engine's aggregator
counts. Only vertices whose features were streamed contribute messages.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graph.graphs import Graph


def build_snapshot(edges: np.ndarray, feats: dict, d_in: int,
                   n_nodes: int) -> tuple[Graph, np.ndarray]:
    """Graph from the final event log + which nodes have features."""
    x = np.zeros((n_nodes, d_in), np.float32)
    has = np.zeros(n_nodes, bool)
    for vid, vec in feats.items():
        x[vid] = vec
        has[vid] = True
    # only featured sources emit messages (msgReady gating)
    emask = has[edges[:, 0]]
    g = Graph(senders=jnp.asarray(edges[:, 0], jnp.int32),
              receivers=jnp.asarray(edges[:, 1], jnp.int32),
              x=jnp.asarray(x), edge_mask=jnp.asarray(emask),
              node_mask=jnp.asarray(has))
    return g, has


def oracle_embeddings(model, params, g: Graph) -> jnp.ndarray:
    """Static forward of the same layer stack on the snapshot."""
    x = g.x
    for i, layer in enumerate(model.layers):
        x = layer(params[f"l{i}"], g, x)
    return x
