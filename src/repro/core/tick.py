"""The per-layer micro-tick: streaming (Alg. 1) and windowed (Alg. 2)
forward pass as one pure jitted function.

One tick = two routing rounds (DESIGN §2):

  Round A (replication): master-addressed feature updates land, then
      selectiveBroadcast pushes them to replicas via the replication
      adjacency. Cross-part — all_to_all on the mesh, scatter on 1 device.
  Round B (reduce): per-vertex feature *deltas* are turned into aggregator
      RMIs over out-edges and routed to destination masters. reduce /
      replace / remove all collapse to additive (delta, dcnt) records
      (core/aggregators.py), so a single segment-sum applies any mix.

Windowing replaces "emit now" with deadline tables:
  inter-layer window -> delays the reduce of a source vertex (red_*),
  intra-layer window -> delays the forward/psi-emission of a master (fwd_*).

Counts follow Algorithm 1 exactly:
  addElement(e)   : contributes (x_sent[u], +1) iff u has already sent
  addElement(u.f) : first send emits (x_u, +1) over ALL out-edges
  updateElement   : emits (x_new - x_sent, 0) over all out-edges
so an aggregator count equals the number of in-edges whose source feature
has been seen — identical to the static oracle's in-degree once quiescent.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import windowing as win
from repro.core.aggregators import mean_read
from repro.core.events import EdgeBatch, FeatBatch, ReplBatch
from repro.core.state import LayerState, TopoState


@dataclass(frozen=True)
class TickStats:
    broadcast_msgs: jnp.ndarray      # round-A replica messages
    reduce_msgs: jnp.ndarray         # round-B aggregator RMIs routed
    cross_part_msgs: jnp.ndarray     # messages leaving their part ("network")
    emitted: jnp.ndarray             # forward emissions to the next layer
    dropped: jnp.ndarray             # emissions deferred by outbox capacity
    busy: jnp.ndarray                # [P] per-part processed-event proxy


jax.tree_util.register_dataclass(
    TickStats, data_fields=["broadcast_msgs", "reduce_msgs",
                            "cross_part_msgs", "emitted", "dropped", "busy"],
    meta_fields=[])


def zero_stats(n_parts: int) -> TickStats:
    """Additive identity for TickStats — the summed carry of the super-tick
    scan starts here; dtypes must match what the tick body emits (int32 on
    the default 32-bit jnp) or the scan carry would be ill-typed."""
    z = jnp.zeros((), jnp.int32)
    return TickStats(broadcast_msgs=z, reduce_msgs=z, cross_part_msgs=z,
                     emitted=z, dropped=z,
                     busy=jnp.zeros((n_parts,), jnp.int32))


def add_stats(a: TickStats, b: TickStats) -> TickStats:
    return jax.tree.map(jnp.add, a, b)


def _flat(part, slot, N):
    return part * N + slot


def layer_tick_body(layer, params, topo: TopoState, ls: LayerState,
                    inbox: FeatBatch, new_edges: EdgeBatch,
                    new_repl: ReplBatch, now: jnp.ndarray,
                    wconf: win.WindowConfig, outbox_cap: int):
    """Advance one GNN layer by one tick (pure, trace-friendly).

    `layer` supplies message/update (phi/psi): layer.message(params, x) and
    layer.update(params, x_self, agg_read) — e.g. graph/sage.SAGELayer.
    Returns (new LayerState, outbox FeatBatch, TickStats).

    This is the un-jitted body so the super-tick driver can inline all L
    layers inside one `lax.scan` step; the per-tick reference path wraps it
    in `layer_tick` below.
    """
    P, N, d_in = ls.feat.shape
    busy = jnp.zeros((P,), jnp.int32)

    # ---------------- Round A: apply inbox at masters, broadcast to replicas
    in_idx = jnp.where(inbox.valid, _flat(inbox.part, inbox.slot, N), P * N)
    feat_flat = ls.feat.reshape(P * N, d_in)
    # coalesce duplicate targets within the tick: last-writer-wins is fine
    # for idempotent feature values; use scatter (later rows overwrite).
    feat_flat = feat_flat.at[in_idx].set(inbox.feat, mode="drop")
    changed = jnp.zeros((P * N,), bool).at[in_idx].set(True, mode="drop")
    has_feat = ls.has_feat.reshape(P * N).at[in_idx].set(True, mode="drop")
    busy = busy.at[inbox.part].add(inbox.valid.astype(jnp.int32), mode="drop")

    # replica-creation sync: a NEW replica immediately receives its master's
    # current state (the paper replicates state on placement, §5.1) — mark
    # the master "changed" so the normal broadcast below covers the new
    # record; only the new record fires because older replicas already hold
    # the value (idempotent re-set, coalesced by the same scatter).
    nr_midx = _flat(new_repl.part, new_repl.master_slot, N)
    nr_push = new_repl.valid & has_feat[nr_midx]
    changed = changed.at[jnp.where(nr_push, nr_midx, P * N)].set(
        True, mode="drop")

    # broadcast: replication records whose master changed this tick
    r_midx = _flat(jnp.arange(P)[:, None], topo.r_master_slot, N)   # [P,R]
    r_live = topo.r_valid & changed[r_midx]
    r_tgt = jnp.where(r_live, _flat(topo.r_rep_part, topo.r_rep_slot, N), P * N)
    r_val = feat_flat[r_midx.reshape(-1)]
    feat_flat = feat_flat.at[r_tgt.reshape(-1)].set(
        jnp.where(r_live.reshape(-1)[:, None], r_val, 0.0), mode="drop")
    # NOTE .set with masked rows: invalid rows point to OOB (dropped)
    changed = changed.at[jnp.where(r_live, r_tgt, P * N).reshape(-1)].set(
        True, mode="drop")
    has_feat = has_feat.at[jnp.where(r_live, r_tgt, P * N).reshape(-1)].set(
        True, mode="drop")
    n_bcast = jnp.sum(r_live)
    bcast_cross = jnp.sum(r_live & (topo.r_rep_part != jnp.arange(P)[:, None]))
    busy = busy.at[topo.r_rep_part].add(r_live.astype(jnp.int32), mode="drop")

    # ---------------- Round B(1): new-edge RMIs  (addElement(e), Alg. 1)
    x_sent_flat = ls.x_sent.reshape(P * N, d_in)
    has_sent = ls.has_sent.reshape(P * N)
    e_sidx = _flat(new_edges.part, new_edges.src_slot, N)
    e_ready = new_edges.valid & has_sent[e_sidx]                 # msgReady
    e_msg = layer.message(params, x_sent_flat[e_sidx])
    d_agg = e_msg.shape[-1]
    e_tgt = jnp.where(e_ready,
                      _flat(new_edges.dst_master_part, new_edges.dst_master_slot, N),
                      P * N)
    busy = busy.at[new_edges.part].add(new_edges.valid.astype(jnp.int32),
                                       mode="drop")

    # ---------------- Round B(2): per-vertex reduce/replace deltas
    # decide which touched vertices send this tick (window policy)
    freq = win.cms_query(ls.cms, jnp.arange(P * N)) if wconf.kind == win.ADAPTIVE \
        else jnp.zeros((P * N,), jnp.float32)
    red_pending = ls.red_pending.reshape(P * N) | changed
    red_deadline = ls.red_deadline.reshape(P * N)
    touched_deadline = win.next_deadline(
        wconf, now, red_deadline, ls.red_pending.reshape(P * N), freq)
    red_deadline = jnp.where(changed, touched_deadline, red_deadline)
    # STREAMING evicts everything pending (incl. deadlines scheduled by a
    # previous windowed policy — the drain path of flush())
    send = red_pending if wconf.kind == win.STREAMING else \
        red_pending & (red_deadline <= now)
    # sources: delta = phi(x) - phi(x_sent) if has_sent else (phi(x), +1)
    msg_new = layer.message(params, feat_flat)
    msg_old = layer.message(params, x_sent_flat)
    delta_vec = jnp.where(send[:, None],
                          msg_new - jnp.where(has_sent[:, None], msg_old, 0.0),
                          0.0)
    delta_cnt = jnp.where(send, jnp.where(has_sent, 0.0, 1.0), 0.0)

    # per-edge gather of source deltas -> destination masters
    pp = jnp.arange(P)[:, None]
    o_sidx = _flat(pp, topo.e_src_slot, N)                        # [P,E]
    o_live = topo.e_valid & send[o_sidx]
    o_tgt = jnp.where(o_live, _flat(topo.e_dst_mpart, topo.e_dst_mslot, N), P * N)
    o_vec = delta_vec[o_sidx.reshape(-1)]
    o_cnt = delta_cnt[o_sidx.reshape(-1)] * o_live.reshape(-1)

    # ---------------- apply RMIs at masters (one segment scatter-add)
    agg_flat = ls.agg.reshape(P * N, d_agg)
    cnt_flat = ls.agg_cnt.reshape(P * N)
    agg_flat = agg_flat.at[e_tgt].add(
        jnp.where(e_ready[:, None], e_msg, 0.0), mode="drop")
    cnt_flat = cnt_flat.at[e_tgt].add(e_ready.astype(jnp.float32), mode="drop")
    agg_flat = agg_flat.at[o_tgt.reshape(-1)].add(
        jnp.where(o_live.reshape(-1)[:, None], o_vec, 0.0), mode="drop")
    cnt_flat = cnt_flat.at[o_tgt.reshape(-1)].add(o_cnt, mode="drop")
    agg_dirty = jnp.zeros((P * N,), bool)
    agg_dirty = agg_dirty.at[e_tgt].set(e_ready, mode="drop")
    agg_dirty = agg_dirty.at[o_tgt.reshape(-1)].max(o_live.reshape(-1), mode="drop")

    n_reduce = jnp.sum(e_ready) + jnp.sum(o_live)
    red_cross = (jnp.sum(e_ready & (new_edges.dst_master_part != new_edges.part))
                 + jnp.sum(o_live & (topo.e_dst_mpart != pp)))
    busy = busy.at[new_edges.dst_master_part].add(e_ready.astype(jnp.int32),
                                                  mode="drop")
    busy = busy.at[topo.e_dst_mpart].add(o_live.astype(jnp.int32), mode="drop")

    # commit send bookkeeping
    x_sent_flat = jnp.where(send[:, None], feat_flat, x_sent_flat)
    has_sent = has_sent | send
    red_pending = red_pending & ~send

    # ---------------- forward/update phase (psi), intra-layer window
    is_m = topo.is_master.reshape(P * N)
    dirty = (agg_dirty | (changed & is_m)) & has_feat & is_m
    fwd_pending = ls.fwd_pending.reshape(P * N) | dirty
    fwd_deadline = ls.fwd_deadline.reshape(P * N)
    fwd_touch_dl = win.next_deadline(
        wconf, now, fwd_deadline, ls.fwd_pending.reshape(P * N), freq)
    fwd_deadline = jnp.where(dirty, fwd_touch_dl, fwd_deadline)
    evict = fwd_pending if wconf.kind == win.STREAMING else \
        fwd_pending & (fwd_deadline <= now)

    # capacity-limited emission: pick the first outbox_cap evicted vertices
    # (rest stay pending -> natural backpressure)
    order = jnp.where(evict, jnp.arange(P * N), P * N)
    k = min(outbox_cap, P * N)
    picked = jax.lax.top_k(-order, k)[0] * -1                     # ascending
    picked_valid = picked < P * N
    picked = jnp.minimum(picked, P * N - 1)
    emitted_mask = jnp.zeros((P * N,), bool).at[picked].set(
        picked_valid, mode="drop")
    deferred = evict & ~emitted_mask
    n_emit = jnp.sum(emitted_mask)
    n_drop = jnp.sum(deferred)

    x_self = feat_flat[picked]
    agg_read = mean_read(agg_flat, cnt_flat)[picked]
    x_out = layer.update(params, x_self, agg_read)
    outbox = FeatBatch(part=(picked // N).astype(jnp.int32),
                       slot=(picked % N).astype(jnp.int32),
                       feat=x_out, valid=picked_valid)
    fwd_pending = fwd_pending & ~emitted_mask
    busy = busy.at[(picked // N)].add(picked_valid.astype(jnp.int32),
                                      mode="drop")

    # ---------------- adaptive-session CMS update
    cms = ls.cms
    if wconf.kind == win.ADAPTIVE:
        touch_keys = jnp.where(changed, jnp.arange(P * N), 0)
        cms = win.cms_update(cms, touch_keys, changed.astype(jnp.float32),
                             decay=wconf.cms_decay)

    new_ls = LayerState(
        feat=feat_flat.reshape(P, N, d_in), has_feat=has_feat.reshape(P, N),
        x_sent=x_sent_flat.reshape(P, N, d_in), has_sent=has_sent.reshape(P, N),
        agg=agg_flat.reshape(P, N, d_agg), agg_cnt=cnt_flat.reshape(P, N),
        red_pending=red_pending.reshape(P, N),
        red_deadline=red_deadline.reshape(P, N),
        fwd_pending=fwd_pending.reshape(P, N),
        fwd_deadline=fwd_deadline.reshape(P, N),
        cms=cms, last_touch=jnp.where(changed, now, ls.last_touch.reshape(P * N)
                                      ).reshape(P, N))
    stats = TickStats(broadcast_msgs=n_bcast, reduce_msgs=n_reduce,
                      cross_part_msgs=bcast_cross + red_cross,
                      emitted=n_emit, dropped=n_drop, busy=busy)
    return new_ls, outbox, stats


layer_tick = partial(jax.jit, static_argnames=("layer", "wconf",
                                               "outbox_cap"))(layer_tick_body)


def has_work(ls: LayerState) -> jnp.ndarray:
    """Termination-detection predicate: any pending timer or unsent delta."""
    return jnp.any(ls.red_pending) | jnp.any(ls.fwd_pending)
