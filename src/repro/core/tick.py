"""The per-layer micro-tick: streaming (Alg. 1) and windowed (Alg. 2)
forward pass, factored into SIX planes — a part-local COMPUTE plane
(the four stages below, ISSUE 2), an explicit ROUTING plane
(`dist/router.py`), a pluggable DELIVERY plane (`core/delivery.py`,
ISSUE 3) that lands routed records in the local state blocks, a
QUERY plane (`serve/query.py`, ISSUE 4) that answers point queries from
the state the other three maintain — it runs after the layer ticks and
the sink update (see `core/pipeline.py`), reading this module's
red/fwd pending flags as the per-target freshness signal — a
TRAINING plane (`core/train_plane.py`, ISSUE 8) that closes the tick
with a windowed online training step backpropagating through the live
caches the compute plane just refreshed — and a TELEMETRY plane
(`repro/telemetry/`, ISSUE 9) that WATCHES the other five:
`PipelineConfig.telemetry=True` lights up exact per-plane occupancy
counters in TickStats (defer-ring gauges, peak route-bucket demand)
plus a per-tick occupancy row riding the super-tick scan, streamed to
an on-disk trace the capacity advisor replays. The default
(telemetry=False) emits static zeros — the program is bit-for-bit the
five-plane tick.

One tick = two routing rounds (DESIGN §2), four pure stages with a
Router delivery between them:

  round_a_apply : master-addressed feature updates land at local masters
                  (delivery.deliver_set); selectiveBroadcast records for
                  changed masters are EMITTED as a part-addressed
                  `MsgBatch` (not scattered into other parts' rows).
       -- router.route_lanes((bcast,), ...) --
  round_b_emit  : delivered broadcasts apply at local replicas
                  (delivery.deliver_set); per-vertex feature *deltas* and
                  new-edge messages become aggregator RMI records
                  (delta, dcnt) addressed to destination masters.
                  reduce / replace / remove all collapse to additive
                  records (core/aggregators.py).
       -- router.route_lanes((rmis, [query wire]), ...) --
                  each route_lanes call is ONE packed all_to_all (ISSUE 5)
                  with per-destination buckets capped by route_cap;
                  overflow defers into per-lane rings in LayerState
                  (bc_defer/rmi_defer) and re-enters next tick.
  apply_rmis    : ONE delivery (delivery.deliver_add) applies any RMI mix
                  at the local masters — a flat scatter-add on the "xla"
                  backend, a sorted Pallas segment reduction on "pallas".
  forward_psi   : dirty masters run the update (psi) under the intra-layer
                  window and emit into a per-part capacity-limited outbox;
                  the aggregator read goes through delivery.agg_read_rows
                  (fused on "pallas": only the picked rows are divided).

Every stage sees only its LOCAL block of parts ([P_loc, ...], global part
ids offset by `part0`), so the identical body runs on one device
(LocalRouter: part0=0, P_loc=P) and inside a `shard_map` over the mesh
(MeshRouter: part0 = axis_index * P_loc) — on either delivery backend.
Scalar TickStats are reduced through `router.psum`; the per-part `busy`
vector stays local and is concatenated by the shard_map out-spec.

Stage placement (hybrid parallelism, ISSUE 7): on a 2-D ("stage",
"data") mesh this same body also runs unmodified per PIPELINE STAGE —
the L layers are placed round-robin on the stage axis (layer l = round
r * S + s lives on stage s) and `core/pipeline.py:_tick_program_2d`
calls `layer_tick_body` once per ROUND with that stage's slice of the
stacked layer state. The inbox then comes from the inter-stage ring (the
previous stage's last-tick outbox, shipped by `MeshRouter.stage_shift`)
instead of the same-tick output of the previous layer; `router.psum`
still reduces over "data" only, so each stage's TickStats describe ITS
layers and the host unstacks them back into per-layer stats. Quiescence
and consistent-query silence use `router.psum_vote` (both axes) — a
single stage's quiet never terminates the pipeline while another stage
or the ring still holds work.

Windowing replaces "emit now" with deadline tables:
  inter-layer window -> delays the reduce of a source vertex (red_*),
  intra-layer window -> delays the forward/psi-emission of a master (fwd_*).

Counts follow Algorithm 1 exactly:
  addElement(e)   : contributes (x_sent[u], +1) iff u has already sent
  addElement(u.f) : first send emits (x_u, +1) over ALL out-edges
  updateElement   : emits (x_new - x_sent, 0) over all out-edges
so an aggregator count equals the number of in-edges whose source feature
has been seen — identical to the static oracle's in-degree once quiescent.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import aggregators
from repro.core import windowing as win
from repro.core.delivery import XlaDelivery
from repro.core.events import (EdgeBatch, FeatBatch, MsgBatch, ReplBatch,
                               coalesce_msg_batch, concat_msg_batches)
from repro.core.state import LayerState, TopoState, local_index
from repro.dist.router import LocalRouter, add_receipts


@dataclass(frozen=True)
class TickStats:
    broadcast_msgs: jnp.ndarray      # round-A replica messages
    reduce_msgs: jnp.ndarray         # round-B aggregator RMIs routed
    cross_part_msgs: jnp.ndarray     # messages leaving their part ("network")
    emitted: jnp.ndarray             # forward emissions to the next layer
    dropped: jnp.ndarray             # emissions deferred by outbox capacity
    # routing-plane wire telemetry (ISSUE 5) — MEASURED exchange counters,
    # psum'd over the mesh; all zero under LocalRouter / a 1-device mesh.
    # The emission counters above are counted at EMISSION time, so they
    # stay exactly equal across route_cap settings — these count the wire.
    # (Wire BYTES are a compile-time constant per tick and are accounted
    # host-side in exact ints: StreamMetrics.wire_bytes.)
    wire_rows: jnp.ndarray           # live records shipped on all_to_all
    route_deferred: jnp.ndarray      # records pushed to defer rings
    route_dropped: jnp.ndarray       # records lost to a FULL defer ring
    # delta gating (ISSUE 6): out-edge RMIs NOT emitted because the
    # source's cumulative un-sent delta stayed under delta_eps — the
    # message volume the gate saved this tick. Counted at emission time
    # like reduce_msgs (reduce_msgs + n_suppressed is invariant across
    # eps for a fixed send schedule); psum'd over the mesh; always 0 in
    # exact mode (delta_eps=0 compiles the gate away).
    n_suppressed: jnp.ndarray
    # telemetry plane (ISSUE 9) — occupancy gauges, static zeros unless
    # PipelineConfig.telemetry=True (XLA dead-code-eliminates them, so the
    # default program is bit-for-bit the five-plane tick). The defer-ring
    # gauges are END-OF-TICK ring populations (psum'd exact integers);
    # summed over a super-tick they become backlog INTEGRALS (ring-rows x
    # ticks, the same convention as QueryStats.held_ticks). route_peak is
    # the tick's max per-destination route-bucket demand BEFORE capping —
    # the zero-defer route_cap; its scan SUM is meaningless and unused
    # (per-tick values ride the trace's occupancy row instead).
    occ_bc_defer: jnp.ndarray        # rows waiting in broadcast defer rings
    occ_rmi_defer: jnp.ndarray       # rows waiting in RMI defer rings
    route_peak: jnp.ndarray          # peak per-dest bucket demand (pre-cap)
    # outbox_part_peak is the tick's max PER-PART eviction demand before
    # the outbox quota. The outbox cap binds per part (outbox_cap //
    # n_parts slots each, enforced by forward_psi's top_k), so the GLOBAL
    # demand (emitted + dropped) under-sizes the cap whenever demand is
    # skewed across parts — zero-drop needs
    # outbox_cap >= n_parts x outbox_part_peak. pmax'd across devices;
    # like route_peak its scan SUM is meaningless (per-tick values ride
    # the trace's occupancy row).
    outbox_part_peak: jnp.ndarray    # peak per-part outbox demand (pre-cap)
    busy: jnp.ndarray                # [P] per-part processed-event proxy


jax.tree_util.register_dataclass(
    TickStats, data_fields=["broadcast_msgs", "reduce_msgs",
                            "cross_part_msgs", "emitted", "dropped",
                            "wire_rows", "route_deferred",
                            "route_dropped", "n_suppressed",
                            "occ_bc_defer", "occ_rmi_defer",
                            "route_peak", "outbox_part_peak", "busy"],
    meta_fields=[])


def zero_stats(n_parts: int) -> TickStats:
    """Additive identity for TickStats — the summed carry of the super-tick
    scan starts here; dtypes must match what the tick body emits (int32 on
    the default 32-bit jnp) or the scan carry would be ill-typed. Under the
    mesh `n_parts` is the LOCAL part count (busy stays shard-local)."""
    z = jnp.zeros((), jnp.int32)
    return TickStats(broadcast_msgs=z, reduce_msgs=z, cross_part_msgs=z,
                     emitted=z, dropped=z, wire_rows=z,
                     route_deferred=z, route_dropped=z, n_suppressed=z,
                     occ_bc_defer=z, occ_rmi_defer=z, route_peak=z,
                     outbox_part_peak=z,
                     busy=jnp.zeros((n_parts,), jnp.int32))


def add_stats(a: TickStats, b: TickStats) -> TickStats:
    return jax.tree.map(jnp.add, a, b)


# ===================================================== compute-plane stages

def round_a_apply(topo: TopoState, ls: LayerState, inbox: FeatBatch,
                  new_repl: ReplBatch, part0, delivery):
    """Round A, emit half: apply the inbox at LOCAL masters and build the
    broadcast MsgBatch for replication records whose master changed.

    Returns (feat_flat, changed, has_feat, bcast, busy, n_bcast, n_cross)
    — all [P_loc * N]-flat local arrays except the part-addressed bcast.
    """
    P_loc, N, d_in = ls.feat.shape
    busy = jnp.zeros((P_loc,), jnp.int32)

    in_idx, in_lp = local_index(inbox.part, inbox.slot, part0, P_loc, N,
                                inbox.valid)
    feat_flat = ls.feat.reshape(P_loc * N, d_in)
    # coalesce duplicate targets within the tick: last-writer-wins is fine
    # for idempotent feature values (both backends resolve duplicates that
    # way; valid inbox targets are unique anyway).
    feat_flat, changed = delivery.deliver_set(feat_flat, in_idx, inbox.feat)
    has_feat = ls.has_feat.reshape(P_loc * N) | changed
    busy = busy.at[in_lp].add(1, mode="drop")

    # replica-creation sync: a NEW replica immediately receives its master's
    # current state (the paper replicates state on placement, §5.1) — mark
    # the master "changed" so the broadcast below covers the new record;
    # only the new record fires because older replicas already hold the
    # value (idempotent re-set, coalesced by the same scatter).
    nr_idx, _ = local_index(new_repl.part, new_repl.master_slot, part0,
                            P_loc, N, new_repl.valid)
    nr_push = (nr_idx < P_loc * N) & has_feat[jnp.minimum(nr_idx,
                                                          P_loc * N - 1)]
    changed = changed.at[jnp.where(nr_push, nr_idx, P_loc * N)].set(
        True, mode="drop")

    # broadcast emission: replication records whose master changed this tick
    pp = jnp.arange(P_loc)[:, None]
    r_midx = pp * N + topo.r_master_slot                           # [Pl,R]
    r_live = topo.r_valid & changed[r_midx]
    src_part = jnp.broadcast_to(part0 + pp, r_live.shape)
    bcast = MsgBatch(
        part=topo.r_rep_part.reshape(-1),
        slot=topo.r_rep_slot.reshape(-1),
        vec=jnp.where(r_live.reshape(-1)[:, None],
                      feat_flat[r_midx.reshape(-1)], 0.0),
        cnt=jnp.zeros((r_live.size,), jnp.float32),
        src_part=src_part.reshape(-1),
        valid=r_live.reshape(-1))
    n_bcast = jnp.sum(r_live)
    n_cross = jnp.sum(r_live & (topo.r_rep_part != part0 + pp))
    return feat_flat, changed, has_feat, bcast, busy, n_bcast, n_cross


def round_b_emit(layer, params, topo: TopoState, ls: LayerState, feat_flat,
                 changed, has_feat, bcast_d: MsgBatch, new_edges: EdgeBatch,
                 now, wconf: win.WindowConfig, part0, busy, freq, delivery,
                 delta_eps: float = 0.0):
    """Round B, emit half: apply DELIVERED broadcasts at local replicas,
    decide which touched vertices send this tick (inter-layer window), and
    emit the tick's aggregator RMI records.

    delta_eps (static, ISSUE 6): delta-gated incremental propagation. A
    deadline-due vertex that has already sent only re-emits when its
    CUMULATIVE un-sent delta ||phi(x) - phi(x_sent)|| exceeds eps (per
    the layer's aggregator gate, core/aggregators.GATES — MAX/MIN use the
    grow-only monotonic short-circuit instead of the L2 norm). Suppressed
    vertices clear red_pending (they count as QUIET for termination) but
    keep their x_sent, so the residual accumulates and re-gates on the
    next touch: the un-sent delta per vertex is <= eps at every quiescent
    point, which bounds the synopsis error by eps. First sends and
    new-edge RMIs are never gated. delta_eps=0.0 (default) compiles the
    gate away — bit-for-bit the ungated program.

    Returns (feat_flat, changed, has_feat, x_sent_flat, has_sent,
    red_pending, red_deadline, rmis, busy, n_reduce, n_cross, n_supp).
    """
    P_loc, N, d_in = ls.feat.shape

    # delivered broadcasts land at local replicas (set semantics; targets
    # are unique — one master per replica, host-coalesced inbox)
    b_idx, b_lp = local_index(bcast_d.part, bcast_d.slot, part0, P_loc, N,
                              bcast_d.valid)
    feat_flat, b_touched = delivery.deliver_set(feat_flat, b_idx,
                                                bcast_d.vec)
    changed = changed | b_touched
    has_feat = has_feat | b_touched
    busy = busy.at[b_lp].add(1, mode="drop")

    x_sent_flat = ls.x_sent.reshape(P_loc * N, d_in)
    has_sent = ls.has_sent.reshape(P_loc * N)

    # new-edge RMIs (addElement(e), Alg. 1) — emitted by the part that owns
    # the edge record (it holds the source replica's x_sent)
    e_sidx, e_lp = local_index(new_edges.part, new_edges.src_slot, part0,
                               P_loc, N, new_edges.valid)
    e_local = e_sidx < P_loc * N
    e_gather = jnp.minimum(e_sidx, P_loc * N - 1)
    e_ready = e_local & has_sent[e_gather]                       # msgReady
    e_msg = layer.message(params, x_sent_flat[e_gather])
    busy = busy.at[e_lp].add(1, mode="drop")

    # per-vertex reduce/replace deltas under the inter-layer window
    red_pending = ls.red_pending.reshape(P_loc * N) | changed
    red_deadline = ls.red_deadline.reshape(P_loc * N)
    touched_deadline = win.next_deadline(
        wconf, now, red_deadline, ls.red_pending.reshape(P_loc * N), freq)
    red_deadline = jnp.where(changed, touched_deadline, red_deadline)
    # STREAMING evicts everything pending (incl. deadlines scheduled by a
    # previous windowed policy — the drain path of flush())
    cand = red_pending if wconf.kind == win.STREAMING else \
        red_pending & (red_deadline <= now)
    # sources: delta = phi(x) - phi(x_sent) if has_sent else (phi(x), +1)
    msg_new = layer.message(params, feat_flat)
    msg_old = layer.message(params, x_sent_flat)
    if delta_eps > 0.0:
        gate = aggregators.GATES[getattr(layer, "agg_kind", "mean")]
        suppress = cand & has_sent & gate(msg_new, msg_old, delta_eps)
        send = cand & ~suppress
    else:                       # exact mode: the gate is compiled away
        suppress = None
        send = cand
    delta_vec = jnp.where(send[:, None],
                          msg_new - jnp.where(has_sent[:, None], msg_old, 0.0),
                          0.0)
    delta_cnt = jnp.where(send, jnp.where(has_sent, 0.0, 1.0), 0.0)

    # per-edge gather of source deltas -> destination masters
    pp = jnp.arange(P_loc)[:, None]
    o_sidx = pp * N + topo.e_src_slot                            # [Pl,E]
    o_live = topo.e_valid & send[o_sidx]
    o_src_part = jnp.broadcast_to(part0 + pp, o_live.shape)
    e_rmis = MsgBatch(
        part=new_edges.dst_master_part, slot=new_edges.dst_master_slot,
        vec=jnp.where(e_ready[:, None], e_msg, 0.0),
        cnt=e_ready.astype(jnp.float32),
        src_part=new_edges.part, valid=e_ready)
    o_rmis = MsgBatch(
        part=topo.e_dst_mpart.reshape(-1), slot=topo.e_dst_mslot.reshape(-1),
        vec=jnp.where(o_live.reshape(-1)[:, None],
                      delta_vec[o_sidx.reshape(-1)], 0.0),
        cnt=delta_cnt[o_sidx.reshape(-1)] * o_live.reshape(-1),
        src_part=o_src_part.reshape(-1), valid=o_live.reshape(-1))
    rmis = concat_msg_batches(e_rmis, o_rmis)
    n_reduce = jnp.sum(e_ready) + jnp.sum(o_live)
    n_cross = (jnp.sum(e_ready
                       & (new_edges.dst_master_part != new_edges.part))
               + jnp.sum(o_live & (topo.e_dst_mpart != part0 + pp)))

    # commit send bookkeeping; suppressed vertices leave the pending set
    # WITHOUT advancing x_sent — the residual delta stays accumulated
    # against the last value actually emitted, so a later touch re-gates
    # the cumulative delta (and quiescence sees a quiet vertex meanwhile)
    x_sent_flat = jnp.where(send[:, None], feat_flat, x_sent_flat)
    has_sent = has_sent | send
    if suppress is None:
        red_pending = red_pending & ~send
        n_supp = jnp.zeros((), jnp.int32)
    else:
        red_pending = red_pending & ~send & ~suppress
        # saved message volume = the out-edge RMIs the gate skipped
        n_supp = jnp.sum(topo.e_valid & suppress[o_sidx])
    return (feat_flat, changed, has_feat, x_sent_flat, has_sent,
            red_pending, red_deadline, rmis, busy, n_reduce, n_cross,
            n_supp)


def canon_msg_batch(b: MsgBatch, part0, P_loc: int, N: int,
                    n_parts: int) -> MsgBatch:
    """Deterministic delivery (ISSUE 10): reorder a DELIVERED additive
    batch into the canonical (local destination index, source part) order
    with a stable sort.

    The all_to_all concatenates arrivals by SOURCE DEVICE, so the order
    in which two records from different shards reach the same aggregator
    depends on the device count — the one place the mesh program's f32
    sums depend on D. Rows from the SAME source part always arrive in
    that part's emission order (route_pack and the defer rings are
    order-preserving), so a stable sort keyed by
    (dst_idx * n_parts + src_part) is a TOTAL canonical order: uncapped
    mesh runs become bit-equal across any device count, which is what
    lets a live reshard (D -> D') be verified against the uninterrupted
    run with assert_array_equal rather than allclose. Invalid rows carry
    the one-past-the-end sentinel index and sort to the back.

    Key fits int32 for any realistic config (P_loc * N * n_parts < 2^31).
    """
    idx, _ = local_index(b.part, b.slot, part0, P_loc, N, b.valid)
    key = idx * jnp.int32(n_parts) + jnp.clip(b.src_part, 0, n_parts - 1)
    order = jnp.argsort(key, stable=True)
    return MsgBatch(part=b.part[order], slot=b.slot[order],
                    vec=b.vec[order], cnt=b.cnt[order],
                    src_part=b.src_part[order], valid=b.valid[order])


def apply_rmis(ls: LayerState, rmis_d: MsgBatch, part0, busy, delivery):
    """Apply DELIVERED aggregator RMIs at local masters: one delivery
    regardless of the reduce/replace/remove mix (flat scatter-add on
    "xla", sorted segment reduction on "pallas").

    Returns (agg_flat, cnt_flat, agg_dirty, busy)."""
    P_loc, N, d_agg = ls.agg.shape
    idx, lp = local_index(rmis_d.part, rmis_d.slot, part0, P_loc, N,
                          rmis_d.valid)
    agg_flat, cnt_flat, agg_dirty = delivery.deliver_add(
        ls.agg.reshape(P_loc * N, d_agg), ls.agg_cnt.reshape(P_loc * N),
        idx, rmis_d.vec, rmis_d.cnt)
    busy = busy.at[lp].add(1, mode="drop")
    return agg_flat, cnt_flat, agg_dirty, busy


def forward_psi(layer, params, topo: TopoState, ls: LayerState, feat_flat,
                has_feat, agg_flat, cnt_flat, agg_dirty, changed, now,
                wconf: win.WindowConfig, outbox_cap_pp: int, part0, busy,
                freq, delivery):
    """Forward/update phase (psi) under the intra-layer window, with a
    PER-PART capacity-limited outbox (first `outbox_cap_pp` evicted slots
    per part emit; the rest stay pending -> natural backpressure).

    Returns (fwd_pending, fwd_deadline, outbox, busy, n_emit, n_drop,
    n_demand_pp) — n_demand_pp is the max per-part eviction demand
    BEFORE the quota (the zero-drop per-part outbox size; DCE'd by XLA
    when the telemetry plane is off)."""
    P_loc, N, _ = ls.feat.shape
    is_m = topo.is_master.reshape(P_loc * N)
    dirty = (agg_dirty | (changed & is_m)) & has_feat & is_m
    fwd_pending = ls.fwd_pending.reshape(P_loc * N) | dirty
    fwd_deadline = ls.fwd_deadline.reshape(P_loc * N)
    fwd_touch_dl = win.next_deadline(
        wconf, now, fwd_deadline, ls.fwd_pending.reshape(P_loc * N), freq)
    fwd_deadline = jnp.where(dirty, fwd_touch_dl, fwd_deadline)
    evict = fwd_pending if wconf.kind == win.STREAMING else \
        fwd_pending & (fwd_deadline <= now)

    n_demand_pp = jnp.max(jnp.sum(evict.reshape(P_loc, N), axis=1,
                                  dtype=jnp.int32))
    order = jnp.where(evict.reshape(P_loc, N),
                      jnp.arange(N)[None, :], N)                # [Pl,N]
    k = max(1, min(outbox_cap_pp, N))
    picked = jax.lax.top_k(-order, k)[0] * -1                   # ascending
    picked_valid = picked < N                                   # [Pl,k]
    picked = jnp.minimum(picked, N - 1)
    flat_picked = (jnp.arange(P_loc)[:, None] * N + picked).reshape(-1)
    # invalid picks go to the OOB sentinel, NOT clamped onto slot N-1: a
    # duplicate-index scatter-set of (True, False) can resolve to False
    # and silently erase the emission (fwd_pending then never clears)
    mask_idx = jnp.where(picked_valid.reshape(-1), flat_picked, P_loc * N)
    emitted_mask = jnp.zeros((P_loc * N,), bool).at[mask_idx].set(
        True, mode="drop")
    deferred = evict & ~emitted_mask
    n_emit = jnp.sum(emitted_mask)
    n_drop = jnp.sum(deferred)

    x_self = feat_flat[flat_picked]
    agg_read = delivery.agg_read_rows(agg_flat, cnt_flat, flat_picked)
    x_out = layer.update(params, x_self, agg_read)
    out_part = jnp.broadcast_to(part0 + jnp.arange(P_loc)[:, None],
                                picked.shape)
    outbox = FeatBatch(part=out_part.reshape(-1).astype(jnp.int32),
                       slot=picked.reshape(-1).astype(jnp.int32),
                       feat=x_out, valid=picked_valid.reshape(-1))
    fwd_pending = fwd_pending & ~emitted_mask
    busy = busy + jnp.sum(picked_valid, axis=1, dtype=jnp.int32)
    return (fwd_pending, fwd_deadline, outbox, busy, n_emit, n_drop,
            n_demand_pp)


# ======================================================== the full tick body

def layer_tick_body(layer, params, topo: TopoState, ls: LayerState,
                    inbox: FeatBatch, new_edges: EdgeBatch,
                    new_repl: ReplBatch, now: jnp.ndarray,
                    wconf: win.WindowConfig, outbox_cap: int, router=None,
                    delivery=None, extra_lane=None, delta_eps: float = 0.0,
                    telemetry: bool = False):
    """Advance one GNN layer by one tick (pure, trace-friendly).

    `layer` supplies message/update (phi/psi): layer.message(params, x) and
    layer.update(params, x_self, agg_read) — e.g. graph/sage.SAGELayer.
    `router` owns cross-part transport (default: LocalRouter over the full
    part axis); `delivery` owns how routed records land in state (default:
    the XLA scatter reference, see core/delivery.py). `outbox_cap` is the
    GLOBAL per-tick emission budget; each part gets outbox_cap //
    router.n_parts slots.

    extra_lane: optional (batch, (defer_rows, defer_ok)) — one extra
    part-addressed lane FUSED into this layer's round-B exchange (same
    all_to_all launch; ISSUE 5 lane fusion). The pipeline rides the query
    plane's link-score wire on layer 0 this way.

    telemetry (static, ISSUE 9): when True the TickStats occupancy gauges
    (occ_bc_defer / occ_rmi_defer / route_peak) carry exact measured
    integers; when False (default) they are static zeros and XLA compiles
    the gauge arithmetic away — bit-for-bit the untraced tick.

    delta_eps (static): delta-gated propagation (ISSUE 6, see
    round_b_emit). In approximate mode (> 0) the tick additionally
    COALESCES same-destination RMI records before the routing plane, so
    a hub master that many gated sources touch in one tick receives one
    pre-summed record — fewer live rows through the capped all_to_all
    and the defer rings (coalescing reorders f32 sums, which is why the
    exact eps=0 program skips it and stays bit-identical to PR 5).

    Returns (new LayerState, outbox FeatBatch, TickStats, extra_out) —
    stats scalars are router.psum'd (global), `busy` stays local [P_loc];
    extra_out is None, or (delivered extra lane, its new defer ring).

    This is the un-jitted body so the super-tick driver can inline all L
    layers inside one `lax.scan` step (and the mesh path can wrap the whole
    program in one `shard_map`); the per-tick reference path wraps it in
    `layer_tick` below.
    """
    if router is None:
        router = LocalRouter(n_parts=ls.feat.shape[0])
    if delivery is None:
        delivery = XlaDelivery()
    part0 = router.part0()
    P_loc, N, d_in = ls.feat.shape
    cap_pp = max(1, outbox_cap // router.n_parts)

    keys = part0 * N + jnp.arange(P_loc * N)          # global CMS keys
    freq = win.cms_query(ls.cms, keys) if wconf.kind == win.ADAPTIVE \
        else jnp.zeros((P_loc * N,), jnp.float32)

    # ---- Round A: apply inbox at masters, emit + route the broadcast
    (feat_flat, changed, has_feat, bcast, busy,
     n_bcast, bcast_cross) = round_a_apply(topo, ls, inbox, new_repl, part0,
                                           delivery)
    (bcast_d,), (bc_defer,), rcpt = router.route_lanes(
        (bcast,), ((ls.bc_defer, ls.bc_defer_ok),))

    # ---- Round B: apply broadcast at replicas, emit + route the RMIs
    # (the optional extra lane shares this exchange's single all_to_all)
    (feat_flat, changed, has_feat, x_sent_flat, has_sent, red_pending,
     red_deadline, rmis, busy, n_reduce, red_cross, n_supp) = round_b_emit(
        layer, params, topo, ls, feat_flat, changed, has_feat, bcast_d,
        new_edges, now, wconf, part0, busy, freq, delivery,
        delta_eps=delta_eps)
    if delta_eps > 0.0:
        # approximate mode only: coalesce same-destination additive RMIs
        # before the outbox/routing plane (stats above counted pre-coalesce)
        rmis = coalesce_msg_batch(rmis, N)
    rmi_defer_in = (ls.rmi_defer, ls.rmi_defer_ok)
    if extra_lane is None:
        (rmis_d,), (rmi_defer,), rcpt_b = router.route_lanes(
            (rmis,), (rmi_defer_in,))
        extra_out = None
    else:
        xbatch, xdefer = extra_lane
        (rmis_d, extra_d), (rmi_defer, xdefer_new), rcpt_b = \
            router.route_lanes((rmis, xbatch), (rmi_defer_in, xdefer))
        extra_out = (extra_d, xdefer_new)
    rcpt = add_receipts(rcpt, rcpt_b)

    # ---- apply RMIs at local masters (canonical order first: the additive
    # scatter is the one delivery whose f32 result depends on arrival
    # order, and arrival order is the one thing that depends on D)
    rmis_d = canon_msg_batch(rmis_d, part0, P_loc, N, router.n_parts)
    agg_flat, cnt_flat, agg_dirty, busy = apply_rmis(ls, rmis_d, part0,
                                                     busy, delivery)

    # ---- forward/update phase (psi), intra-layer window
    (fwd_pending, fwd_deadline, outbox, busy,
     n_emit, n_drop, n_demand_pp) = forward_psi(
        layer, params, topo, ls, feat_flat, has_feat, agg_flat, cnt_flat,
        agg_dirty, changed, now, wconf, cap_pp, part0, busy, freq, delivery)

    # ---- adaptive-session CMS update (sketch replicated across devices:
    # local contributions are psum'd so every device applies the same add)
    cms = ls.cms
    if wconf.kind == win.ADAPTIVE:
        touch_keys = jnp.where(changed, keys, 0)
        delta = win.cms_delta(cms.shape, touch_keys,
                              changed.astype(jnp.float32))
        cms = cms * wconf.cms_decay + router.psum(delta)

    d_agg = agg_flat.shape[-1]
    new_ls = LayerState(
        feat=feat_flat.reshape(P_loc, N, d_in),
        has_feat=has_feat.reshape(P_loc, N),
        x_sent=x_sent_flat.reshape(P_loc, N, d_in),
        has_sent=has_sent.reshape(P_loc, N),
        agg=agg_flat.reshape(P_loc, N, d_agg),
        agg_cnt=cnt_flat.reshape(P_loc, N),
        red_pending=red_pending.reshape(P_loc, N),
        red_deadline=red_deadline.reshape(P_loc, N),
        fwd_pending=fwd_pending.reshape(P_loc, N),
        fwd_deadline=fwd_deadline.reshape(P_loc, N),
        cms=cms,
        last_touch=jnp.where(changed, now,
                             ls.last_touch.reshape(P_loc * N)
                             ).reshape(P_loc, N),
        bc_defer=bc_defer[0], bc_defer_ok=bc_defer[1],
        rmi_defer=rmi_defer[0], rmi_defer_ok=rmi_defer[1])
    psum = router.psum
    if telemetry:
        occ_bc = psum(jnp.sum(bc_defer[1].astype(jnp.int32)))
        occ_rmi = psum(jnp.sum(rmi_defer[1].astype(jnp.int32)))
        route_peak = router.pmax(rcpt.peak)
        outbox_pp = router.pmax(n_demand_pp)
    else:
        occ_bc = occ_rmi = route_peak = outbox_pp = jnp.zeros((), jnp.int32)
    stats = TickStats(broadcast_msgs=psum(n_bcast),
                      reduce_msgs=psum(n_reduce),
                      cross_part_msgs=psum(bcast_cross + red_cross),
                      emitted=psum(n_emit), dropped=psum(n_drop),
                      wire_rows=psum(rcpt.rows),
                      route_deferred=psum(rcpt.deferred),
                      route_dropped=psum(rcpt.dropped),
                      n_suppressed=psum(n_supp),
                      occ_bc_defer=occ_bc, occ_rmi_defer=occ_rmi,
                      route_peak=route_peak, outbox_part_peak=outbox_pp,
                      busy=busy)
    return new_ls, outbox, stats, extra_out


layer_tick = partial(jax.jit, static_argnames=("layer", "wconf", "outbox_cap",
                                               "router", "delivery",
                                               "delta_eps", "telemetry")
                     )(layer_tick_body)


def has_work(ls: LayerState) -> jnp.ndarray:
    """Termination-detection predicate: any pending timer, unsent delta, or
    route-deferred record still waiting in a backpressure ring (carried
    wire rows are in-flight work — quiescence must not fire over them)."""
    return (jnp.any(ls.red_pending) | jnp.any(ls.fwd_pending)
            | jnp.any(ls.bc_defer_ok) | jnp.any(ls.rmi_defer_ok))
