"""Termination detection (paper §5.3).

The TerminationCoordinator declares the pipeline quiescent when, for one
full sweep, every layer operator reports (a) no events received since the
last collection and (b) no scheduled timers (window deadlines still
pending). Used to compute bounded-run "runtime" (paper Fig. 4c) and to
flush the pipeline before training (§4.3.1).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tick import has_work


class TerminationCoordinator:
    def __init__(self, quiet_sweeps: int = 2):
        self.quiet_sweeps = quiet_sweeps
        self._quiet = 0

    def observe(self, layer_states, tick_stats) -> bool:
        """Feed one tick's observations; True once terminated."""
        moved = any(int(s.emitted) + int(s.reduce_msgs) + int(s.broadcast_msgs)
                    for s in tick_stats)
        timers = any(bool(has_work(ls)) for ls in layer_states)
        if moved or timers:
            self._quiet = 0
        else:
            self._quiet += 1
        return self._quiet >= self.quiet_sweeps

    def reset(self):
        self._quiet = 0
