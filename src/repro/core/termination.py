"""Termination detection (paper §5.3).

The TerminationCoordinator declares the pipeline quiescent when, for one
full sweep, every layer operator reports (a) no events received since the
last collection and (b) no scheduled timers (window deadlines still
pending). Used to compute bounded-run "runtime" (paper Fig. 4c) and to
flush the pipeline before training (§4.3.1).

Two observation paths:
  * per-tick (host): `observe` pulls each tick's stats to the host — one
    blocking sync per tick, fine for the reference driver;
  * super-tick (device): `quiet_update` advances a consecutive-quiet-tick
    counter INSIDE the `lax.scan` body, and the driver reads the resulting
    quiescence flag exactly once per super-tick (`observe_flag`).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tick import has_work


def moved_msgs(tick_stats):
    """Total MOVEMENT of one layer's TickStats: emissions + reduces +
    broadcasts. This is THE movement vote both observation paths share
    (`quiet_update` on device, `TerminationCoordinator.observe` on host).

    TickStats.n_suppressed is deliberately EXCLUDED: a delta-gated
    (suppressed-but-pending) vertex counts as QUIET. Suppression clears
    red_pending without emitting (core/tick.py:round_b_emit), so its
    residual is not in-flight work — it only re-enters on a future touch.
    Counting suppressions as movement would let a stream of sub-eps
    updates hold quiescence off forever and flush() would never
    terminate.
    """
    return tick_stats.emitted + tick_stats.reduce_msgs \
        + tick_stats.broadcast_msgs


def pending_work(layer_states, queries=None, extra_work=None) -> jnp.ndarray:
    """LOCAL in-flight-work count (int32): window timers + the routing
    plane's per-lane defer rings (both via `has_work`) + the query
    plane's wire-lane backlog when a QueryState is given + any
    `extra_work` count the caller carries (the hybrid-parallel pipeline
    passes its inter-stage ring occupancy here, so records in flight
    between stages hold quiescence off exactly like deferred wire rows).

    This is THE single aggregation every quiescence / silence gate uses —
    `quiet_update` (super-tick scan), `TerminationCoordinator.observe`
    (per-tick flush) and the query plane's silence gates
    (serve/query.py:_plane_work). A new carried-work source added here
    reaches all of them at once; added anywhere else it silently weakens
    some gate."""
    timers = jnp.zeros((), jnp.int32)
    for ls in layer_states:
        timers = timers + has_work(ls).astype(jnp.int32)
    if queries is not None:
        timers = timers + jnp.sum(queries.wire_defer_ok.astype(jnp.int32))
    if extra_work is not None:
        timers = timers + jnp.asarray(extra_work, jnp.int32)
    return timers


def quiet_update(quiet: jnp.ndarray, layer_states, tick_stats,
                 router=None, queries=None, extra_work=None) -> jnp.ndarray:
    """One in-graph step of quiescence tracking.

    quiet: int32 scalar — consecutive ticks with no movement and no
    in-flight work (`pending_work`: window timers, routing-plane defer
    rings, the query plane's wire backlog when `queries` is given, plus
    the caller's `extra_work` — e.g. inter-stage ring occupancy).
    Resets to 0 on any emission/reduce/broadcast or pending work.
    Under a sharded tick (`router=MeshRouter`) the pending-work vote is
    globally reduced (`psum_vote`: both mesh axes on a hybrid 2-D mesh)
    so every device agrees on the same counter. On a 1-D mesh the stats
    scalars are already globally reduced by the tick body; on a 2-D mesh
    each stage's scalars cover only ITS layers, so the movement vote is
    additionally psum'd over the stage axis.
    """
    if router is not None and getattr(router, "n_stages", 1) > 1:
        moved_n = jnp.zeros((), jnp.int32)
        for s in tick_stats:
            moved_n = moved_n + moved_msgs(s)
        moved = router.psum_stage(moved_n) > 0
    else:
        moved = jnp.zeros((), bool)
        for s in tick_stats:
            moved = moved | (moved_msgs(s) > 0)
    timers = pending_work(layer_states, queries, extra_work)
    if router is not None:
        timers = router.psum_vote(timers)
    return jnp.where(moved | (timers > 0), jnp.int32(0),
                     quiet + jnp.int32(1))


class TerminationCoordinator:
    def __init__(self, quiet_sweeps: int = 2):
        self.quiet_sweeps = quiet_sweeps
        self._quiet = 0

    @property
    def quiet(self) -> int:
        """Consecutive quiet ticks observed so far (read-only)."""
        return self._quiet

    def seed_quiet(self) -> int:
        """The value to seed a device-resident quiet counter with when
        chaining super-ticks (`run_super_tick(quiet0=...)`): quiescence
        streaks must survive the host round-trip between launches."""
        return self._quiet

    def observe(self, layer_states, tick_stats, queries=None,
                extra_work=None) -> bool:
        """Feed one tick's observations; True once terminated.
        queries: optional QueryState — votes the wire-lane backlog as
        pending work (same `pending_work` aggregation as the device
        paths). extra_work: host-side in-flight count (the per-tick
        driver passes the hybrid pipeline's stage-ring occupancy)."""
        moved = any(int(moved_msgs(s)) for s in tick_stats)
        if moved or bool(pending_work(layer_states, queries, extra_work)):
            self._quiet = 0
        else:
            self._quiet += 1
        return self._quiet >= self.quiet_sweeps

    def observe_flag(self, quiet_ticks: int) -> bool:
        """Feed a device-computed consecutive-quiet counter (one host read
        per super-tick). The counter already accumulated within the scan, so
        it replaces — not adds to — the host-side count."""
        self._quiet = int(quiet_ticks)
        return self._quiet >= self.quiet_sweeps

    def reset(self):
        self._quiet = 0
