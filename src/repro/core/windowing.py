"""Windowed forward-pass policies (paper §4.2.4) + CountMinSketch.

Timers are tick-granular (the paper uses a 10ms coalescing interval; one
tick here plays that role). Policies compute per-vertex eviction deadlines:

  Streaming        : deadline = now                  (evict immediately)
  Tumbling         : deadline = (now // W + 1) * W   (fixed buckets)
  Session          : deadline = now + W              (touch extends)
  AdaptiveSession  : deadline = now + clip(alpha / freq_v)  with freq_v an
                     exponentially-decayed CountMinSketch estimate of the
                     vertex's update frequency (paper: "windowed exponential
                     mean of past frequencies ... thread-safe CountMinSketch
                     that is periodically averaged").

Intra-layer windows delay the *forward* (psi-emission) per master vertex;
inter-layer windows delay the *reduce* per source vertex — source-side
delta batching plus per-tick destination coalescing gives the paper's
partial-aggregation effect (DESIGN §2 records this adaptation).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

STREAMING = "streaming"
TUMBLING = "tumbling"
SESSION = "session"
ADAPTIVE = "adaptive"


@dataclass(frozen=True)
class WindowConfig:
    kind: str = STREAMING
    interval: int = 4              # W, in ticks
    adaptive_min: int = 1
    adaptive_max: int = 16
    adaptive_alpha: float = 8.0    # deadline ~= alpha / freq
    cms_decay: float = 0.9         # exponential decay applied per tick


def next_deadline(cfg: WindowConfig, now, cur_deadline, pending, freq):
    """Deadline for vertices touched at tick `now`.

    pending: whether the vertex already had a scheduled eviction.
    freq: CMS frequency estimate (only used by ADAPTIVE).
    """
    if cfg.kind == STREAMING:
        return jnp.full_like(cur_deadline, now)
    if cfg.kind == TUMBLING:
        bucket = (now // cfg.interval + 1) * cfg.interval
        # an existing earlier deadline stays (tumbling buckets don't move)
        return jnp.where(pending, jnp.minimum(cur_deadline, bucket), bucket)
    if cfg.kind == SESSION:
        # every touch pushes eviction back
        return jnp.full_like(cur_deadline, now + cfg.interval)
    if cfg.kind == ADAPTIVE:
        # ceil, not truncate-toward-zero: a hot vertex with alpha/freq in
        # (0, 1) must round UP to a 1-tick interval by policy, not collapse
        # to interval 0 before the clip; fractional intervals generally
        # round to the next whole tick (a deadline is tick-granular)
        interval = jnp.clip(
            jnp.ceil(cfg.adaptive_alpha
                     / jnp.maximum(freq, 1e-3)).astype(jnp.int32),
            cfg.adaptive_min, cfg.adaptive_max)
        return (now + interval).astype(cur_deadline.dtype)
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------- sketch
_CMS_PRIMES = (1000003, 1000033, 1000037, 1000039, 1000081, 1000099)


def cms_hash(keys: jnp.ndarray, depth: int, width: int) -> jnp.ndarray:
    """[depth, n] bucket indices via multiply-shift hashing."""
    ks = keys.astype(jnp.uint32)
    rows = []
    for d in range(depth):
        h = (ks * jnp.uint32(_CMS_PRIMES[d % len(_CMS_PRIMES)])
             + jnp.uint32((d * 0x9E3779B9) & 0xFFFFFFFF))
        h ^= h >> 16
        h *= jnp.uint32(0x85EBCA6B)
        h ^= h >> 13
        rows.append((h % jnp.uint32(width)).astype(jnp.int32))
    return jnp.stack(rows)


def cms_delta(shape, keys: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """The [depth, width] additive table for one update batch.

    Split out from `cms_update` so a sharded tick can keep the sketch
    replicated: each device builds its local delta, psums it, and every
    device applies the identical add (repro/dist/router.py).

    ONE batched scatter-add over all depth rows at once (flattened
    [depth * width] table, row-offset indices) — this runs every tick
    inside the super-tick scan under the ADAPTIVE policy, where the old
    per-depth Python loop of scatters cost `depth` kernel launches.
    The sums are exact small counts, so the scatter order is irrelevant.
    """
    depth, width = shape
    idx = cms_hash(keys, depth, width)                       # [depth, n]
    flat = idx + width * jnp.arange(depth, dtype=idx.dtype)[:, None]
    w = jnp.broadcast_to(weights, idx.shape)
    return jnp.zeros((depth * width,), weights.dtype).at[
        flat.reshape(-1)].add(w.reshape(-1)).reshape(depth, width)


def cms_update(cms: jnp.ndarray, keys: jnp.ndarray, weights: jnp.ndarray,
               decay: float = 1.0) -> jnp.ndarray:
    """Add `weights` at `keys`; optionally decay the whole sketch first."""
    return cms * decay + cms_delta(cms.shape, keys, weights)


def cms_query(cms: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    depth, width = cms.shape
    idx = cms_hash(keys, depth, width)
    ests = jnp.stack([cms[d][idx[d]] for d in range(depth)])
    return jnp.min(ests, axis=0)
